"""Quickstart: train a reduced smollm-360m for a few steps on CPU, then
serve a few greedy tokens from it.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.launch.serve import serve_loop
from repro.launch.train import train_loop


def main() -> None:
    print("== training (reduced smollm-360m, synthetic stream) ==")
    out = train_loop("smollm-360m", steps=15, batch=8, seq=48, lr=3e-3)
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

    print("== serving (reduced qwen3-0.6b, batched greedy decode) ==")
    served = serve_loop("qwen3-0.6b", batch=4, prompt_len=12, gen=8)
    print("generated token ids:\n", served["generated"])


if __name__ == "__main__":
    main()
