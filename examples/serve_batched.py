"""Batched serving demo: prefill a batch of prompts for an enc-dec model
(whisper-tiny backbone with the stubbed audio frontend) and an SSM
(mamba2), then decode tokens — exercising KV-cache, cross-attention cache,
and recurrent-state serving paths.

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import serve_loop


def main() -> None:
    for arch in ("whisper-tiny", "mamba2-130m", "recurrentgemma-9b"):
        print(f"== {arch} ==")
        out = serve_loop(arch, batch=3, prompt_len=10, gen=8)
        print("tokens:\n", out["generated"])


if __name__ == "__main__":
    main()
