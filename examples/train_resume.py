"""Fault-tolerance demo: train with checkpointing, simulate a preemption
mid-run, restart, and verify the resumed run continues the exact same
trajectory (deterministic data pipeline + restored optimizer state).

  PYTHONPATH=src python examples/train_resume.py
"""
import tempfile

import numpy as np

from repro.launch.train import train_loop


def main() -> None:
    with tempfile.TemporaryDirectory() as d:
        print("== uninterrupted 12-step run ==")
        full = train_loop("granite-moe-1b-a400m", steps=12, batch=4, seq=32,
                          ckpt_dir=f"{d}/ref", ckpt_every=4,
                          log=lambda *a: None)
        print("losses:", [f"{x:.3f}" for x in full["losses"]])

        print("== run killed after 6 steps (simulated preemption) ==")
        train_loop("granite-moe-1b-a400m", steps=12, batch=4, seq=32,
                   ckpt_dir=f"{d}/job", ckpt_every=4, stop_after=6,
                   log=lambda *a: None)

        print("== restarted: resumes from latest checkpoint ==")
        resumed = train_loop("granite-moe-1b-a400m", steps=12, batch=4,
                             seq=32, ckpt_dir=f"{d}/job", ckpt_every=4,
                             resume=True)
        drift = np.abs(np.array(full["losses"][6:])
                       - np.array(resumed["losses"])).max()
        print(f"max loss drift vs uninterrupted run: {drift:.2e}")
        assert drift < 1e-3, "resume must continue the same trajectory"
        print("OK — resumed trajectory matches")


if __name__ == "__main__":
    main()
