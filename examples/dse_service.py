"""DSE-as-a-service demo: a burst of mixed sweep queries — different
networks, budgets, objectives, inference and training — submitted from
several client threads to one ``DSEService``, which coalesces them onto
shared cost tables and fans the answers back out.  Ends by printing the
``ServiceStats`` snapshot (coalescing ratio, batch occupancy, latency
percentiles, table/store hit rates) and demonstrating that a poisoned
request fails alone with a structured error.

  PYTHONPATH=src python examples/dse_service.py
"""
import threading

from repro.core import INFER_PRESETS, Study, Workload
from repro.core.layers import ConvLayer, batch_norm, fc, relu
from repro.serve import DSEClient, DSERequest, DSEService, ServiceError


def tiny_train_net():
    def conv(name, **kw):
        base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16,
                    ow=16, kh=3, kw=3, s=1, has_bias=False)
        base.update(kw)
        return ConvLayer(**base)
    return (conv("c1"), batch_norm("c1.bn", 16, 16, 1, 32),
            relu("c1.relu", 16, 16, 1, 32), conv("c2", ic=32, oc=32),
            fc("fc", 1, 2048, 10))


def main() -> None:
    study = Study(INFER_PRESETS[16], sizes=(32, 64, 128, 256),
                  bws=(32, 64, 128, 256), tol=0.5, store=None)
    train = Workload(net=tiny_train_net(), training=True, name="tiny-train")
    burst = [
        DSERequest("resnet18", 512, 256, objective="cycles", tag="r18/cyc"),
        DSERequest("resnet18", 256, 256, objective="edp", tag="r18/edp"),
        DSERequest("alexnet", 512, 256, objective="edp", tag="alex/edp"),
        DSERequest("alexnet", 256, 256, objective="cycles", tag="alex/cyc"),
        DSERequest(train, 512, 256, objective="cycles", tag="train/cyc"),
        DSERequest(train, 256, 256, objective="edp", tag="train/edp"),
        DSERequest("resnet18", 512, 256, objective="cycles", tag="dup"),
        DSERequest("no_such_net", 512, 256, tag="poisoned"),
    ]

    # autostart=False: submit the whole burst first, then start the
    # dispatcher, so it lands in one micro-batch (maximal coalescing).
    svc = DSEService(study, autostart=False, max_batch=len(burst))
    client = DSEClient(svc)
    tickets = [None] * len(burst)

    def submitter(tid, stride=4):
        for i in range(tid, len(burst), stride):
            tickets[i] = client.submit(burst[i])

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.start()

    print("== responses ==")
    for req, ticket in zip(burst, tickets):
        try:
            res = ticket.result(timeout=600)
            print(f"  {req.tag:>10}: sizes_kb={res.best.sizes_kb} "
                  f"bws={res.best.bws} cycles={res.best.cycles}")
        except ServiceError as exc:
            print(f"  {req.tag:>10}: FAILED kind={exc.kind} ({exc.message})")
    svc.close()

    print("== service stats ==")
    st = svc.stats()
    print(" ", st.summary())
    print(f"  searches={st.searches} for priced={st.priced_requests} "
          f"requests (+{st.dedup_hits} dedup) -> "
          f"coalescing {st.coalescing_ratio:.2f}x, "
          f"occupancy {st.batch_occupancy:.2f}")


if __name__ == "__main__":
    main()
