"""SimDIT demo — the paper's own workloads: simulate ResNet-50 training and
inference on the HT3/HI3 accelerators, print the Conv/non-Conv and
per-phase breakdowns (paper Table VI / Sec. V), then run the objective-first
DSE (paper Table VIII row + the Sec. VI energy half): min-cycles, min-energy
and min-EDP allocations, the cycles-vs-energy Pareto frontier, and the
off-lattice refine front-end.

  PYTHONPATH=src python examples/simulate_accelerator.py
"""
from repro.core import HI3, HT3, Study, Workload, simulate


def main() -> None:
    print("== ResNet-50 training on HT3 (64x64 PE array, batch 32) ==")
    rep = simulate(HT3, "resnet50", mode="training")
    e = rep.energy(HT3)
    print(f"  total cycles      : {rep.total_cycles:.3e}")
    print(f"  non-Conv runtime  : {rep.nonconv_fraction('cycles'):.1%}"
          f"  (paper: 59.5%; this model brackets it, see"
          f" benchmarks/table11_training_dse.py)")
    print(f"  non-Conv off-chip : {rep.nonconv_fraction('dram'):.1%}"
          f"  (paper: 56.2%)")
    shares = ", ".join(f"{k} {v:.1%}"
                       for k, v in sorted(rep.phase_shares().items()))
    print(f"  phase shares      : {shares}")
    print(f"  energy            : {e['E_total']:.3f} J,"
          f" P_avg {e['P_avg']:.2f} W, t {e['runtime_s']:.3f} s")

    print("== ResNet-50 inference on HI3 (batch 1) ==")
    rep = simulate(HI3, "resnet50", mode="inference")
    print(f"  non-Conv runtime  : {rep.nonconv_fraction('cycles'):.1%}"
          f"  (paper: 49.3%)")

    print("== DSE: optimal vs worst allocation (2048kB, 2048 bits/cyc) ==")
    study = Study(HI3)
    inference = Workload("resnet50")            # batch 1, BN-folded
    res = study.search(inference, 2048, 2048)
    grid_best = res.best.cycles
    print(f"  best  {res.best.sizes_kb} kB, bw {res.best.bws}"
          f" -> {res.best.cycles:.3e} cycles")
    print(f"  worst -> {res.worst.cycles:.3e} cycles")
    print(f"  improvement {res.improvement:.1f}x (paper: 18.43x)")

    print("== Objectives: min-energy / min-EDP on the same grid ==")
    res_e = study.search(inference, 2048, 2048, objective="energy")
    res_edp = study.search(inference, 2048, 2048, objective="edp")
    print(f"  min-cycles point  : {res.energy_of():.4f} J,"
          f" {res.power_of():.2f} W")
    print(f"  min-energy point  : {res_e.best_score:.4f} J at"
          f" {res_e.best.cycles / grid_best:.1%} of min-cycles latency"
          f"  (sizes {res_e.best.sizes_kb} kB)")
    print(f"  min-EDP point     : {res_edp.energy_of():.4f} J at"
          f" {res_edp.best.cycles / grid_best:.1%} latency")
    front = res.pareto()
    print(f"  cycles-energy Pareto frontier: {len(front)} points"
          f" (vs {len(res.points)} in the within-15% cycles band)")
    for p in front:
        print(f"    {p.sizes_kb} kB, bw {p.bws}: {p.cycles:.3e} cyc,"
              f" {res.energy_of(p):.4f} J")

    print("== Training-graph DSE on HT3 (same budget) ==")
    training = Workload("resnet50", training=True)   # batch 32, Table I
    res_t = Study(HT3).search(training, 2048, 2048)
    pb = res_t.phase_breakdown()
    print(f"  best  {res_t.best.sizes_kb} kB, bw {res_t.best.bws}"
          f" -> {res_t.best.cycles:.3e} cycles")
    print(f"  at optimum: non-Conv {pb.nonconv_share:.1%},"
          f" backward+updates {pb.bwd_share:.1%}")

    print("== Off-lattice DSE (method='refine', same budget) ==")
    ref = study.search(inference, 2048, 2048, method="refine")
    print(f"  best  {ref.best.sizes_kb} kB, bw {ref.best.bws}"
          f" -> {ref.best.cycles:.3e} cycles"
          f" ({ref.best.cycles / grid_best:.1%} of the power-of-two optimum"
          f" at {ref.refine.eval_saving:.0f}x fewer evaluations)")
    pb = ref.phase_breakdown()          # works off-lattice too
    print(f"  at refined optimum: non-Conv {pb.nonconv_share:.1%}")
    ref_e = study.search(inference, 2048, 2048, objective="energy",
                         method="refine")
    print(f"  min-energy refine : {ref_e.best_score:.4f} J"
          f" ({ref_e.best_score / res_e.best_score:.1%} of the"
          f" power-of-two energy optimum)")


if __name__ == "__main__":
    main()
