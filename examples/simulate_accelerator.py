"""SimDIT demo — the paper's own workloads: simulate ResNet-50 training and
inference on the HT3/HI3 accelerators, print the Conv/non-Conv and
per-phase breakdowns (paper Table VI / Sec. V), then run a quick DSE
(paper Table VIII row) including the training-graph sweep.

  PYTHONPATH=src python examples/simulate_accelerator.py
"""
from repro.core import HI3, HT3, simulate
from repro.core.dse import search
from repro.core.networks import resnet50


def main() -> None:
    print("== ResNet-50 training on HT3 (64x64 PE array, batch 32) ==")
    rep = simulate(HT3, "resnet50", mode="training")
    e = rep.energy(HT3)
    print(f"  total cycles      : {rep.total_cycles:.3e}")
    print(f"  non-Conv runtime  : {rep.nonconv_fraction('cycles'):.1%}"
          f"  (paper: 59.5%; this model brackets it, see"
          f" benchmarks/table11_training_dse.py)")
    print(f"  non-Conv off-chip : {rep.nonconv_fraction('dram'):.1%}"
          f"  (paper: 56.2%)")
    shares = ", ".join(f"{k} {v:.1%}"
                       for k, v in sorted(rep.phase_shares().items()))
    print(f"  phase shares      : {shares}")
    print(f"  energy            : {e['E_total']:.3f} J,"
          f" P_avg {e['P_avg']:.2f} W, t {e['runtime_s']:.3f} s")

    print("== ResNet-50 inference on HI3 (batch 1) ==")
    rep = simulate(HI3, "resnet50", mode="inference")
    print(f"  non-Conv runtime  : {rep.nonconv_fraction('cycles'):.1%}"
          f"  (paper: 49.3%)")

    print("== DSE: optimal vs worst allocation (2048kB, 2048 bits/cyc) ==")
    res = search(HI3, resnet50(1, bn=False), 2048, 2048)
    grid_best = res.best.cycles
    print(f"  best  {res.best.sizes_kb} kB, bw {res.best.bws}"
          f" -> {res.best.cycles:.3e} cycles")
    print(f"  worst -> {res.worst.cycles:.3e} cycles")
    print(f"  improvement {res.improvement:.1f}x (paper: 18.43x)")

    print("== Training-graph DSE on HT3 (same budget) ==")
    res = search(HT3, resnet50(32), 2048, 2048, training=True)
    pb = res.phase_breakdown()
    print(f"  best  {res.best.sizes_kb} kB, bw {res.best.bws}"
          f" -> {res.best.cycles:.3e} cycles")
    print(f"  at optimum: non-Conv {pb.nonconv_share:.1%},"
          f" backward+updates {pb.bwd_share:.1%}")

    print("== Off-lattice DSE (method='refine', same budget) ==")
    ref = search(HI3, resnet50(1, bn=False), 2048, 2048, method="refine")
    print(f"  best  {ref.best.sizes_kb} kB, bw {ref.best.bws}"
          f" -> {ref.best.cycles:.3e} cycles"
          f" ({ref.best.cycles / grid_best:.1%} of the power-of-two optimum"
          f" at {ref.refine.eval_saving:.0f}x fewer evaluations)")
    pb = ref.phase_breakdown()          # works off-lattice too
    print(f"  at refined optimum: non-Conv {pb.nonconv_share:.1%}")


if __name__ == "__main__":
    main()
