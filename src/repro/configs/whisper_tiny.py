"""whisper-tiny [audio] — enc-dec, conv frontend stubbed.
4L enc + 4L dec, d_model=384, 6H (kv=6), d_ff=1536, vocab=51865.
[arXiv:2212.04356; unverified]

Whisper uses learned positions (no rope), LayerNorm, GELU; the real model
caps decoder positions at 448 — decode shapes beyond that are exercised
structurally (the launch layer resizes the learned-position table), noted
in DESIGN.md.
"""
from repro.models.common import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
        vocab_size=51865,
        norm_type="layernorm", act="gelu",
        rope_fraction=0.0, learned_pos=448,
        encoder_layers=4, encoder_seq=1500,
        tie_embeddings=True,
    )
