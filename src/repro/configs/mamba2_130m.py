"""mamba2-130m [ssm] — 24L d_model=768, attention-free (d_ff=0),
vocab=50280, ssm_state=128 — SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.models.common import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, d_ff=0,
        vocab_size=50280,
        block_pattern=("mamba2",),
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, conv_width=4,
        tie_embeddings=True,
    )
