"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 — RG-LRU + local attention in a (recurrent, recurrent,
attention) 2:1 pattern, window=2048, head_dim=256.
[arXiv:2402.19427; unverified]

38 = 12 full (rglru, rglru, attn) groups + 2 trailing recurrent layers
(handled by the grouped-scan remainder)."""
from repro.models.common import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
        vocab_size=256000, head_dim=256,
        act="gelu",
        window=2048, attn_pattern=("local",),
        block_pattern=("rglru", "rglru", "attn"),
        rnn_width=4096, conv_width=4,
        tie_embeddings=True,
    )
