"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (expert dim), vocab=202048, MoE 128 experts top-1 + shared
expert, alternating dense/MoE layers (Llama-4 interleave), head_dim=128,
early fusion (text backbone here; vision stub not in the assigned shape
set). [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.common import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab_size=202048, head_dim=128, rope_theta=5e5,
        n_experts=128, top_k=1, shared_expert=True,
        block_pattern=("attn+moe", "attn"), moe_every=2,
        tie_embeddings=False,
    )
