"""Architecture config registry: ``get_config(arch_id)`` plus the reduced
(smoke-test) transform.  One module per assigned architecture."""
from __future__ import annotations

from typing import Dict, List

from repro.models.common import ModelConfig

ARCHS: List[str] = [
    "whisper-tiny",
    "qwen3-0.6b",
    "gemma3-27b",
    "stablelm-1.6b",
    "smollm-360m",
    "pixtral-12b",
    "mamba2-130m",
    "llama4-maverick-400b-a17b",
    "granite-moe-1b-a400m",
    "recurrentgemma-9b",
]

_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "qwen3-0.6b": "qwen3_0_6b",
    "gemma3-27b": "gemma3_27b",
    "stablelm-1.6b": "stablelm_1_6b",
    "smollm-360m": "smollm_360m",
    "pixtral-12b": "pixtral_12b",
    "mamba2-130m": "mamba2_130m",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    import importlib
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.get_config()


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to smoke-test scale, preserving the family's
    structure (pattern length, GQA ratio, MoE top-k, qk-norm, etc.)."""
    plen = len(cfg.pattern)
    # >=2 full groups, plus a remainder layer when the pattern is grouped so
    # the unrolled-remainder path is exercised (recurrentgemma: 38 = 12*3+2)
    n_layers = 2 * plen + (1 if plen > 1 else 0)
    kv_ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    heads = 4
    kv = max(1, heads // kv_ratio)
    if cfg.n_kv_heads == cfg.n_heads:
        kv = heads
    return cfg.replace(
        n_layers=n_layers,
        d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=503,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_block=64,
        ssm_state=16 if cfg.ssm_state else 0, ssm_head_dim=16,
        rnn_width=64 if cfg.rnn_width else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=24 if cfg.encoder_seq else 0,
        learned_pos=96 if cfg.learned_pos else 0,
        n_patches=8 if cfg.n_patches else 0,
        window=8 if cfg.window else 0,
        attn_block=32, dense_attn_max_seq=64,
    )
