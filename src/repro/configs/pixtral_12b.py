"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT frontend STUBBED (precomputed patch embeddings,
early fusion) + mistral-nemo-style decoder, head_dim=128.
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.models.common import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=131072, head_dim=128, rope_theta=1e6,
        n_patches=64,
        tie_embeddings=False,
    )
