"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding-window schedule (window=1024),
qk-norm, head_dim=128, 128k-class context. [hf:google/gemma-3-1b-pt;
unverified]"""
from repro.models.common import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
        vocab_size=262144, head_dim=128,
        qk_norm=True, act="gelu", rope_theta=1e6,
        window=1024,
        attn_pattern=("local", "local", "local", "local", "local", "global"),
        tie_embeddings=True,
    )
