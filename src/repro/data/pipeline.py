"""Deterministic, checkpointable, shardable synthetic-token data pipeline.

Production shape: each host generates only its shard of the global batch
(``host_slice``), the stream is a counter-based PRNG (stateless — the
pipeline state is just the step counter, so restore = set the counter),
and batches arrive as numpy so device placement stays under pjit's
control.  A real deployment swaps ``_synth_doc`` for a tokenized corpus
reader; every interface (state save/restore, sharding, determinism) is
what the checkpoint/restart machinery relies on.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: Dict) -> "PipelineState":
        return cls(step=int(d["step"]))


@dataclass
class TokenPipeline:
    """Markov-chain synthetic LM stream (learnable structure, so smoke
    training shows a decreasing loss)."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    order: int = 2          # tokens depend on the previous token mod order

    def __post_init__(self):
        assert self.global_batch % self.host_count == 0
        self.local_batch = self.global_batch // self.host_count

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._batch_rng(step)
        b, s, v = self.local_batch, self.seq_len, self.vocab_size
        # learnable structure: tokens repeat with p=0.6 (bigram identity)
        # over a Zipf-skewed unigram base (marginal is learnable too)
        zipf = np.minimum(rng.zipf(1.5, size=(b, s)) - 1, v - 1).astype(
            np.int32)
        x = np.empty((b, s), np.int32)
        x[:, 0] = zipf[:, 0]
        repeat = rng.random((b, s)) < 0.6
        for t in range(1, s):
            x[:, t] = np.where(repeat[:, t], x[:, t - 1], zipf[:, t])
        return {"tokens": x}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iter_from(self, state: PipelineState) -> Iterator[
            Tuple[PipelineState, Dict[str, np.ndarray]]]:
        step = state.step
        while True:
            yield PipelineState(step + 1), self.batch_at(step)
            step += 1
