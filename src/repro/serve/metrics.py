"""Service metrics: the observability surface of ``repro.serve``.

Two pieces:

  * ``ServiceMetrics`` — the mutable, lock-guarded accumulator the
    ``DSEService`` dispatcher and client threads write into (counters,
    a bounded latency window, batch occupancy sums).
  * ``ServiceStats`` — an immutable snapshot of everything at one
    instant: request counters, batch/coalescing numbers, p50/p95 request
    latency, queue depth, and a consistent cut of the shared table-cache
    counters (``table_cache_stats()`` itself snapshots under the cache
    lock, so hits/misses/builds are never torn).

The headline number is ``coalescing_ratio``: requests priced per
``search_many`` dispatch.  A ratio of 1.0 means every query paid its own
search; above 1.0 means concurrent queries shared grouped dispatches
(and, through the union tables inside each dispatch plus the
process-lifetime caches across dispatches, shared table builds — the
thing that makes serving cheaper than N independent scripts).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

LATENCY_WINDOW = 4096          # completed-request latencies retained


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile of an unsorted sample (0 <= q <= 1);
    0.0 on an empty sample.  Deterministic and dependency-free — the
    service snapshot must never need numpy for a handful of floats."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = max(0, min(len(s) - 1, int(round(q * (len(s) - 1)))))
    return s[rank]


@dataclass(frozen=True)
class ServiceStats:
    """Immutable metrics snapshot; see ``DSEService.stats()``.

    Counter semantics:

    ``submitted``        accepted requests (dedup followers included)
    ``completed``        requests resolved with a result
    ``failed``           requests resolved with a structured error
                         (timeouts counted separately in ``timeouts``)
    ``rejected``         admission-control refusals (never enqueued)
    ``dedup_hits``       submissions answered by an in-flight duplicate
    ``batches``          dispatcher micro-batches drained
    ``degraded_batches`` grouped dispatches that fell back to
                         per-request serial evaluation
    ``searches``         pricing dispatches (grouped ``search_many``
                         calls + serial per-request evaluations)
    ``priced_requests``  requests answered through those dispatches
    """
    submitted: int
    completed: int
    failed: int
    timeouts: int
    rejected: int
    dedup_hits: int
    batches: int
    batch_requests: int
    degraded_batches: int
    searches: int
    priced_requests: int
    queue_depth: int
    inflight: int
    latency_p50_s: float
    latency_p95_s: float
    latency_samples: int
    table_cache: Dict[str, object] = field(repr=False)

    @property
    def batch_occupancy(self) -> float:
        """Mean requests per dispatched micro-batch."""
        return self.batch_requests / self.batches if self.batches else 0.0

    @property
    def coalescing_ratio(self) -> float:
        """Requests priced per pricing dispatch (dedup followers ride
        their primary's dispatch, so they count toward the numerator)."""
        return ((self.priced_requests + self.dedup_hits) / self.searches
                if self.searches else 0.0)

    def _hit_rate(self, hits_key: str, misses_key: str) -> float:
        h = int(self.table_cache.get(hits_key, 0))
        m = int(self.table_cache.get(misses_key, 0))
        return h / (h + m) if h + m else 0.0

    @property
    def table_hit_rate(self) -> float:
        """L1 hit rate over every table kind (conv + simd + gemm)."""
        h = sum(int(self.table_cache.get(f"{k}_hits", 0))
                for k in ("conv", "simd", "gemm"))
        m = sum(int(self.table_cache.get(f"{k}_misses", 0))
                for k in ("conv", "simd", "gemm"))
        return h / (h + m) if h + m else 0.0

    @property
    def store_hit_rate(self) -> float:
        """Persistent-store (L2) hit rate; 0.0 when the store is off."""
        return self._hit_rate("store_hits", "store_misses")

    def summary(self) -> str:
        """One human line for logs and the example/benchmark output."""
        return (f"submitted={self.submitted} completed={self.completed} "
                f"failed={self.failed} timeouts={self.timeouts} "
                f"rejected={self.rejected} dedup={self.dedup_hits} "
                f"batches={self.batches} "
                f"occupancy={self.batch_occupancy:.2f} "
                f"coalescing={self.coalescing_ratio:.2f}x "
                f"degraded={self.degraded_batches} "
                f"p50={self.latency_p50_s * 1e3:.1f}ms "
                f"p95={self.latency_p95_s * 1e3:.1f}ms "
                f"table_hit_rate={self.table_hit_rate:.2f} "
                f"store_hit_rate={self.store_hit_rate:.2f}")


class ServiceMetrics:
    """Lock-guarded accumulator behind ``DSEService.stats()``.

    Every mutator is a single short critical section, safe to call from
    the dispatcher thread, pricing watchdog threads, and any number of
    client threads at once."""

    def __init__(self, latency_window: int = LATENCY_WINDOW):
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {             # guarded-by: self._lock
            k: 0 for k in ("submitted", "completed", "failed", "timeouts",
                           "rejected", "dedup_hits", "batches",
                           "batch_requests", "degraded_batches",
                           "searches", "priced_requests")}
        self._latencies: deque = deque(maxlen=latency_window)  # guarded-by: self._lock

    def count(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._counts[key] += n

    def batch(self, n_requests: int) -> None:
        with self._lock:
            self._counts["batches"] += 1
            self._counts["batch_requests"] += n_requests

    def search(self, n_priced: int) -> None:
        with self._lock:
            self._counts["searches"] += 1
            self._counts["priced_requests"] += n_priced

    def completed(self, latency_s: float) -> None:
        with self._lock:
            self._counts["completed"] += 1
            self._latencies.append(latency_s)

    def failed(self, timeout: bool) -> None:
        with self._lock:
            self._counts["failed"] += 1
            if timeout:
                self._counts["timeouts"] += 1

    def snapshot(self, queue_depth: int, inflight: int,
                 table_cache: Dict[str, object]) -> ServiceStats:
        with self._lock:
            counts = dict(self._counts)
            lats = list(self._latencies)
        return ServiceStats(
            queue_depth=queue_depth, inflight=inflight,
            latency_p50_s=percentile(lats, 0.50),
            latency_p95_s=percentile(lats, 0.95),
            latency_samples=len(lats),
            table_cache=table_cache, **counts)
