"""repro.serve — DSE-as-a-service: concurrent sweep serving.

Public surface::

    from repro.serve import DSEService, DSEClient, DSERequest

    svc = DSEService(Study(...))
    client = DSEClient(svc)
    result = client.query("resnet18", size_budget_kb=512, bw_budget=16)
    print(svc.stats().summary())

See ``service.py`` for the architecture (micro-batching, coalescing,
admission control, graceful degradation) and ``metrics.py`` for the
``ServiceStats`` snapshot semantics.
"""
from .client import DSEClient
from .metrics import ServiceMetrics, ServiceStats, percentile
from .service import (AdmissionError, DSERequest, DSEService,
                      InvalidRequest, RequestFailed, RequestTimeout,
                      ServiceError, Ticket)

__all__ = [
    "DSEClient", "DSEService", "DSERequest", "Ticket",
    "ServiceError", "AdmissionError", "InvalidRequest",
    "RequestFailed", "RequestTimeout",
    "ServiceMetrics", "ServiceStats", "percentile",
]
