"""DSE-as-a-service: a concurrent sweep-serving loop over one ``Study``.

The durability layer (``core.store``) made warm sweeps pure lookups;
this module is the serving half of the ROADMAP item: many concurrent
DSE queries — different networks, budgets, objectives, inference and
training — submitted from any number of threads, answered from ONE
``Study`` so they coalesce on shared cost tables.  The framing is the
TPU paper's datacenter one (serve heavy query traffic from a shared
accelerator fleet model), applied to the simulator itself.

Architecture::

    client threads ── submit() ──>  bounded queue  ──>  dispatcher thread
         ^   admission control /        |                   |
         |   in-flight dedup            |            micro-batch drain
         |                              v                   v
      Ticket  <── future fan-out ── per-request   group by (budgets,
       .result()                      futures      objective, method)
                                                        |
                                              ONE search_many per group
                                              (union-of-shapes tables)

  * **Micro-batching + coalescing.**  The dispatcher drains the queue in
    micro-batches (up to ``max_batch``, waiting ``coalesce_window_s``
    for a burst to accumulate), groups compatible requests — same
    ``SweepRequest.group_key``, i.e. same budgets/objective/method on
    this service's one hardware base and lattice — and prices each group
    with ONE ``Study.search_requests`` call, so N concurrent queries for
    different networks share every table build their shape union allows.
    Results fan back out through per-request futures, each bit-identical
    to a direct synchronous ``Study.search`` (pinned in
    tests/test_service.py).
  * **Dedup/memoization.**  Identical in-flight queries (equal
    ``SweepRequest.dedup_key``) attach to the first submission's future
    and never hit the queue.
  * **Admission control.**  At most ``max_pending`` requests may be
    in flight; past that, ``submit`` raises ``AdmissionError`` instead
    of letting the queue grow without bound.  Per-request deadlines
    (``timeout_s``) fail a request with ``RequestTimeout`` whether it
    expires waiting in the queue or mid-pricing (watchdog).
  * **Graceful degradation.**  A poisoned request fails ALONE: unknown
    nets are caught at pre-validation, and any grouped dispatch that
    raises or hangs (see the ``service_batch_exc`` /
    ``service_request_hang`` fault points in ``core.faultinject``) is
    retried per request serially — the batch is never dropped, and each
    failure surfaces as a structured ``ServiceError`` on its own future.
  * **Metrics.**  ``stats()`` returns a ``ServiceStats`` snapshot: queue
    depth, batch occupancy, coalescing ratio, p50/p95 request latency,
    and a race-safe cut of ``table_cache_stats()`` (cache/store hit
    rates).

Thread-safety note: the dispatcher and its pricing watchdog threads
drive the process-lifetime table caches concurrently with any direct
``Study`` use on other threads; the caches serialize check-then-build
under a lock (``core.dse._CACHE_LOCK``), so concurrent identical
queries build each table exactly once.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Union

from ..core import faultinject
from ..core.dse import DSEResult, table_cache_stats
from ..core.study import Study, SweepRequest
from .metrics import ServiceMetrics, ServiceStats

HANG_DEFAULT_S = 3600.0        # service_request_hang without an arg


class ServiceError(RuntimeError):
    """Structured per-request failure.

    ``kind`` is one of ``"rejected"`` (admission control), ``"timeout"``
    (deadline passed in queue or mid-pricing), ``"invalid"`` (the
    workload itself cannot be resolved), or ``"error"`` (pricing raised;
    the original exception rides on ``__cause__``).  ``request`` is the
    offending ``DSERequest`` so callers can retry or log it."""
    kind = "error"

    def __init__(self, message: str,
                 request: Optional["DSERequest"] = None):
        self.request = request
        self.message = message
        tag = f" [{request.tag}]" if request is not None and request.tag \
            else ""
        super().__init__(f"[{self.kind}]{tag} {message}")


class AdmissionError(ServiceError):
    """Submission refused: the service is saturated or closed."""
    kind = "rejected"


class RequestTimeout(ServiceError):
    """The request's deadline passed before a result was produced."""
    kind = "timeout"


class InvalidRequest(ServiceError):
    """The workload cannot be resolved (unknown net, bad seq, ...)."""
    kind = "invalid"


class RequestFailed(ServiceError):
    """Pricing this request raised; the cause is chained."""
    kind = "error"


@dataclass(frozen=True)
class DSERequest(SweepRequest):
    """A ``SweepRequest`` plus service-level envelope fields.

    ``timeout_s`` is this request's deadline (measured from ``submit``;
    ``None`` falls back to the service default); ``tag`` is an opaque
    client label echoed in errors and ``Ticket.request``.  Neither field
    participates in ``dedup_key``/``group_key`` — they describe the
    *delivery*, not the answer."""
    timeout_s: Optional[float] = None
    tag: Optional[str] = None


class Ticket:
    """Client handle for one submitted request.

    ``result(timeout=None)`` blocks for the ``DSEResult``; it raises the
    structured ``ServiceError`` subclass the service resolved the
    request with on failure.  Deduplicated submissions hold tickets
    backed by the same future, so they observe one shared result."""

    def __init__(self, request: DSERequest, future: "Future[DSEResult]",
                 submitted_at: float):
        self.request = request
        self._future = future
        self._submitted_at = submitted_at

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None) -> DSEResult:
        return self._future.result(timeout)

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        return self._future.exception(timeout)

    @property
    def latency_s(self) -> Optional[float]:
        """Submission-to-now wall time while pending, frozen usage is up
        to the caller; ``None`` before submission bookkeeping."""
        return time.monotonic() - self._submitted_at


class _Entry:
    """Internal queue record: request + future + deadline."""
    __slots__ = ("request", "future", "submitted_at", "deadline", "key")

    def __init__(self, request: DSERequest, submitted_at: float,
                 deadline: Optional[float], key: Optional[tuple]):
        self.request = request
        self.future: "Future[DSEResult]" = Future()
        self.submitted_at = submitted_at
        self.deadline = deadline
        self.key = key

    def remaining(self, now: float) -> Optional[float]:
        return None if self.deadline is None else self.deadline - now


class _WatchdogTimeout(Exception):
    """Internal: a pricing call outlived its watchdog deadline."""


def _run_with_watchdog(fn, timeout_s: Optional[float]):
    """Run ``fn()`` on a watchdog thread; raise ``_WatchdogTimeout`` if
    it neither returns nor raises within ``timeout_s`` (``None`` = run
    inline, unguarded).  A timed-out call keeps running on its daemon
    thread — it may still warm the shared caches — but its result is
    discarded and it can never touch a request future (completion
    happens in the caller, after this returns)."""
    if timeout_s is None:
        return fn()
    box: Dict[str, object] = {}
    done = threading.Event()

    def run():
        try:
            box["ok"] = fn()
        except BaseException as exc:       # noqa: BLE001 — re-raised below
            box["err"] = exc
        finally:
            done.set()

    t = threading.Thread(target=run, daemon=True,
                         name="repro-dse-pricing")
    t.start()
    if not done.wait(max(0.001, timeout_s)):
        raise _WatchdogTimeout(f"pricing exceeded {timeout_s:.3f}s")
    if "err" in box:
        raise box["err"]                   # type: ignore[misc]
    return box["ok"]


class DSEService:
    """Concurrent sweep-serving front door over one ``Study``.

    Parameters:

    ``study``             the one ``Study`` whose hardware base, lattice,
                          store, workers, self-check, and backend every
                          request runs against
    ``max_pending``       admission bound: in-flight requests past which
                          ``submit`` raises ``AdmissionError``
    ``max_batch``         micro-batch size cap per dispatcher drain
    ``coalesce_window_s`` how long a drain waits for a burst to
                          accumulate after its first request
    ``batch_timeout_s``   watchdog ceiling per pricing dispatch when no
                          request deadline is tighter (``None`` disables
                          the watchdog entirely)
    ``default_timeout_s`` per-request deadline for requests that don't
                          carry their own (``None`` = no deadline)
    ``autostart``         spawn the dispatcher immediately; pass False
                          to submit a burst first and ``start()`` after,
                          which guarantees maximal coalescing
                          (deterministic tests/benchmarks)

    Use as a context manager: ``with DSEService(study) as svc: ...``
    closes and drains on exit."""

    def __init__(self, study: Study, *,
                 max_pending: int = 128,
                 max_batch: int = 16,
                 coalesce_window_s: float = 0.02,
                 batch_timeout_s: Optional[float] = 300.0,
                 default_timeout_s: Optional[float] = None,
                 poll_s: float = 0.05,
                 autostart: bool = True):
        self.study = study
        self.max_pending = int(max_pending)
        self.max_batch = max(1, int(max_batch))
        self.coalesce_window_s = float(coalesce_window_s)
        self.batch_timeout_s = batch_timeout_s
        self.default_timeout_s = default_timeout_s
        self.poll_s = float(poll_s)
        self.metrics = ServiceMetrics()
        self._queue: "queue.Queue[_Entry]" = queue.Queue()
        self._lock = threading.Lock()
        self._inflight: Dict[tuple, _Entry] = {}   # guarded-by: self._lock
        self._pending = 0                          # guarded-by: self._lock
        self._closed = False                       # guarded-by: self._lock
        self._abandon = False                      # guarded-by: self._lock
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None  # guarded-by: self._lock
        if autostart:
            self.start()

    # ---- lifecycle ---------------------------------------------------------

    def start(self) -> "DSEService":
        """Spawn the dispatcher thread (idempotent)."""
        with self._lock:
            if self._closed:
                raise AdmissionError("service is closed")
            if self._thread is None or not self._thread.is_alive():
                self._stop.clear()
                self._thread = threading.Thread(
                    target=self._loop, daemon=True,
                    name="repro-dse-dispatcher")
                self._thread.start()
        return self

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop accepting requests; by default let the dispatcher drain
        what is already queued, then join it.  ``drain=False`` fails the
        backlog with ``AdmissionError`` instead of pricing it."""
        with self._lock:
            self._closed = True
            if not drain:
                self._abandon = True
            t = self._thread
        self._stop.set()
        if t is not None and t is not threading.current_thread():
            t.join(timeout)

    def __enter__(self) -> "DSEService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- submission --------------------------------------------------------

    def submit(self, request, size_budget_kb: Optional[int] = None,
               bw_budget: Optional[int] = None, *,
               objective: Union[str, object, None] = "cycles",
               method: str = "grid",
               timeout_s: Optional[float] = None,
               tag: Optional[str] = None) -> Ticket:
        """Enqueue one query and return its ``Ticket`` immediately.

        Accepts either a prebuilt ``DSERequest``/``SweepRequest`` or the
        inline form ``submit(workload, size_budget_kb, bw_budget,
        objective=..., method=..., timeout_s=...)``.  Raises
        ``AdmissionError`` when the service is closed or ``max_pending``
        requests are already in flight."""
        if isinstance(request, DSERequest):
            req = request
        elif isinstance(request, SweepRequest):
            req = DSERequest(request.workload, request.size_budget_kb,
                             request.bw_budget, objective=request.objective,
                             method=request.method, timeout_s=timeout_s,
                             tag=tag)
        else:
            if size_budget_kb is None or bw_budget is None:
                raise TypeError("submit(workload, size_budget_kb, "
                                "bw_budget, ...) or submit(DSERequest)")
            req = DSERequest(request, size_budget_kb, bw_budget,
                             objective=objective, method=method,
                             timeout_s=timeout_s, tag=tag)
        now = time.monotonic()
        try:
            key: Optional[tuple] = req.dedup_key
            hash(key)
        except TypeError:                  # unhashable custom piece: no dedup
            key = None
        with self._lock:
            if self._closed:
                self.metrics.count("rejected")
                raise AdmissionError("service is closed", req)
            if key is not None:
                primary = self._inflight.get(key)
                if primary is not None:
                    self.metrics.count("submitted")
                    self.metrics.count("dedup_hits")
                    return Ticket(req, primary.future, now)
            if self._pending >= self.max_pending:
                self.metrics.count("rejected")
                raise AdmissionError(
                    f"queue full ({self.max_pending} requests pending)",
                    req)
            timeout = req.timeout_s if req.timeout_s is not None \
                else self.default_timeout_s
            entry = _Entry(req, now,
                           None if timeout is None else now + timeout, key)
            if key is not None:
                self._inflight[key] = entry
            self._pending += 1
        self._queue.put(entry)
        self.metrics.count("submitted")
        return Ticket(req, entry.future, now)

    # ---- metrics -----------------------------------------------------------

    def stats(self) -> ServiceStats:
        """A consistent ``ServiceStats`` snapshot (see ``serve.metrics``);
        the table-cache cut comes from ``table_cache_stats()``, which
        copies its counters under the cache lock."""
        with self._lock:
            inflight = self._pending
        return self.metrics.snapshot(queue_depth=self._queue.qsize(),
                                     inflight=inflight,
                                     table_cache=table_cache_stats())

    # ---- dispatcher --------------------------------------------------------

    def _loop(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=self.poll_s)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            batch = [first]
            window_end = time.monotonic() + self.coalesce_window_s
            while len(batch) < self.max_batch:
                remaining = window_end - time.monotonic()
                try:
                    batch.append(self._queue.get(
                        timeout=max(0.0, remaining)))
                except queue.Empty:
                    break
            self._dispatch(batch)

    def _dispatch(self, batch: List[_Entry]) -> None:
        self.metrics.batch(len(batch))
        now = time.monotonic()
        live: List[_Entry] = []
        with self._lock:
            abandon = self._abandon
        for e in batch:
            if abandon:
                self._fail(e, AdmissionError("service closed before "
                                             "dispatch", e.request))
                continue
            rem = e.remaining(now)
            if rem is not None and rem <= 0:
                self._fail(e, RequestTimeout(
                    f"deadline passed after {now - e.submitted_at:.3f}s "
                    f"in queue", e.request))
                continue
            # Pre-validation: a workload that cannot even resolve to a
            # layer graph (unknown net, seq on a CNN, ...) fails alone
            # here instead of poisoning its group's shared search call.
            try:
                e.request.workload.layers()
            except Exception as exc:
                err = InvalidRequest(str(exc), e.request)
                err.__cause__ = exc
                self._fail(e, err)
                continue
            live.append(e)
        groups: Dict[tuple, List[_Entry]] = {}
        for e in live:
            groups.setdefault(e.request.group_key, []).append(e)
        for entries in groups.values():
            self._price_group(entries)

    # ---- pricing -----------------------------------------------------------

    def _effective_timeout(self, entries: List[_Entry],
                           now: float) -> Optional[float]:
        """Watchdog budget for one dispatch: the tightest remaining
        request deadline, capped by ``batch_timeout_s``."""
        limits = [r for e in entries
                  if (r := e.remaining(now)) is not None]
        if self.batch_timeout_s is not None:
            limits.append(self.batch_timeout_s)
        return min(limits) if limits else None

    def _price_group(self, entries: List[_Entry]) -> None:
        """Price one compatible group with a single shared search; on any
        failure — an exception out of the dispatch or a watchdog trip —
        degrade to per-request serial evaluation so one poisoned request
        cannot take its batchmates down."""
        requests = [e.request for e in entries]

        def work() -> List[DSEResult]:
            f = faultinject.fire("service_batch_exc")
            if f is not None:
                raise RuntimeError(
                    "faultinject: injected dispatcher batch exception")
            f = faultinject.fire("service_request_hang")
            if f is not None:
                time.sleep(f.arg if f.arg is not None else HANG_DEFAULT_S)
            return self.study.search_requests(requests)

        try:
            results = _run_with_watchdog(
                work, self._effective_timeout(entries, time.monotonic()))
        except Exception:
            self.metrics.count("degraded_batches")
            self._price_serial(entries)
            return
        self.metrics.search(len(entries))
        for e, res in zip(entries, results):
            self._complete(e, res)

    def _price_serial(self, entries: List[_Entry]) -> None:
        """Degraded mode: each request priced (and watchdogged) alone, so
        failures and timeouts stay request-local."""
        for e in entries:
            now = time.monotonic()
            rem = e.remaining(now)
            if rem is not None and rem <= 0:
                self._fail(e, RequestTimeout(
                    "deadline passed during degraded batch", e.request))
                continue

            def work_one(req=e.request) -> DSEResult:
                f = faultinject.fire("service_request_hang")
                if f is not None:
                    time.sleep(f.arg if f.arg is not None
                               else HANG_DEFAULT_S)
                return self.study.search_requests([req])[0]

            try:
                res = _run_with_watchdog(
                    work_one, self._effective_timeout([e], now))
            except _WatchdogTimeout as exc:
                self._fail(e, RequestTimeout(str(exc), e.request))
            except Exception as exc:
                err = RequestFailed(f"{type(exc).__name__}: {exc}",
                                    e.request)
                err.__cause__ = exc
                self._fail(e, err)
            else:
                self.metrics.search(1)
                self._complete(e, res)

    # ---- completion fan-out ------------------------------------------------

    def _retire(self, e: _Entry) -> None:
        with self._lock:
            if e.key is not None and self._inflight.get(e.key) is e:
                del self._inflight[e.key]
            self._pending -= 1

    def _complete(self, e: _Entry, result: DSEResult) -> None:
        self._retire(e)
        e.future.set_result(result)
        self.metrics.completed(time.monotonic() - e.submitted_at)

    def _fail(self, e: _Entry, error: ServiceError) -> None:
        self._retire(e)
        e.future.set_exception(error)
        self.metrics.failed(timeout=isinstance(error, RequestTimeout))
