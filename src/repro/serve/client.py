"""Client-side convenience wrapper around a ``DSEService``.

The service's native surface is ``submit() -> Ticket``; this wrapper
adds the three shapes callers actually write:

  * ``query(...)``        — synchronous single query (submit + wait)
  * ``submit(...)``       — passthrough, returns the ``Ticket``
  * ``query_burst(...)``  — submit a whole burst first, THEN gather, so
    the dispatcher sees the burst inside one coalesce window and can
    group it (submit-then-wait loops serialize and defeat coalescing)

``query_burst`` with ``return_errors=True`` maps failed requests to
their ``ServiceError`` instead of raising, which is what sweep drivers
want: one poisoned config shouldn't abort the gather of the other N-1.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

from ..core.dse import DSEResult
from .service import DSERequest, DSEService, ServiceError, Ticket


class DSEClient:
    """Thin, thread-safe facade over one ``DSEService``.

    Many clients (one per thread, or one shared — both are fine) can
    point at the same service; all state lives in the service."""

    def __init__(self, service: DSEService):
        self.service = service

    def submit(self, workload, size_budget_kb: Optional[int] = None,
               bw_budget: Optional[int] = None, *,
               objective: Union[str, object, None] = "cycles",
               method: str = "grid",
               timeout_s: Optional[float] = None,
               tag: Optional[str] = None) -> Ticket:
        """Enqueue one query (inline fields or a prebuilt ``DSERequest``
        as the sole argument); returns immediately with its ``Ticket``."""
        return self.service.submit(
            workload, size_budget_kb, bw_budget, objective=objective,
            method=method, timeout_s=timeout_s, tag=tag)

    def query(self, workload, size_budget_kb: int, bw_budget: int, *,
              objective: Union[str, object, None] = "cycles",
              method: str = "grid",
              timeout_s: Optional[float] = None,
              tag: Optional[str] = None) -> DSEResult:
        """Synchronous query: submit and block for the ``DSEResult``
        (raises the request's ``ServiceError`` on failure)."""
        return self.submit(workload, size_budget_kb, bw_budget,
                           objective=objective, method=method,
                           timeout_s=timeout_s, tag=tag).result()

    def submit_burst(self, requests: Sequence[DSERequest]) -> List[Ticket]:
        """Submit every request before waiting on any — the coalescing-
        friendly pattern.  Admission failures surface immediately."""
        return [self.service.submit(r) for r in requests]

    def query_burst(self, requests: Sequence[DSERequest], *,
                    return_errors: bool = False
                    ) -> List[Union[DSEResult, ServiceError]]:
        """Submit a burst, then gather in submission order.

        With ``return_errors=False`` (default) the first failure raises
        its ``ServiceError``; with ``True`` each failed slot holds its
        error so the healthy majority still comes back."""
        tickets = self.submit_burst(requests)
        out: List[Union[DSEResult, ServiceError]] = []
        for t in tickets:
            if return_errors:
                err = t.exception()
                out.append(err if err is not None else t.result())
            else:
                out.append(t.result())
        return out
