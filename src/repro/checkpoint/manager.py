"""Checkpointing: atomic, retention-managed, mesh-elastic.

Fault-tolerance contract:
  * atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` -> a crash
    mid-save never corrupts the latest checkpoint;
  * resumable: ``latest_step`` + ``restore`` reconstruct params, optimizer
    state, and the data-pipeline state;
  * elastic: arrays are saved UNSHARDED (gathered) with a manifest of
    logical PartitionSpecs; ``restore`` re-shards onto whatever mesh the
    restarted job has (the mesh shape may differ from the saving job's);
  * preemption-aware: ``CheckpointManager.save_on_signal`` installs a
    SIGTERM hook that flushes a checkpoint before exit.

Storage is npz-per-leaf with a JSON manifest (no external deps); a real
cluster deployment would swap the file driver for a parallel blob store —
the interfaces (manifest, atomicity, resharding) are the load-bearing part.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import signal
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16 natively: store as a uint16 view and
# record the logical dtype in the manifest
_VIEW_DTYPES = {"bfloat16": np.uint16}


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ---- write -------------------------------------------------------------
    def save(self, step: int, state: Dict, extra: Optional[Dict] = None
             ) -> pathlib.Path:
        tmp = self.dir / f"tmp.{step}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        arrays = {}
        for key, leaf in _flatten_with_paths(state):
            arr = np.asarray(jax.device_get(leaf))
            logical = str(arr.dtype)
            if logical in _VIEW_DTYPES:
                arr = arr.view(_VIEW_DTYPES[logical])
            arrays[key] = arr
            manifest["leaves"].append(
                {"key": key, "shape": list(arr.shape), "dtype": logical})
        np.savez(tmp / "arrays.npz",
                 **{k.replace("/", "__"): v for k, v in arrays.items()})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():                # re-save of the same step
            shutil.rmtree(final)
        os.replace(tmp, final)            # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---- read --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None) -> Tuple[Dict, Dict]:
        """Restore into the structure of ``template``; if ``shardings`` (a
        matching pytree of NamedSharding/PartitionSpec under an active mesh)
        is given, leaves are placed sharded — this is the elastic-restart
        path (mesh may differ from the saving run)."""
        path = self.dir / f"step_{step:010d}"
        data = np.load(path / "arrays.npz")
        manifest = json.loads((path / "manifest.json").read_text())
        logical = {l["key"]: l["dtype"] for l in manifest["leaves"]}
        arrays = {}
        for k in data.files:
            key = k.replace("__", "/")
            arr = data[k]
            ldt = logical.get(key, str(arr.dtype))
            if ldt in _VIEW_DTYPES:
                arr = arr.view(ml_dtypes.bfloat16)
            arrays[key] = arr

        leaves_t = _flatten_with_paths(template)
        shard_leaves = (_flatten_with_paths(shardings)
                        if shardings is not None else None)
        restored = []
        for i, (key, leaf) in enumerate(leaves_t):
            arr = arrays[key]
            want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            arr = arr.astype(want_dtype)
            if shard_leaves is not None:
                restored.append(jax.device_put(arr, shard_leaves[i][1]))
            else:
                restored.append(jnp.asarray(arr))
        treedef = jax.tree_util.tree_structure(template)
        return (jax.tree_util.tree_unflatten(treedef, restored),
                manifest["extra"])

    # ---- preemption hook -----------------------------------------------------
    def save_on_signal(self, get_state: Callable[[], Tuple[int, Dict, Dict]],
                       signals=(signal.SIGTERM,)) -> None:
        def handler(signum, frame):
            step, state, extra = get_state()
            self.save(step, state, extra)
            raise SystemExit(128 + signum)
        for s in signals:
            signal.signal(s, handler)
