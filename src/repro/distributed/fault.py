"""Fault tolerance: step watchdog (hang/straggler detection) and the
restart contract.

At 1000+-node scale the failure modes are (a) hard node loss — the job
dies and the launcher restarts it; recovery = CheckpointManager.restore on
a possibly different mesh (elastic); (b) soft hangs / stragglers — a host
stalls inside a collective, everyone blocks.  The watchdog detects (b):
the train loop beats once per step; if no beat arrives within ``timeout``
the callback fires (default: checkpoint + abort, converting a silent hang
into a restartable hard failure).  Straggler *mitigation* beyond
detection (e.g. backup workers) is a scheduler-level concern documented in
DESIGN.md; detection + fast restart is what the framework owns.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class Watchdog:
    """``_fired`` latches once per stall so a hung callback isn't invoked
    every poll tick, and ``beat()`` re-arms it — a second stall later in
    the same run fires again instead of being silently absorbed by the
    first.  The latch and the stop flag are read/written under a lock so
    ``stop()`` can never race ``_run`` into firing after shutdown."""

    def __init__(self, timeout_s: float,
                 on_stall: Callable[[float], None]):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._fired = False
        self._thread: Optional[threading.Thread] = None

    def beat(self) -> None:
        with self._lock:
            self._last = time.monotonic()
            self._fired = False          # re-arm: detect the *next* stall too

    def _run(self) -> None:
        while not self._stop.wait(self.timeout_s / 10):
            with self._lock:
                idle = time.monotonic() - self._last
                fire = (idle > self.timeout_s and not self._fired
                        and not self._stop.is_set())
                if fire:
                    self._fired = True
            if fire:
                self.on_stall(idle)

    def start(self) -> "Watchdog":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        with self._lock:
            self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)

    def __enter__(self) -> "Watchdog":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
