"""Fault tolerance: step watchdog (hang/straggler detection) and the
restart contract.

At 1000+-node scale the failure modes are (a) hard node loss — the job
dies and the launcher restarts it; recovery = CheckpointManager.restore on
a possibly different mesh (elastic); (b) soft hangs / stragglers — a host
stalls inside a collective, everyone blocks.  The watchdog detects (b):
the train loop beats once per step; if no beat arrives within ``timeout``
the callback fires (default: checkpoint + abort, converting a silent hang
into a restartable hard failure).  Straggler *mitigation* beyond
detection (e.g. backup workers) is a scheduler-level concern documented in
DESIGN.md; detection + fast restart is what the framework owns.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class Watchdog:
    def __init__(self, timeout_s: float,
                 on_stall: Callable[[float], None]):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._fired = False
        self._thread: Optional[threading.Thread] = None

    def beat(self) -> None:
        self._last = time.monotonic()

    def _run(self) -> None:
        while not self._stop.wait(self.timeout_s / 10):
            idle = time.monotonic() - self._last
            if idle > self.timeout_s and not self._fired:
                self._fired = True
                self.on_stall(idle)

    def start(self) -> "Watchdog":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)

    def __enter__(self) -> "Watchdog":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
