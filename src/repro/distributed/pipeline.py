"""GPipe-style pipeline parallelism via ``shard_map`` + ``lax.ppermute``.

Layers are divided into S contiguous stages; stage s holds the (stacked)
params of its layer group, sharded over the ``stage`` mesh axis.  The
global batch is split into M microbatches; a software pipeline of
M + S - 1 ticks streams activations stage-to-stage with ``ppermute``
(which JAX transposes correctly, so ``jax.grad`` through the pipelined
forward yields the 1F1B-equivalent backward schedule under XLA's
scheduler).  Bubble fraction = (S-1)/(M+S-1), reported by
``bubble_fraction`` so configs can budget M accordingly.

This is the depth-wise scaling path for models whose layer count outgrows
the FSDPxTP mesh; the production dry-run mesh uses FSDPxTP (right regime
for <=30B dense models), and the pipeline runtime is exercised by
tests/test_pipeline_parallel.py on a forced-device mesh.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable, stage_params, x, n_micro: int,
                   mesh: Mesh, axis: str = "stage"):
    """Run ``stage_fn(params_s, h) -> h`` over S pipeline stages.

    stage_params: pytree with leading dim S on every leaf (stage-stacked).
    x: (batch, ...) global input; batch must divide by n_micro.
    Returns y: (batch, ...) output of the final stage.
    """
    n_stages = mesh.shape[axis]
    batch = x.shape[0]
    assert batch % n_micro == 0
    mb = batch // n_micro

    def per_stage(params, xs):
        # params: (1, ...) local stage slice; xs: full input (replicated)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        x_mb = xs.reshape(n_micro, mb, *xs.shape[1:])
        ticks = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            h_prev, out_buf = carry
            # stage 0 ingests microbatch t (clamped); others take the wire
            feed = jax.lax.dynamic_index_in_dim(
                x_mb, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            h_in = jnp.where(idx == 0, feed, h_prev)
            h_out = stage_fn(params, h_in)
            # last stage banks its result at microbatch slot t-(S-1)
            slot = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (idx == n_stages - 1) & (t >= n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(out_buf, slot, keepdims=False)
            upd = jnp.where(valid, h_out, cur)
            out_buf = jax.lax.dynamic_update_index_in_dim(out_buf, upd, slot,
                                                          axis=0)
            # ship to the next stage
            h_next = jax.lax.ppermute(h_out, axis, perm)
            return (h_next, out_buf), None

        h0 = jnp.zeros((mb, *xs.shape[1:]), xs.dtype)
        buf0 = jnp.zeros((n_micro, mb, *xs.shape[1:]), xs.dtype)
        (h_last, out_buf), _ = jax.lax.scan(tick, (h0, buf0),
                                            jnp.arange(ticks))
        # broadcast the final stage's buffer to every stage (masked psum —
        # ppermute cannot fan out one source to many destinations)
        masked = jnp.where(idx == n_stages - 1, out_buf,
                           jnp.zeros_like(out_buf))
        out = jax.lax.psum(masked, axis)
        return out.reshape(batch, *xs.shape[1:])

    spec_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x)
