"""Attention: GQA/MQA/MHA with rotary, qk-norm, sliding windows, cross
attention, KV caching, and a memory-bounded chunked (online-softmax)
implementation for long sequences.

The chunked path scans KV blocks with a running (max, denominator)
pair — the pure-jnp analogue of the Pallas flash kernel in
``repro.kernels.flash_attention`` (which is the TPU-target implementation;
this one is backend-agnostic and is what the dry-run lowers)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, ParamDef, Rules, shard
from .layers import rms_head_norm, rope

NEG_INF = -1e30


def attn_defs(cfg: ModelConfig, lead: Tuple[int, ...] = (),
              cross: bool = False) -> Dict:
    la = ("layers",) * len(lead)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out = {
        "wq": ParamDef(lead + (d, h, hd), la + ("embed", "heads", None)),
        "wk": ParamDef(lead + (d, kv, hd), la + ("embed", "kv_heads", None)),
        "wv": ParamDef(lead + (d, kv, hd), la + ("embed", "kv_heads", None)),
        "wo": ParamDef(lead + (h, hd, d), la + ("heads", None, "embed")),
    }
    if cfg.qk_norm and not cross:
        out["q_norm"] = ParamDef(lead + (hd,), la + (None,), init="ones")
        out["k_norm"] = ParamDef(lead + (hd,), la + (None,), init="ones")
    return out


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window) -> jax.Array:
    """(q, k) additive bias: 0 where attending is allowed, NEG_INF else.

    ``window`` may be a python int or traced scalar; 0 disables windowing.
    Negative ``k_pos`` marks invalid (unwritten cache) slots."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = dk >= 0
    if causal:
        ok &= dk <= dq
    window = jnp.asarray(window)
    ok &= jnp.where(window > 0, dk > dq - window, True)
    return jnp.where(ok, 0.0, NEG_INF)


def _dense_attention(q, k, v, bias) -> jax.Array:
    """q: (B,S,H,D); k,v: (B,T,KV,D); bias: (S,T) additive."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, s, kvh, groups, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(d) + bias
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, d)


def attention(cfg: ModelConfig, p: Dict, x: jax.Array,
              rules: Optional[Rules],
              kv_x: Optional[jax.Array] = None,
              q_offset: jax.Array | int = 0,
              cache: Optional[Dict] = None,
              window: Optional[jax.Array] = None,
              causal: Optional[bool] = None,
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """Self- or cross-attention with optional KV cache.

    * training / prefill: ``cache`` None or empty -> keys from ``x`` itself
      (or ``kv_x`` for cross attention).
    * decode: ``cache`` = {'k','v','pos'} ring buffer; new KV appended at
      position ``pos`` and attention runs against the whole buffer.
    * ``window``: scalar (traced ok) sliding-window size; 0 = full.
    """
    b, s, _ = x.shape
    causal = cfg.causal if causal is None else causal
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"])
    q = shard(q, rules, "batch", "seq", "act_heads", None)
    k = shard(k, rules, "batch", "seq", "cache_heads", None)
    v = shard(v, rules, "batch", "seq", "cache_heads", None)

    if cfg.qk_norm and "q_norm" in p:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)

    if cache is not None:
        q_offset = cache["pos"]
    q_pos = q_offset + jnp.arange(s)
    if kv_x is None:
        k_pos_new = q_pos
        q = rope(q, jnp.broadcast_to(q_pos, (b, s)), cfg.rope_theta,
                 cfg.rope_fraction)
        k = rope(k, jnp.broadcast_to(k_pos_new, (b, s)), cfg.rope_theta,
                 cfg.rope_fraction)
    else:
        k_pos_new = jnp.arange(src.shape[1])

    new_cache = None
    if cache is not None:
        # append at pos (decode or staged prefill); int8 caches quantize on
        # write with per-(token, kv-head) dynamic scales stored alongside
        pos = cache["pos"]
        int8 = cache["k"].dtype == jnp.int8
        dus = jax.lax.dynamic_update_slice_in_dim
        if int8:
            def enc(x):
                scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                                keepdims=True) / 127.0
                scale = jnp.maximum(scale, 1e-8)
                q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                             -127, 127).astype(jnp.int8)
                return q, scale[..., 0].astype(jnp.float32)

            k8, ks = enc(k)
            v8, vs = enc(v)
            ck = dus(cache["k"], k8, pos, axis=1)
            cv = dus(cache["v"], v8, pos, axis=1)
            cks = dus(cache["k_scale"], ks, pos, axis=1)
            cvs = dus(cache["v_scale"], vs, pos, axis=1)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                         "pos": pos + s}
            k = (ck.astype(cfg.dtype)
                 * cks[..., None].astype(cfg.dtype))
            v = (cv.astype(cfg.dtype)
                 * cvs[..., None].astype(cfg.dtype))
        else:
            ck = dus(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            cv = dus(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            new_cache = {"k": ck, "v": cv, "pos": pos + s}
            k, v = ck, cv
        t = ck.shape[1]
        k_pos = jnp.arange(t)
        valid = k_pos < (pos + s)
        k_pos = jnp.where(valid, k_pos, -10 ** 9)
    else:
        k_pos = k_pos_new
        k_pos = jnp.asarray(k_pos)

    w = window if window is not None else jnp.asarray(cfg.window)
    t = k.shape[1]
    if s == 1 or (s <= cfg.dense_attn_max_seq and t <= cfg.dense_attn_max_seq):
        bias = _mask_bias(q_pos, k_pos, causal, w)
        out = _dense_attention(q, k, v, bias)
    else:
        out = _chunked_attention_dynwin(q, k, v, q_pos, k_pos, causal, w,
                                        cfg.attn_block)
    out = shard(out, rules, "batch", "seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(y, rules, "batch", "seq", "act_embed"), new_cache


def _chunked_attention_dynwin(q, k, v, q_pos, k_pos, causal, window, block):
    """Chunked attention where ``window`` may be a traced scalar."""
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    groups = h // kvh
    nblk = -(-t // block)
    pad = nblk * block - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-10 ** 9)
    kb = k.reshape(b, nblk, block, kvh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block, kvh, d).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nblk, block)
    qg = q.reshape(b, s, kvh, groups, d)
    scale = 1.0 / np.sqrt(d)

    def bias_fn(pc):
        dq = q_pos[:, None]
        dk = pc[None, :]
        ok = jnp.ones((s, pc.shape[0]), bool)
        if causal:
            ok &= dk <= dq
        ok &= jnp.where(window > 0, dk > dq - window, True)
        ok &= dk >= 0
        return jnp.where(ok, 0.0, NEG_INF)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, kc).astype(jnp.float32)
        logits = logits * scale + bias_fn(pc)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, groups, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, groups, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, groups, s, d), jnp.float32)
    # checkpoint each KV-block step: the backward pass then saves only the
    # O(S*D) running carries and recomputes the O(S*block) probability
    # matrices per block — the flash-attention memory contract
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kb, vb, pb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
    return out.astype(q.dtype)


def attend_precomputed(cfg: ModelConfig, p: Dict, x: jax.Array,
                       k: jax.Array, v: jax.Array,
                       rules: Optional[Rules]) -> jax.Array:
    """Cross-attention against precomputed (encoder) K/V — no append, no
    mask (every encoder position is valid), no rope."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = shard(q, rules, "batch", "seq", "act_heads", None)
    t = k.shape[1]
    bias = jnp.zeros((x.shape[1], t), jnp.float32)
    out = _dense_attention(q, k, v, bias)
    out = shard(out, rules, "batch", "seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(y, rules, "batch", "seq", "act_embed")


def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int,
                  max_len: int, rules: Optional[Rules] = None) -> Dict:
    kv, hd = cfg.n_kv_heads, cfg.hd
    shape = (n_layers, batch, max_len, kv, hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def kv_cache_specs(cfg: ModelConfig, n_layers: int, batch: int,
                   max_len: int) -> Dict:
    kv, hd = cfg.n_kv_heads, cfg.hd
    shape = (n_layers, batch, max_len, kv, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, cfg.dtype),
        "v": jax.ShapeDtypeStruct(shape, cfg.dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
