"""Shared neural layers: norms, MLPs, rotary embeddings, embedding/head."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamDef, Rules, shard


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig, d: int, lead: Tuple[int, ...] = ()) -> Dict:
    lead_axes = ("layers",) * len(lead)
    out = {"scale": ParamDef(lead + (d,), lead_axes + (None,), init="ones")}
    if cfg.norm_type == "layernorm":
        out["bias"] = ParamDef(lead + (d,), lead_axes + (None,), init="zeros")
    return out


def apply_norm(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array) -> jax.Array:
    """Per-head RMS norm over the last (head_dim) axis (qk-norm)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, lead: Tuple[int, ...] = ()) -> Dict:
    la = ("layers",) * len(lead)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi": ParamDef(lead + (d, f), la + ("embed", "ff")),
        "wg": ParamDef(lead + (d, f), la + ("embed", "ff")),
        "wo": ParamDef(lead + (f, d), la + ("ff", "embed")),
    }


def _act(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


def apply_mlp(cfg: ModelConfig, p: Dict, x: jax.Array,
              rules: Optional[Rules]) -> jax.Array:
    h = _act(cfg, x @ p["wg"]) * (x @ p["wi"])
    h = shard(h, rules, "batch", "seq", "act_ff")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Rotary embeddings (partial-fraction support)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions: (B, S) -> angles (B, S, 1, half), broadcast over heads
    ang = positions.astype(jnp.float32)[..., None, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> Dict:
    out = {"embedding": ParamDef((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        out["head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                               ("embed", "vocab"))
    return out


def embed_tokens(p: Dict, tokens: jax.Array, rules: Optional[Rules],
                 dtype) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0).astype(dtype)
    return shard(x, rules, "batch", "seq", "act_embed")


def lm_logits(p: Dict, x: jax.Array, rules: Optional[Rules]) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["embedding"].T
    logits = (x @ w).astype(jnp.float32)
    return shard(logits, rules, "batch", "seq", "vocab")
