"""Model-stack foundations: config, parameter declaration, sharding rules.

Parameters are declared once as ``ParamDef`` trees (shape + logical axes +
initializer); the same tree materializes to
  * initialized arrays           (``init_params``)
  * ``jax.ShapeDtypeStruct``s    (``abstract_params`` — dry-run)
  * ``PartitionSpec``s           (``param_specs`` — pjit in/out shardings)

Logical axis names are mapped to mesh axes through a ``Rules`` dict
(MaxText-style).  The production default is FSDP over ``data`` x tensor
parallelism over ``model``; decode/long-context cells override activation
rules (e.g. KV-cache sequence over ``data`` when batch < mesh data size).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # norms / activations
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    qk_norm: bool = False
    # rotary
    rope_theta: float = 1e4
    rope_fraction: float = 1.0     # partial rotary (stablelm: 0.25)
    # attention pattern
    window: int = 0                # sliding-window size (0 = full attention)
    # per-layer pattern of window usage: 'local'/'global'; empty -> all global
    attn_pattern: Tuple[str, ...] = ()
    causal: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # MoE on layers where (i % moe_every)==moe_offset
    moe_offset: int = 0
    shared_expert: bool = False
    moe_block: int = 1024          # token block size for dispatch
    moe_capacity: float = 1.25     # expert capacity factor (tokens dropped
                                   # beyond cap — standard capacity MoE)
    moe_dispatch: str = "onehot"   # onehot (GEMM dispatch) | scatter
    # mixer pattern: repeating tuple over layers; entries in
    # {'attn','mamba2','rglru'}
    block_pattern: Tuple[str, ...] = ("attn",)
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # RG-LRU
    rnn_width: int = 0             # 0 -> d_model
    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed encoder length (whisper: 1500)
    learned_pos: int = 0           # learned position table size (0 = rope)
    # vlm stub
    n_patches: int = 0
    # misc
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_block: int = 1024         # kv block for chunked attention
    dense_attn_max_seq: int = 4096  # use dense attention at/below this length
    ce_chunk: int = 0              # seq-chunked cross-entropy (0 = off):
                                   # only (B, chunk, V) logits materialize
    cache_dtype: Any = None        # KV-cache storage dtype (None = dtype);
                                   # jnp.int8 enables quantized KV serving
    kv_quant_scale: float = 1 / 32.  # symmetric int8 KV quantization scale
    remat_policy: str = "full"     # full | save_dots (selective remat)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern or ("attn",)

    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def is_moe_layer(self, i: int) -> bool:
        return (self.n_experts > 0
                and (i % self.moe_every) == self.moe_offset)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

Rules = Dict[str, Any]   # logical axis -> mesh axis (str | tuple | None)

# Production default: FSDP('data') x TP('model'); batch over data (+pod).
PROD_RULES: Rules = {
    # parameter axes
    "embed": "data",          # FSDP axis of 2D weights
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "vocab": "model",
    "experts": "data",
    "expert_ff": "model",
    "rnn": "model",
    "ssm_heads": "model",
    "conv": None,
    "layers": None,
    "pos": None,
    # activation axes
    "batch": "data",
    "seq": None,
    # residual stream between layers (the remat-saved carry): sequence-
    # sharded over the tensor axis (Megatron-style sequence parallelism) —
    # XLA inserts the gather/scatter at the norm <-> qkv/ff boundaries
    "seq_resid": "model",
    "act_embed": None,
    "act_heads": "model",
    "act_ff": "model",
    "cache_seq": None,
    "cache_heads": "model",
}


def multipod(rules: Rules) -> Rules:
    """Extend rules with a leading 'pod' pure-DP axis."""
    r = dict(rules)
    r["batch"] = ("pod", "data")
    return r


def with_axis_sizes(rules: Rules, mesh) -> Rules:
    """Attach mesh axis sizes so spec resolution can apply the
    divisibility fallback (a dim not divisible by its mesh axis product is
    left unsharded — the standard production behavior for e.g. 5 KV heads
    on a 16-way tensor axis)."""
    r = dict(rules)
    r["_axis_sizes"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    return r


def _axis_product(rules: Rules, axis) -> int:
    sizes = rules.get("_axis_sizes")
    if not sizes or axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def _resolve(rules: Rules, axis, dim: Optional[int]):
    """Logical axis -> mesh axis, dropped if ``dim`` is not divisible."""
    phys = rules.get(axis) if axis else None
    if phys is None:
        return None
    if dim is not None and "_axis_sizes" in rules:
        if dim % _axis_product(rules, phys) != 0:
            return None
    return phys


def spec(rules: Optional[Rules], *axes: Optional[str],
         shape: Optional[Tuple[int, ...]] = None) -> P:
    if rules is None:
        return P()
    dims = shape if shape is not None else (None,) * len(axes)
    out, used = [], set()
    for a, d in zip(axes, dims):
        phys = _resolve(rules, a, d)
        # a mesh axis may appear at most once per spec: first dim wins
        flat = phys if isinstance(phys, tuple) else (phys,)
        if phys is not None and any(f in used for f in flat):
            phys = None
        if phys is not None:
            used.update(flat)
        out.append(phys)
    return P(*out)


def shard(x: jax.Array, rules: Optional[Rules], *axes: Optional[str]):
    """with_sharding_constraint by logical axes; no-op without rules."""
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, spec(rules, *axes, shape=x.shape))


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 1.0            # stddev multiplier for 'normal'

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_params(rng: jax.Array, defs, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(rng, len(leaves))
    arrs = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            arrs.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            arrs.append(jnp.ones(d.shape, dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / np.sqrt(max(1, fan_in))
            arrs.append((jax.random.normal(k, d.shape, jnp.float32)
                         * std).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(defs, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def param_specs(defs, rules: Optional[Rules]):
    def to_spec(d: ParamDef) -> P:
        if rules is None:
            return P()
        return spec(rules, *d.axes, shape=d.shape)
    return jax.tree_util.tree_map(
        to_spec, defs, is_leaf=lambda x: isinstance(x, ParamDef))
