"""Mixture-of-Experts layer: top-k router + capacity-bounded expert FFNs.

Dispatch uses a *blocked* one-hot capacity formulation: tokens are processed
in blocks of ``cfg.moe_block``; per block each expert takes at most
C = ceil(k * block / E * capacity_factor) tokens.  The dispatch tensor is
(block, E, C) — bounded memory regardless of sequence length — and the
expert matmuls are dense einsums over the (E, C, d) dispatched activations,
which XLA shards cleanly with experts on the ``experts`` mesh axis (the
token -> expert exchange lowers to all-to-all/all-gather on that axis).

Overflowed tokens are dropped (standard capacity-based MoE); the router
keeps an auxiliary load-balancing loss (Switch-style).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamDef, Rules, shard
from .layers import _act

def moe_defs(cfg: ModelConfig, lead: Tuple[int, ...] = ()) -> Dict:
    la = ("layers",) * len(lead)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    # expert weights shard experts over the FSDP ('data') axis and the
    # expert FF dim over the tensor axis; the embed dim stays unsharded
    # (it cannot reuse 'data' — one mesh axis per spec position)
    out = {
        "router": ParamDef(lead + (d, e), la + ("embed", None)),
        "wi": ParamDef(lead + (e, d, f), la + ("experts", None, "expert_ff")),
        "wg": ParamDef(lead + (e, d, f), la + ("experts", None, "expert_ff")),
        "wo": ParamDef(lead + (e, f, d), la + ("experts", "expert_ff", None)),
    }
    if cfg.shared_expert:
        out["shared_wi"] = ParamDef(lead + (d, f), la + ("embed", "ff"))
        out["shared_wg"] = ParamDef(lead + (d, f), la + ("embed", "ff"))
        out["shared_wo"] = ParamDef(lead + (f, d), la + ("ff", "embed"))
    return out


def _capacity(cfg: ModelConfig) -> int:
    c = int(cfg.top_k * cfg.moe_block / cfg.n_experts * cfg.moe_capacity)
    return max(4, -(-c // 4) * 4)


def apply_moe(cfg: ModelConfig, p: Dict, x: jax.Array,
              rules: Optional[Rules]) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    blk = min(cfg.moe_block, b * s)
    cap = _capacity(cfg)
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    pad = (-n) % blk
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    nblk = tokens.shape[0] // blk
    tb = tokens.reshape(nblk, blk, d)

    router = p["router"]

    def block_fn(xt: jax.Array) -> Tuple[jax.Array, jax.Array]:
        # xt: (blk, d)
        logits = (xt @ router).astype(jnp.float32)            # (blk, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, k)              # (blk, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        # position of each (token, choice) within its expert queue
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)      # (blk, k, E)
        flat = onehot.reshape(blk * k, e)
        ranks = jnp.cumsum(flat, axis=0) - flat               # (blk*k, E)
        rank = (ranks * flat).sum(-1).reshape(blk, k)
        keep = rank < cap
        if cfg.moe_dispatch == "scatter":
            # gather/scatter dispatch: ~zero FLOPs, O(tokens*d) traffic —
            # the beyond-paper optimization over the GEMM-dispatch baseline
            pos = idx * cap + rank                            # (blk, k)
            pos_safe = jnp.where(keep, pos, e * cap)          # overflow slot
            xe_flat = jnp.zeros((e * cap + 1, xt.shape[-1]), xt.dtype)
            xe_flat = xe_flat.at[pos_safe.reshape(-1)].add(
                jnp.repeat(xt, k, axis=0))
            xe = xe_flat[:e * cap].reshape(e, cap, -1)        # (E, C, d)
            xe = shard(xe, rules, "experts", None, None)
            h = _act(cfg, jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
                * jnp.einsum("ecd,edf->ecf", xe, p["wi"])
            h = shard(h, rules, "experts", None, "act_ff")
            ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])       # (E, C, d)
            ye_flat = jnp.concatenate(
                [ye.reshape(e * cap, -1),
                 jnp.zeros((1, xt.shape[-1]), ye.dtype)], axis=0)
            taken = ye_flat[pos_safe]                         # (blk, k, d)
            y = jnp.sum(taken * (gate_vals[..., None] * keep[..., None]
                                 ).astype(taken.dtype), axis=1)
        else:
            # one-hot GEMM dispatch (baseline; maps onto the paper's
            # systolic-GEMM cost model but pays O(blk * E * C * d) FLOPs)
            oh_e = jax.nn.one_hot(idx, e, dtype=xt.dtype) * keep[..., None]
            oh_c = jax.nn.one_hot(jnp.where(keep, rank, cap), cap + 1,
                                  dtype=xt.dtype)[..., :cap]  # (blk, k, C)
            disp = jnp.einsum("bke,bkc->bec", oh_e, oh_c)     # (blk, E, C)
            xe = jnp.einsum("bec,bd->ecd", disp, xt)          # (E, C, d)
            xe = shard(xe, rules, "experts", None, None)
            h = _act(cfg, jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
                * jnp.einsum("ecd,edf->ecf", xe, p["wi"])
            h = shard(h, rules, "experts", None, "act_ff")
            ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])       # (E, C, d)
            combine = jnp.einsum("bke,bkc->bec",
                                 oh_e * gate_vals[..., None].astype(xt.dtype),
                                 oh_c)
            y = jnp.einsum("bec,ecd->bd", combine, ye)
        # Switch aux loss: E * sum_e (frac_tokens_e * mean_prob_e)
        frac = onehot.sum(1).mean(0).astype(jnp.float32)      # (E,)
        aux = e * jnp.sum(frac * probs.mean(0))
        return y, aux

    ys, auxs = jax.lax.map(block_fn, tb)
    y = ys.reshape(-1, d)[:n].reshape(b, s, d)
    if cfg.shared_expert:
        h = _act(cfg, x @ p["shared_wg"]) * (x @ p["shared_wi"])
        y = y + h @ p["shared_wo"]
    return y, auxs.mean()
