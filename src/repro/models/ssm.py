"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Training path: chunked SSD — within-chunk quadratic (attention-like) term
plus an inter-chunk linear recurrence over the (H, P, N) state, implemented
with a ``lax.scan`` over chunks.  Decode path: single-step recurrence over
the cached state.  The chunk matmuls are GEMM-shaped (the systolic/MXU case
of the paper's model); the recurrence is the non-Conv/VPU case.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamDef, Rules, shard


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_state


def ssm_defs(cfg: ModelConfig, lead: Tuple[int, ...] = ()) -> Dict:
    la = ("layers",) * len(lead)
    d = cfg.d_model
    di, h, n = ssm_dims(cfg)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": ParamDef(lead + (d, 2 * di + 2 * n + h),
                         la + ("embed", "rnn")),
        "conv_w": ParamDef(lead + (cfg.conv_width, di + 2 * n),
                           la + ("conv", "rnn"), init="normal", scale=1.0),
        "a_log": ParamDef(lead + (h,), la + ("ssm_heads",), init="zeros"),
        "dt_bias": ParamDef(lead + (h,), la + ("ssm_heads",), init="zeros"),
        "d_skip": ParamDef(lead + (h,), la + ("ssm_heads",), init="ones"),
        "norm_scale": ParamDef(lead + (di,), la + ("rnn",), init="ones"),
        "w_out": ParamDef(lead + (di, d), la + ("rnn", "embed")),
    }


def _split(cfg: ModelConfig, proj: jax.Array):
    di, h, n = ssm_dims(cfg)
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    bb = proj[..., 2 * di:2 * di + n]
    cc = proj[..., 2 * di + n:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, x, bb, cc, dt


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv, width W. x: (B,S,C), w: (W,C).
    Returns (y, new_state) with state = last W-1 inputs."""
    wlen = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(wlen))
    new_state = xp[:, -(wlen - 1):, :] if wlen > 1 else None
    return jax.nn.silu(y), new_state


def _gated_rmsnorm(scale: jax.Array, x: jax.Array, z: jax.Array) -> jax.Array:
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(xh, dt, a_log, bb, cc, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    xh: (B,S,H,P); dt: (B,S,H) post-softplus; a_log: (H,) (A = -exp(a_log));
    bb, cc: (B,S,N) (single group, broadcast over heads).
    Returns y: (B,S,H,P) and final state (B,H,P,N).
    """
    b, s, h, p = xh.shape
    n = bb.shape[-1]
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
    t = xh.shape[1]
    nc = t // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                   # (H,)
    # per-step log decay: (B, T, H)
    la = dt.astype(jnp.float32) * a
    xc = xh.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    lac = la.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    bc = bb.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    ccn = cc.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    def chunk_fn(state, blk):
        xk, dtk, lak, bk, ck = blk                  # (B,chunk,...) each
        cum = jnp.cumsum(lak, axis=1)               # (B,L,H)
        # intra-chunk "attention": M[i,j] = exp(cum_i - cum_j) * (i >= j)
        diff = cum[:, :, None, :] - cum[:, None, :, :]        # (B,L,L,H)
        ii = jnp.arange(chunk)
        causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
        m = jnp.where(causal, jnp.exp(diff), 0.0)
        g = jnp.einsum("bln,bmn->blm", ck.astype(jnp.float32),
                       bk.astype(jnp.float32))                # (B,L,L)
        w = m * g[..., None]                                  # (B,L,L,H)
        xdt = xk.astype(jnp.float32) * dtk[..., None].astype(jnp.float32)
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, xdt)
        # inter-chunk: contribution of incoming state
        y_state = jnp.einsum("bln,blh,bhpn->blhp",
                             ck.astype(jnp.float32), jnp.exp(cum), state)
        # state update
        tail = cum[:, -1:, :] - cum                           # (B,L,H)
        sx = jnp.einsum("bln,blh,blhp->bhpn", bk.astype(jnp.float32),
                        jnp.exp(tail) * dtk.astype(jnp.float32),
                        xk.astype(jnp.float32))
        new_state = state * jnp.exp(cum[:, -1, :])[..., None, None] + sx
        return new_state, (y_intra + y_state)

    state0 = (jnp.zeros((b, h, p, n), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))
    final, ys = jax.lax.scan(chunk_fn, state0, (xc, dtc, lac, bc, ccn))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)[:, :s]
    return y, final


def apply_ssm(cfg: ModelConfig, p: Dict, u: jax.Array,
              rules: Optional[Rules],
              state: Optional[Dict] = None,
              chunk: int = 256) -> Tuple[jax.Array, Optional[Dict]]:
    """u: (B,S,d). state (decode): {'ssm': (B,H,P,N), 'conv': (B,W-1,C)}."""
    b, s, _ = u.shape
    di, h, n = ssm_dims(cfg)
    proj = u @ p["w_in"]
    z, x, bb, cc, dt = _split(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xbc = jnp.concatenate([x, bb, cc], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_state)
    x, bb, cc = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]
    xh = x.reshape(b, s, h, cfg.ssm_head_dim)
    xh = shard(xh, rules, "batch", "seq", "ssm_heads", None)

    init = None if state is None else state["ssm"]
    if s == 1 and state is not None:
        # single-step recurrence (decode)
        a = -jnp.exp(p["a_log"].astype(jnp.float32))
        dt1 = dt[:, 0]                                        # (B,H)
        decay = jnp.exp(dt1 * a)                              # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt1,
                         xh[:, 0].astype(jnp.float32),
                         bb[:, 0].astype(jnp.float32))
        new_state = init * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cc[:, 0].astype(jnp.float32),
                       new_state)[:, None]
        final = new_state
    else:
        y, final = ssd_chunked(xh, dt, p["a_log"], bb, cc, chunk, init)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(
        jnp.float32)[:, None]
    y = y.reshape(b, s, di).astype(u.dtype)
    y = _gated_rmsnorm(p["norm_scale"], y, z)
    out = y @ p["w_out"]
    new_state = None
    if state is not None:
        new_state = {"ssm": final, "conv": new_conv}
    return shard(out, rules, "batch", "seq", "act_embed"), new_state


def init_ssm_state(cfg: ModelConfig, n_layers: int, batch: int) -> Dict:
    di, h, n = ssm_dims(cfg)
    return {
        "ssm": jnp.zeros((n_layers, batch, h, cfg.ssm_head_dim, n),
                         jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1, di + 2 * n),
                          jnp.float32),
    }
