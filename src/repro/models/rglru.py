"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

  r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
  a_t = exp(-c * softplus(Lambda) * r_t)            (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over the per-step affine maps
(h -> a*h + b composes associatively), giving O(log S) depth; decode is the
single-step recurrence on the cached state.  The block follows Griffin's
recurrent block: linear in, short causal conv, RG-LRU, gated output.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamDef, Rules, shard
from .ssm import _causal_conv

C_FACTOR = 8.0


def rglru_defs(cfg: ModelConfig, lead: Tuple[int, ...] = ()) -> Dict:
    la = ("layers",) * len(lead)
    d = cfg.d_model
    r = cfg.rnn_width or d
    return {
        "w_x": ParamDef(lead + (d, r), la + ("embed", "rnn")),
        "w_gate": ParamDef(lead + (d, r), la + ("embed", "rnn")),
        "conv_w": ParamDef(lead + (cfg.conv_width, r), la + ("conv", "rnn"),
                           init="normal", scale=1.0),
        "w_r": ParamDef(lead + (r, r), la + ("rnn", None)),
        "w_i": ParamDef(lead + (r, r), la + ("rnn", None)),
        "lam": ParamDef(lead + (r,), la + ("rnn",), init="ones"),
        "w_out": ParamDef(lead + (r, d), la + ("rnn", "embed")),
    }


def _rglru_scan(x: jax.Array, a: jax.Array,
                h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """x, a: (B,S,R) f32. h_t = a_t h_{t-1} + x_t via associative scan."""
    if h0 is not None:
        # fold initial state into the first step
        x = x.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, x), axis=1)
    return hh, hh[:, -1]


def apply_rglru(cfg: ModelConfig, p: Dict, u: jax.Array,
                rules: Optional[Rules],
                state: Optional[Dict] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """u: (B,S,d); state (decode): {'h': (B,R), 'conv': (B,W-1,R)}."""
    b, s, _ = u.shape
    x = u @ p["w_x"]
    gate = jax.nn.gelu(u @ p["w_gate"])
    conv_state = None if state is None else state["conv"]
    x, new_conv = _causal_conv(x, p["conv_w"], conv_state)
    x = shard(x, rules, "batch", "seq", "rnn")

    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    inp = beta * (i * xf)

    h0 = None if state is None else state["h"]
    if s == 1 and state is not None:
        h = a[:, 0] * h0 + inp[:, 0]
        hh = h[:, None]
        h_last = h
    else:
        hh, h_last = _rglru_scan(inp, a, h0)
    y = (hh.astype(u.dtype) * gate) @ p["w_out"]
    new_state = None
    if state is not None:
        new_state = {"h": h_last, "conv": new_conv}
    return shard(y, rules, "batch", "seq", "act_embed"), new_state


def init_rglru_state(cfg: ModelConfig, n_layers: int, batch: int) -> Dict:
    r = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((n_layers, batch, r), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1, r),
                          jnp.float32),
    }
