"""Modality frontend STUBS (per the assignment: ``[audio]``/``[vlm]``
entries specify the transformer backbone only; ``input_specs()`` provides
precomputed frame/patch embeddings).

* whisper-tiny: the conv1d mel frontend is stubbed — the model consumes
  precomputed frame embeddings (batch, encoder_seq=1500, d_model).
* pixtral-12b: the Pixtral ViT is stubbed — the model consumes precomputed
  patch embeddings (batch, n_patches, d_model) prepended to the token
  stream (early fusion).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig


def frontend_input_specs(cfg: ModelConfig, batch: int) -> Dict:
    """Extra abstract inputs the stubbed frontends inject."""
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.encoder_layers > 0:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.n_patches > 0:
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), cfg.dtype)
    return out


def synth_frontend_inputs(cfg: ModelConfig, batch: int,
                          rng: Optional[jax.Array] = None) -> Dict:
    """Concrete synthetic embeddings for smoke tests/examples."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out: Dict[str, jax.Array] = {}
    if cfg.encoder_layers > 0:
        out["frames"] = jax.random.normal(
            rng, (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype) * 0.02
    if cfg.n_patches > 0:
        out["patches"] = jax.random.normal(
            rng, (batch, cfg.n_patches, cfg.d_model), cfg.dtype) * 0.02
    return out
