"""Model front-ends: modality input stubs and the cost-model lowering.

Stubs (per the assignment: ``[audio]``/``[vlm]`` entries specify the
transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings):

* whisper-tiny: the conv1d mel frontend is stubbed — the model consumes
  precomputed frame embeddings (batch, encoder_seq=1500, d_model).
* pixtral-12b: the Pixtral ViT is stubbed — the model consumes precomputed
  patch embeddings (batch, n_patches, d_model) prepended to the token
  stream (early fusion).

Cost-model lowering (``lower_llm``): turns any registered ``ModelConfig``
— dense / MoE / SSM / RG-LRU-hybrid / enc-dec — into a flat
(GEMM + SIMD) layer graph the SimDIT DSE engine prices like any CNN:
attention/MLP/router/expert projections become ``GemmLayer``s on the
systolic array (k on the J rows, n on the K columns, m streamed — no
im2col), and softmax/norms/rotary/activations/short-convs/scans route
through the SIMD model exactly like the paper's non-conv ops.
``Workload(net="qwen3_0_6b")`` resolves through ``resolve_llm_config``,
so every downstream feature (objectives, refine, Pareto, phase
attribution, store, backends) prices LLM serving and training for free.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from ..core import layers as L
from ..core.layers import GemmLayer, SimdLayer, gemm
from .common import ModelConfig

LLM_SEQ_DEFAULT = 512

LlmLayer = Union[GemmLayer, SimdLayer]


def frontend_input_specs(cfg: ModelConfig, batch: int) -> Dict:
    """Extra abstract inputs the stubbed frontends inject."""
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.encoder_layers > 0:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.n_patches > 0:
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), cfg.dtype)
    return out


def synth_frontend_inputs(cfg: ModelConfig, batch: int,
                          rng: Optional[jax.Array] = None) -> Dict:
    """Concrete synthetic embeddings for smoke tests/examples."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out: Dict[str, jax.Array] = {}
    if cfg.encoder_layers > 0:
        out["frames"] = jax.random.normal(
            rng, (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype) * 0.02
    if cfg.n_patches > 0:
        out["patches"] = jax.random.normal(
            rng, (batch, cfg.n_patches, cfg.d_model), cfg.dtype) * 0.02
    return out


# ---------------------------------------------------------------------------
# Cost-model lowering: ModelConfig -> (GEMM + SIMD) layer graph
# ---------------------------------------------------------------------------

def llm_config_names() -> List[str]:
    """Every name ``resolve_llm_config`` accepts: the hyphenated arch ids
    plus their module-style (underscore) aliases."""
    from repro import configs
    return sorted(set(configs._MODULES) | set(configs._MODULES.values()))


def resolve_llm_config(name: str) -> Optional[ModelConfig]:
    """Resolve an arch id (``"gemma3-27b"``) or its module alias
    (``"gemma3_27b"``) to its ``ModelConfig``; ``None`` if unknown."""
    from repro import configs
    if name in configs._MODULES:
        return configs.get_config(name)
    inverse = {v: k for k, v in configs._MODULES.items()}
    if name in inverse:
        return configs.get_config(inverse[name])
    return None


def _norm(cfg: ModelConfig, name: str, tokens: int, d: int) -> SimdLayer:
    fn = L.layer_norm if cfg.norm_type == "layernorm" else L.rmsnorm
    return fn(name, tokens, d)


def _residual(name: str, tokens: int, d: int) -> SimdLayer:
    return L.tensor_add(name, tokens, 1, 1, d)


def _attention(cfg: ModelConfig, name: str, batch: int, s_q: int,
               s_kv: int, *, local: bool = False,
               cross: bool = False, rope: bool = True) -> List[LlmLayer]:
    """One attention sub-block: norm, q/k/v projections, (qk-norm,
    rotary), the two activation-activation GEMMs (scores, A·V) repeated
    per batch x query-head, softmax, out projection, residual.  GQA
    shares k/v across head groups (the k/v projections are
    ``n_kv_heads`` wide; the score/AV GEMM count stays batch x heads).
    ``local`` clips the attended length to the sliding window; ``cross``
    projects k/v from the (encoder) kv stream instead of the queries."""
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    t_q = batch * s_q
    t_kv = batch * s_kv if cross else t_q
    s_att = min(cfg.window, s_kv) if local and cfg.window else s_kv
    out: List[LlmLayer] = [
        _norm(cfg, f"{name}.norm", t_q, D),
        gemm(f"{name}.q", t_q, H * hd, D),
        gemm(f"{name}.k", t_kv, Hkv * hd, D),
        gemm(f"{name}.v", t_kv, Hkv * hd, D),
    ]
    if cfg.qk_norm:
        out.append(L.rmsnorm(f"{name}.qnorm", t_q * H, hd))
        out.append(L.rmsnorm(f"{name}.knorm", t_kv * Hkv, hd))
    if rope and cfg.rope_fraction > 0:
        d_rot = max(1, int(hd * cfg.rope_fraction))
        out.append(L.rotary(f"{name}.rope_q", t_q * H, d_rot))
        out.append(L.rotary(f"{name}.rope_k", t_kv * Hkv, d_rot))
    out += [
        gemm(f"{name}.scores", s_q, s_att, hd, count=batch * H,
             param=False),
        L.softmax(f"{name}.softmax", batch * H * s_q, s_att),
        gemm(f"{name}.av", s_q, hd, s_att, count=batch * H, param=False),
        gemm(f"{name}.o", t_q, D, H * hd),
        _residual(f"{name}.res", t_q, D),
    ]
    return out


def _mlp(cfg: ModelConfig, name: str, tokens: int,
         gated: bool) -> List[LlmLayer]:
    D, F = cfg.d_model, cfg.d_ff
    out: List[LlmLayer] = [_norm(cfg, f"{name}.norm", tokens, D)]
    if gated:
        out += [gemm(f"{name}.gate", tokens, F, D),
                gemm(f"{name}.up", tokens, F, D),
                L.activation(f"{name}.act", tokens, F, cfg.act,
                             gated=True)]
    else:
        out += [gemm(f"{name}.fc1", tokens, F, D),
                L.activation(f"{name}.act", tokens, F, cfg.act)]
    out += [gemm(f"{name}.down", tokens, D, F),
            _residual(f"{name}.res", tokens, D)]
    return out


def _moe(cfg: ModelConfig, name: str, tokens: int) -> List[LlmLayer]:
    """Router + capacity-balanced expert GEMMs: each of the ``n_experts``
    identical expert MLPs processes ``ceil(tokens * top_k / n_experts)``
    tokens (the balanced-dispatch expectation the capacity factor
    enforces), expressed through ``GemmLayer.count``."""
    D, F, E, K = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k
    m_exp = max(1, math.ceil(tokens * K / E))
    out: List[LlmLayer] = [
        _norm(cfg, f"{name}.norm", tokens, D),
        gemm(f"{name}.router", tokens, E, D),
        L.softmax(f"{name}.route_sm", tokens, E),
        gemm(f"{name}.e_gate", m_exp, F, D, count=E),
        gemm(f"{name}.e_up", m_exp, F, D, count=E),
        L.activation(f"{name}.e_act", m_exp * E, F, cfg.act, gated=True),
        gemm(f"{name}.e_down", m_exp, D, F, count=E),
    ]
    if cfg.shared_expert:
        out += [gemm(f"{name}.s_gate", tokens, F, D),
                gemm(f"{name}.s_up", tokens, F, D),
                L.activation(f"{name}.s_act", tokens, F, cfg.act,
                             gated=True),
                gemm(f"{name}.s_down", tokens, D, F)]
    out.append(_residual(f"{name}.res", tokens, D))
    return out


def _mamba2(cfg: ModelConfig, name: str, batch: int,
            seq: int) -> List[LlmLayer]:
    """Mamba-2 mixer: in-projection (x, z, B, C, dt), short conv over the
    x/B/C channels, the SSD block expressed as its two per-head
    activation-activation GEMMs (state outer-product update and the
    output contraction against the carried state) plus the elementwise
    decay scan, gated merge, out-projection."""
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    nh = max(1, d_inner // cfg.ssm_head_dim)
    tokens = batch * seq
    d_conv = d_inner + 2 * cfg.ssm_state
    return [
        _norm(cfg, f"{name}.norm", tokens, D),
        gemm(f"{name}.in", tokens, 2 * d_inner + 2 * cfg.ssm_state + nh, D),
        L.conv1d(f"{name}.conv", tokens, d_conv, cfg.conv_width),
        gemm(f"{name}.ssd_state", seq, cfg.ssm_state, cfg.ssm_head_dim,
             count=batch * nh, param=False),
        L.elementwise_scan(f"{name}.scan", tokens,
                           nh * cfg.ssm_state, kind="ssm"),
        gemm(f"{name}.ssd_out", seq, cfg.ssm_head_dim, cfg.ssm_state,
             count=batch * nh, param=False),
        L.rmsnorm(f"{name}.gnorm", tokens, d_inner),
        L.activation(f"{name}.gate", tokens, d_inner, "silu", gated=True),
        gemm(f"{name}.out", tokens, D, d_inner),
        _residual(f"{name}.res", tokens, D),
    ]


def _rglru(cfg: ModelConfig, name: str, batch: int,
           seq: int) -> List[LlmLayer]:
    """RG-LRU recurrent mixer (recurrentgemma): two input branches, short
    conv, the input/recurrence gate projections (block-diagonal in the
    real model; priced dense as an upper bound), the elementwise gated
    recurrence, gated merge, out-projection."""
    D = cfg.d_model
    W = cfg.rnn_width or D
    tokens = batch * seq
    return [
        _norm(cfg, f"{name}.norm", tokens, D),
        gemm(f"{name}.in", tokens, 2 * W, D),
        L.conv1d(f"{name}.conv", tokens, W, cfg.conv_width),
        gemm(f"{name}.gates", tokens, 2 * W, W),
        L.elementwise_scan(f"{name}.scan", tokens, W, kind="rglru"),
        L.activation(f"{name}.gate", tokens, W, cfg.act, gated=True),
        gemm(f"{name}.out", tokens, D, W),
        _residual(f"{name}.res", tokens, D),
    ]


def lower_llm(cfg: ModelConfig, batch: int = 1,
              seq: Optional[int] = None) -> List[LlmLayer]:
    """Lower a model config to the flat (GEMM + SIMD) inference graph the
    DSE engine prices; ``expand_training_graph`` turns it into the
    training workload.  Embedding lookups are not modeled (pure DRAM
    gathers, no array work); the lm-head projection is.  VLM patch
    stubs extend the token stream (early fusion); enc-dec configs emit
    the encoder stack plus cross-attention in every decoder layer."""
    S = seq if seq is not None else LLM_SEQ_DEFAULT
    if S <= 0 or batch <= 0:
        raise ValueError(f"batch/seq must be positive, got {batch}/{S}")
    B = batch
    S = S + cfg.n_patches                   # early-fusion patch prefix
    D = cfg.d_model
    out: List[LlmLayer] = []
    gated = cfg.family not in ("audio", "encdec")
    for e in range(cfg.encoder_layers):
        enc = f"enc{e}"
        out += _attention(cfg, f"{enc}.attn", B, cfg.encoder_seq,
                          cfg.encoder_seq, rope=False)
        out += _mlp(cfg, f"{enc}.mlp", B * cfg.encoder_seq, gated)
    kinds = cfg.layer_kinds()
    pat = cfg.attn_pattern
    for i, kind in enumerate(kinds):
        blk = f"blk{i}"
        local = bool(pat) and pat[i % len(pat)] == "local"
        if kind.startswith("attn"):
            out += _attention(cfg, f"{blk}.attn", B, S, S, local=local,
                              rope=cfg.rope_fraction > 0)
            if cfg.encoder_layers:
                out += _attention(cfg, f"{blk}.xattn", B, S,
                                  cfg.encoder_seq, cross=True, rope=False)
        elif kind == "mamba2":
            out += _mamba2(cfg, blk, B, S)
        elif kind == "rglru":
            out += _rglru(cfg, blk, B, S)
        else:
            raise ValueError(f"unknown block kind {kind!r} in "
                             f"{cfg.name}: {kinds}")
        if "moe" in kind:
            out += _moe(cfg, f"{blk}.moe", B * S)
        elif cfg.d_ff:
            # every mixer is followed by an MLP when d_ff > 0 — this
            # covers hybrid patterns (recurrentgemma: MLP after rglru
            # and attn alike); pure-SSM configs set d_ff = 0
            out += _mlp(cfg, f"{blk}.mlp", B * S, gated)
    out.append(_norm(cfg, "final.norm", B * S, D))
    out.append(gemm("lm_head", B * S, cfg.vocab_size, D))
    return out
