"""Unified model: assembles attention / Mamba2 / RG-LRU mixers with dense /
MoE FFNs into layer stacks, supporting all ten assigned architectures.

Layer stacking: the layer list is ``cfg.pattern`` repeated.  Layers are
grouped so each *pattern position* forms a homogeneous stack scanned with
``lax.scan`` over ``G = n_layers // len(pattern)`` groups (stacked params ->
small HLO, fast compile); the remainder ``n_layers % len(pattern)`` layers
are unrolled.  Per-layer scalars that vary within a homogeneous stack (the
gemma3 5:1 local:global window schedule) ride along as scan xs.

Caches mirror the parameter structure: ``cache['blk<i>']`` holds the stacked
per-layer state for pattern position i (KV ring buffer for attention, SSD
state for mamba2, recurrent state for RG-LRU), ``cache['rem<j>']`` the
unrolled remainder, ``cache['cross']`` the encoder KV for enc-dec models.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as ATT
from . import moe as MOE
from . import rglru as RG
from . import ssm as SSM
from .common import (ModelConfig, ParamDef, Rules, abstract_params,
                     init_params, param_specs, shard)
from .layers import (apply_mlp, apply_norm, embed_defs, embed_tokens,
                     lm_logits, mlp_defs, norm_defs)


def _mixer_kind(entry: str) -> str:
    return entry.split("+")[0]


def _is_moe(entry: str) -> bool:
    return entry.endswith("+moe")


def _block_defs(cfg: ModelConfig, entry: str, lead: Tuple[int, ...],
                cross: bool) -> Dict:
    kind = _mixer_kind(entry)
    defs: Dict[str, Any] = {"norm1": norm_defs(cfg, cfg.d_model, lead)}
    if kind == "attn":
        defs["attn"] = ATT.attn_defs(cfg, lead)
    elif kind == "mamba2":
        defs["ssm"] = SSM.ssm_defs(cfg, lead)
    elif kind == "rglru":
        defs["rglru"] = RG.rglru_defs(cfg, lead)
    else:
        raise ValueError(kind)
    if cross:
        defs["xnorm"] = norm_defs(cfg, cfg.d_model, lead)
        defs["xattn"] = ATT.attn_defs(cfg, lead, cross=True)
    if cfg.d_ff > 0:
        defs["norm2"] = norm_defs(cfg, cfg.d_model, lead)
        defs["mlp"] = (MOE.moe_defs(cfg, lead) if _is_moe(entry)
                       else mlp_defs(cfg, lead))
    return defs


def _apply_block(cfg: ModelConfig, entry: str, p: Dict, x: jax.Array,
                 rules: Optional[Rules], *,
                 window=None, cache: Optional[Dict] = None,
                 enc_out: Optional[jax.Array] = None,
                 causal: Optional[bool] = None,
                 ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    kind = _mixer_kind(entry)
    aux = jnp.zeros((), jnp.float32)
    # split the cached cross-attention KV (it is read-only) from the
    # mixer's own mutable state
    cross_kv = None
    mix_cache = cache
    if cache is not None and "_cross" in cache:
        cross_kv = cache["_cross"]
        mix_cache = {k: v for k, v in cache.items() if k != "_cross"}
    h = apply_norm(cfg, p["norm1"], x)
    new_cache: Optional[Dict] = None
    if kind == "attn":
        mix, new_cache = ATT.attention(cfg, p["attn"], h, rules,
                                       cache=mix_cache, window=window,
                                       causal=causal)
    elif kind == "mamba2":
        mix, new_cache = SSM.apply_ssm(cfg, p["ssm"], h, rules,
                                       state=mix_cache)
    else:
        mix, new_cache = RG.apply_rglru(cfg, p["rglru"], h, rules,
                                        state=mix_cache)
    # named for selective remat: the 'save_mixer' policy keeps this (small,
    # (B,S,d)) tensor and skips recomputing the whole mixer in backward
    from jax.ad_checkpoint import checkpoint_name
    mix = checkpoint_name(mix, "mixer_out")
    x = x + mix
    if "xattn" in p:
        hx = apply_norm(cfg, p["xnorm"], x)
        if cross_kv is not None:
            ymix = ATT.attend_precomputed(cfg, p["xattn"], hx,
                                          cross_kv["k"], cross_kv["v"],
                                          rules)
        else:
            ymix, _ = ATT.attention(cfg, p["xattn"], hx, rules,
                                    kv_x=enc_out, causal=False)
        x = x + ymix
    if cross_kv is not None and new_cache is not None:
        new_cache = dict(new_cache, _cross=cross_kv)
    if cfg.d_ff > 0:
        h2 = apply_norm(cfg, p["norm2"], x)
        if _is_moe(entry):
            ff, aux = MOE.apply_moe(cfg, p["mlp"], h2, rules)
        else:
            ff = apply_mlp(cfg, p["mlp"], h2, rules)
        x = x + ff
    return x, new_cache, aux


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- structure ---------------------------------------------------------
    @property
    def pat(self) -> Tuple[str, ...]:
        return self.cfg.pattern

    @property
    def groups(self) -> int:
        return self.cfg.n_layers // len(self.pat)

    @property
    def remainder(self) -> int:
        return self.cfg.n_layers % len(self.pat)

    def _windows(self) -> np.ndarray:
        """Per-layer window sizes from cfg.attn_pattern (0 = full)."""
        cfg = self.cfg
        pat = cfg.attn_pattern or ("global",)
        return np.array(
            [cfg.window if pat[i % len(pat)] == "local" else 0
             for i in range(cfg.n_layers)], np.int32)

    def _entry_layers(self, gi: int) -> np.ndarray:
        """Absolute layer indices covered by pattern position gi."""
        plen = len(self.pat)
        return np.arange(self.groups) * plen + gi

    # ---- params ------------------------------------------------------------
    def param_defs(self) -> Dict:
        cfg = self.cfg
        cross = cfg.encoder_layers > 0
        defs: Dict[str, Any] = {"embed": embed_defs(cfg)}
        if cfg.learned_pos:
            defs["pos_emb"] = ParamDef((cfg.learned_pos, cfg.d_model),
                                       ("pos", "embed"))
        for gi, entry in enumerate(self.pat):
            if self.groups > 0:
                defs[f"blk{gi}"] = _block_defs(cfg, entry, (self.groups,),
                                               cross)
        for j in range(self.remainder):
            defs[f"rem{j}"] = _block_defs(cfg, self.pat[j], (), cross)
        defs["final_norm"] = norm_defs(cfg, cfg.d_model)
        if cross:
            defs["enc"] = {
                "blk": _block_defs(cfg, "attn", (cfg.encoder_layers,), False),
                "norm": norm_defs(cfg, cfg.d_model),
                "pos_emb": ParamDef((cfg.encoder_seq, cfg.d_model),
                                    ("pos", "embed")),
            }
        return defs

    def init(self, rng: jax.Array):
        return init_params(rng, self.param_defs(), self.cfg.dtype)

    def abstract(self):
        return abstract_params(self.param_defs(), self.cfg.dtype)

    def specs(self, rules: Optional[Rules]):
        return param_specs(self.param_defs(), rules)

    # ---- encoder (enc-dec only) ---------------------------------------------
    def encode(self, params: Dict, frames: jax.Array,
               rules: Optional[Rules]) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(cfg.dtype)
        x = x + params["enc"]["pos_emb"][:x.shape[1]].astype(cfg.dtype)
        blk = params["enc"]["blk"]

        def step(carry, pslice):
            y, _, _ = _apply_block(cfg, "attn", pslice, carry, rules,
                                   causal=False)
            return y, None

        body = jax.checkpoint(step) if cfg.remat else step
        x, _ = jax.lax.scan(body, x, blk)
        return apply_norm(cfg, params["enc"]["norm"], x)

    # ---- main stacks ---------------------------------------------------------
    def _run_stack(self, params: Dict, x: jax.Array, rules: Optional[Rules],
                   cache: Optional[Dict], enc_out: Optional[jax.Array]
                   ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
        cfg = self.cfg
        wins = self._windows()
        aux_total = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {} if cache is not None else None

        if self.groups > 0:
            def group_step(carry, xs):
                y, aux = carry
                # the scan carry is what remat saves per layer group:
                # sequence-shard it (Megatron-SP) to cut saved-activation HBM
                y = shard(y, rules, "batch", "seq_resid", "act_embed")
                updated = []
                for gi, entry in enumerate(self.pat):
                    pslice, win, csl = xs[gi]
                    y, nc, a = _apply_block(
                        cfg, entry, pslice, y, rules, window=win,
                        cache=csl, enc_out=enc_out)
                    updated.append(nc)
                    aux = aux + a
                return (y, aux), tuple(updated)

            xs = []
            for gi, entry in enumerate(self.pat):
                win = jnp.asarray(wins[self._entry_layers(gi)])
                csl = None if cache is None else cache[f"blk{gi}"]
                xs.append((params[f"blk{gi}"], win, csl))
            if cfg.remat and cfg.remat_policy == "save_dots":
                # selective remat: matmul outputs are saved, elementwise
                # recomputed — trades HBM for less recompute FLOPs
                body = jax.checkpoint(
                    group_step,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            elif cfg.remat and cfg.remat_policy == "save_mixer":
                # save only the (B,S,d) mixer outputs: skips the attention
                # recompute at ~1 residual-stream tensor per layer of HBM
                body = jax.checkpoint(
                    group_step,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "mixer_out"))
            elif cfg.remat:
                body = jax.checkpoint(group_step)
            else:
                body = group_step
            (x, aux_total), upd = jax.lax.scan(body, (x, aux_total),
                                               tuple(xs))
            if cache is not None:
                for gi in range(len(self.pat)):
                    new_cache[f"blk{gi}"] = upd[gi]

        base = self.groups * len(self.pat)
        for j in range(self.remainder):
            entry = self.pat[j]
            csl = None if cache is None else cache[f"rem{j}"]
            x, nc, a = _apply_block(
                cfg, entry, params[f"rem{j}"], x, rules,
                window=jnp.asarray(wins[base + j]), cache=csl,
                enc_out=enc_out)
            aux_total = aux_total + a
            if cache is not None:
                new_cache[f"rem{j}"] = nc
        return x, new_cache, aux_total

    # ---- forward -------------------------------------------------------------
    def forward(self, params: Dict, tokens: jax.Array,
                rules: Optional[Rules] = None,
                frames: Optional[jax.Array] = None,
                patches: Optional[jax.Array] = None,
                cache: Optional[Dict] = None,
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
        """Returns (logits_f32, new_cache, moe_aux_loss)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, rules, cfg.dtype)
        if patches is not None:
            x = jnp.concatenate([patches.astype(cfg.dtype), x], axis=1)
        if cfg.learned_pos:
            off = cache["pos_offset"] if (cache is not None
                                          and "pos_offset" in cache) else 0
            pos = off + jnp.arange(x.shape[1])
            x = x + jnp.take(params["pos_emb"], pos, axis=0).astype(cfg.dtype)

        enc_out = None
        if cfg.encoder_layers > 0 and frames is not None:
            enc_out = self.encode(params, frames, rules)

        x, new_cache, aux = self._run_stack(params, x, rules, cache, enc_out)
        if cache is not None and "pos_offset" in cache:
            new_cache["pos_offset"] = cache["pos_offset"] + x.shape[1]
        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(params["embed"], x, rules)
        return logits, new_cache, aux

    # ---- loss ------------------------------------------------------------------
    def _final_hidden(self, params: Dict, tokens: jax.Array,
                      rules: Optional[Rules],
                      frames=None, patches=None
                      ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, rules, cfg.dtype)
        if patches is not None:
            x = jnp.concatenate([patches.astype(cfg.dtype), x], axis=1)
        if cfg.learned_pos:
            pos = jnp.arange(x.shape[1])
            x = x + jnp.take(params["pos_emb"], pos, axis=0).astype(cfg.dtype)
        enc_out = None
        if cfg.encoder_layers > 0 and frames is not None:
            enc_out = self.encode(params, frames, rules)
        x, _, aux = self._run_stack(params, x, rules, None, enc_out)
        return apply_norm(cfg, params["final_norm"], x), aux

    def loss(self, params: Dict, batch: Dict,
             rules: Optional[Rules] = None) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        patches = batch.get("patches")
        x, aux = self._final_hidden(params, tokens, rules,
                                    frames=batch.get("frames"),
                                    patches=patches)
        if patches is not None:
            x = x[:, patches.shape[1]:]
        targets = tokens[:, 1:]
        x = x[:, :-1]
        w = params["embed"].get("head")
        if w is None:
            w = params["embed"]["embedding"].T

        def ce_of(xc, tc):
            logits = shard((xc @ w).astype(jnp.float32), rules,
                           "batch", None, "vocab")
            return -jnp.take_along_axis(
                jax.nn.log_softmax(logits, axis=-1), tc[..., None],
                axis=-1).squeeze(-1)

        chunk = cfg.ce_chunk
        s = x.shape[1]
        if chunk and s > chunk:
            pad = (-s) % chunk
            xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            tp = jnp.pad(targets, ((0, 0), (0, pad)))
            nc = xp.shape[1] // chunk
            xcs = xp.reshape(x.shape[0], nc, chunk, -1).swapaxes(0, 1)
            tcs = tp.reshape(x.shape[0], nc, chunk).swapaxes(0, 1)
            # checkpoint: backward rematerializes one chunk of logits at a
            # time — only (B, chunk, V) is ever live
            ces = jax.lax.map(
                jax.checkpoint(lambda args: ce_of(*args)), (xcs, tcs))
            ce = ces.swapaxes(0, 1).reshape(x.shape[0], -1)[:, :s]
        else:
            ce = ce_of(x, targets)
        loss = ce.mean() + 0.01 * aux
        return loss, {"ce": ce.mean(), "aux": aux}

    # ---- caches -----------------------------------------------------------------
    def _cache_entry(self, entry: str, lead: Tuple[int, ...], batch: int,
                     max_len: int, abstract: bool) -> Dict:
        cfg = self.cfg
        kind = _mixer_kind(entry)
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract \
            else (lambda s, d: jnp.zeros(s, d))
        if kind == "attn":
            kv, hd = cfg.n_kv_heads, cfg.hd
            cdt = cfg.cache_dtype or cfg.dtype
            c = {"k": mk(lead + (batch, max_len, kv, hd), cdt),
                 "v": mk(lead + (batch, max_len, kv, hd), cdt),
                 "pos": mk(lead, jnp.int32)}
            if cdt == jnp.int8:
                c["k_scale"] = mk(lead + (batch, max_len, kv), jnp.float32)
                c["v_scale"] = mk(lead + (batch, max_len, kv), jnp.float32)
        elif kind == "mamba2":
            di, h, n = SSM.ssm_dims(cfg)
            c = {"ssm": mk(lead + (batch, h, cfg.ssm_head_dim, n),
                           jnp.float32),
                 "conv": mk(lead + (batch, cfg.conv_width - 1, di + 2 * n),
                            jnp.float32)}
        else:
            r = cfg.rnn_width or cfg.d_model
            c = {"h": mk(lead + (batch, r), jnp.float32),
                 "conv": mk(lead + (batch, cfg.conv_width - 1, r),
                            jnp.float32)}
        if cfg.encoder_layers > 0:
            kv, hd = cfg.n_kv_heads, cfg.hd
            c["_cross"] = {
                "k": mk(lead + (batch, cfg.encoder_seq, kv, hd), cfg.dtype),
                "v": mk(lead + (batch, cfg.encoder_seq, kv, hd), cfg.dtype)}
        return c

    def make_cache(self, batch: int, max_len: int,
                   abstract: bool = False) -> Dict:
        cache: Dict[str, Any] = {}
        for gi, entry in enumerate(self.pat):
            if self.groups > 0:
                cache[f"blk{gi}"] = self._cache_entry(
                    entry, (self.groups,), batch, max_len, abstract)
        for j in range(self.remainder):
            cache[f"rem{j}"] = self._cache_entry(
                self.pat[j], (), batch, max_len, abstract)
        if self.cfg.learned_pos:
            mk = (lambda: jax.ShapeDtypeStruct((), jnp.int32)) if abstract \
                else (lambda: jnp.zeros((), jnp.int32))
            cache["pos_offset"] = mk()
        return cache

    # ---- serving ---------------------------------------------------------------
    def prefill(self, params: Dict, tokens: jax.Array, max_len: int,
                rules: Optional[Rules] = None,
                frames: Optional[jax.Array] = None,
                patches: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
        cache = self.make_cache(tokens.shape[0], max_len)
        if frames is not None and self.cfg.encoder_layers > 0:
            enc_out = self.encode(params, frames, rules)
            cache = self._fill_cross(params, cache, enc_out)
            logits, cache, _ = self.forward(params, tokens, rules,
                                            cache=cache)
        else:
            logits, cache, _ = self.forward(params, tokens, rules,
                                            patches=patches, cache=cache)
        return logits[:, -1], cache

    def _fill_cross(self, params: Dict, cache: Dict,
                    enc_out: jax.Array) -> Dict:
        cfg = self.cfg

        def kv_for(pdefs):
            k = jnp.einsum("btd,ldhk->lbthk", enc_out, pdefs["wk"])
            v = jnp.einsum("btd,ldhk->lbthk", enc_out, pdefs["wv"])
            return k.astype(cfg.dtype), v.astype(cfg.dtype)

        for gi in range(len(self.pat)):
            key = f"blk{gi}"
            if key in cache and "_cross" in cache[key]:
                k, v = kv_for(params[key]["xattn"])
                cache[key]["_cross"] = {"k": k, "v": v}
        for j in range(self.remainder):
            key = f"rem{j}"
            if key in cache and "_cross" in cache[key]:
                k = jnp.einsum("btd,dhk->bthk", enc_out,
                               params[key]["xattn"]["wk"]).astype(cfg.dtype)
                v = jnp.einsum("btd,dhk->bthk", enc_out,
                               params[key]["xattn"]["wv"]).astype(cfg.dtype)
                cache[key]["_cross"] = {"k": k, "v": v}
        return cache

    def decode_step(self, params: Dict, tokens: jax.Array, cache: Dict,
                    rules: Optional[Rules] = None
                    ) -> Tuple[jax.Array, Dict]:
        """tokens: (B, 1) -> (logits (B, vocab), new cache)."""
        logits, cache, _ = self.forward(params, tokens, rules, cache=cache)
        return logits[:, -1], cache
