"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests/benches see the real (single) device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. ('stage',) pipelines)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
