"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests/benches see the real (single) device.
"""
from __future__ import annotations

import jax

# jax.sharding.AxisType landed after 0.4.x; on older pinned JAX the
# explicit-axis-type kwarg simply doesn't exist and every axis is Auto by
# default, so we only pass it when the installed JAX knows it.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _mesh_kwargs(axes) -> dict:
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * len(axes)}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. ('stage',) pipelines)."""
    axes = tuple(axes)
    return jax.make_mesh(tuple(shape), axes, **_mesh_kwargs(axes))
