"""Assigned input shapes (4 per architecture = 40 cells) and per-cell
sharding-rule adjustments.

  train_4k     seq=4096    global_batch=256   (training step)
  prefill_32k  seq=32768   global_batch=32    (inference prefill)
  decode_32k   seq=32768   global_batch=128   (one decode token, 32k cache)
  long_500k    seq=524288  global_batch=1     (long-context decode)

``long_500k`` requires sub-quadratic attention: run for the SSM / hybrid /
local-attention archs (mamba2-130m, recurrentgemma-9b, gemma3-27b), skip
for the pure full-attention archs (documented in DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, PROD_RULES, Rules, multipod
from repro.models.frontends import frontend_input_specs


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k applicability (see DESIGN.md §Arch-applicability)
LONG_OK = {"mamba2-130m", "recurrentgemma-9b", "gemma3-27b"}


def cell_is_runnable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return False, "pure full attention — sub-quadratic required (skip)"
    return True, ""


def adjust_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Per-cell config adjustments (documented deviations)."""
    kw = {}
    if cfg.learned_pos:
        # whisper: learned-position table structurally resized to the cell
        kw["learned_pos"] = max(cfg.learned_pos, shape.seq + 8)
    if shape.kind in ("train", "prefill"):
        # always take the chunked (flash-analogue) attention path for full
        # sequences: memory O(S * block) instead of O(S^2) logits; 512 is
        # the block at which the HBM fit was established (EXPERIMENTS.md)
        kw["dense_attn_max_seq"] = 1
        kw["attn_block"] = 512
    if shape.kind == "train":
        kw["ce_chunk"] = 512       # seq-chunked CE: bounds logits memory
    return cfg.replace(**kw) if kw else cfg


def cell_rules(shape: ShapeSpec, multi_pod: bool,
               data_size: int = 16) -> Rules:
    rules = dict(PROD_RULES)
    if multi_pod:
        rules = multipod(rules)
    if shape.kind == "decode" and shape.global_batch < data_size:
        # batch too small to shard: sequence-shard the KV cache instead
        rules["batch"] = None
        rules["cache_seq"] = ("pod", "data") if multi_pod else "data"
    return rules


def batch_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Abstract (ShapeDtypeStruct) inputs for the cell's step function."""
    b = shape.global_batch
    if shape.kind == "train" or shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq), jnp.int32)}
        specs.update(frontend_input_specs(cfg, b))
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    return specs
