"""Serving driver: prefill + batched autoregressive decode.

``make_prefill_step`` / ``make_serve_step`` build the pjit-ready functions
the dry-run lowers for the prefill/decode shapes; ``serve_loop`` is a
runnable single-host batched-request demo (greedy decoding).

Run (CPU example scale):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.common import Rules
from repro.models.frontends import synth_frontend_inputs
from repro.models.transformer import Model


def make_prefill_step(model: Model, rules: Optional[Rules], max_len: int):
    def prefill_step(params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        return model.prefill(params, batch["tokens"], max_len, rules,
                             frames=batch.get("frames"),
                             patches=batch.get("patches"))
    return prefill_step


def make_serve_step(model: Model, rules: Optional[Rules]):
    def serve_step(params: Dict, cache: Dict, tokens: jax.Array
                   ) -> Tuple[jax.Array, Dict]:
        logits, cache = model.decode_step(params, tokens, cache, rules)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, cache
    return serve_step


def serve_loop(arch: str, batch: int = 4, prompt_len: int = 16,
               gen: int = 16, use_reduced: bool = True, seed: int = 0,
               log=print) -> Dict:
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    cfg = cfg.replace(dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    max_len = prompt_len + gen + 8

    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    extras = synth_frontend_inputs(cfg, batch)

    prefill = jax.jit(make_prefill_step(model, None, max_len))
    step = jax.jit(make_serve_step(model, None), donate_argnums=(1,))

    t0 = time.perf_counter()
    last_logits, cache = prefill(params, {"tokens": prompts, **extras})
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    for _ in range(gen - 1):
        nxt, cache = step(params, cache, tok)
        tok = nxt[:, None]
        out_tokens.append(tok)
    elapsed = time.perf_counter() - t0
    gen_tokens = jnp.concatenate(out_tokens, axis=1)
    log(f"served {batch} requests x {gen} tokens in {elapsed:.2f}s "
        f"({batch * gen / elapsed:.1f} tok/s)")
    return {"generated": np.asarray(gen_tokens), "elapsed_s": elapsed}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()
    serve_loop(args.arch, args.batch, args.prompt_len, args.gen,
               args.reduced)


if __name__ == "__main__":
    main()
