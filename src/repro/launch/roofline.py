"""Roofline extraction from compiled dry-run artifacts.

``collective_bytes``: cost_analysis does not report collective traffic, so
we parse the optimized HLO (``compiled.as_text()``) and sum the output
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (async ``-start`` forms counted
once).  ``analyze`` assembles the three-term roofline of
``repro.core.tpu_model`` plus the MODEL_FLOPS/HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

from repro.core.tpu_model import RooflineTerms, model_flops

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_WHILE = re.compile(r"while\(.*?\),.*?condition=%?([\w.\-]+),"
                    r"\s*body=%?([\w.\-]+)")
_WHILE2 = re.compile(r"while\(.*?\),.*?body=%?([\w.\-]+),"
                     r"\s*condition=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip()) if line and not line.startswith(
            " ") else None
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _line_coll_bytes(line: str) -> Tuple[int, Optional[str]]:
    if "-done(" in line:
        return 0, None
    m = _COLL.search(line)
    if not m:
        return 0, None
    tuple_part, dtype, dims, kind = m.groups()
    if tuple_part is not None:
        sz = sum(_shape_bytes(dt, dm) for dt, dm in _SHAPE.findall(tuple_part))
    else:
        sz = _shape_bytes(dtype, dims)
    return sz, kind


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Total bytes and per-kind breakdown of collective outputs,
    **multiplying while-loop (scan) bodies by their trip count** (parsed
    from the largest integer constant in the loop condition — XLA's scan
    lowering compares the induction variable against the length).  Without
    this, collectives inside scanned layers are counted once instead of
    n_layers times."""
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"__entry__": hlo_text.splitlines()}

    def trip_count(cond_name: str) -> int:
        names = [cond_name]
        for line in comps.get(cond_name, []):
            names += _CALLS.findall(line)
        consts = [int(c) for n in names for line in comps.get(n, [])
                  for c in _CONST.findall(line)]
        return max(consts) if consts else 1

    from functools import lru_cache

    def walk(name: str, seen=()) -> Tuple[int, Dict[str, int]]:
        if name in seen:
            return 0, {}
        total = 0
        by_kind: Dict[str, int] = {}
        for line in comps.get(name, []):
            sz, kind = _line_coll_bytes(line)
            if sz:
                total += sz
                by_kind[kind] = by_kind.get(kind, 0) + sz
            m = _WHILE.search(line) or _WHILE2.search(line)
            if m:
                g = m.groups()
                cond, body = (g[0], g[1]) if _WHILE.search(line) else (
                    g[1], g[0])
                t = trip_count(cond)
                sub_total, sub_kind = walk(body, seen + (name,))
                total += sub_total * t
                for k, v in sub_kind.items():
                    by_kind[k] = by_kind.get(k, 0) + v * t
        return total, by_kind

    return walk("__entry__")


def analyze(compiled, chips: int, n_active_params: int, tokens: int,
            training: bool, flops: Optional[float] = None,
            hbm_bytes: Optional[float] = None) -> Dict:
    """Roofline terms + usefulness ratio for one compiled step.

    ``flops``/``hbm_bytes`` should come from the scan-aware jaxpr walker
    (``launch.costmodel``): XLA's cost_analysis counts while bodies once
    and is recorded only as a reference lower bound."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # some backends return [dict]
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    flops = flops if flops is not None else xla_flops
    hbm = hbm_bytes if hbm_bytes is not None else xla_bytes
    coll, by_kind = collective_bytes(compiled.as_text())
    terms = RooflineTerms(flops=flops, hbm_bytes=hbm,
                          collective_bytes=float(coll), chips=chips)
    mf = model_flops(n_active_params, tokens, training)
    out = terms.as_dict()
    out["model_flops"] = mf
    out["model_flops_ratio"] = (mf / flops) if flops else 0.0
    out["collective_by_kind"] = by_kind
    out["xla_flops_body_once"] = xla_flops
    out["xla_bytes_body_once"] = xla_bytes
    return out


def count_params(defs_tree, moe_scale: Optional[Dict[str, float]] = None
                 ) -> Tuple[int, int]:
    """(total, active) parameter counts from a ParamDef tree.

    ``active`` scales expert-axis parameters by (top_k [+ shared]) / E.
    """
    import jax
    from repro.models.common import ParamDef

    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            defs_tree, is_leaf=lambda x: isinstance(x, ParamDef))[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        scale = 1.0
        if "experts" in leaf.axes and moe_scale:
            scale = moe_scale.get("expert_frac", 1.0)
        active += int(n * scale)
    return total, active
