import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Runs named optimization variants against a cell's baseline, re-lowers,
re-analyses, and records hypothesis -> change -> before -> after.

The ``flash`` variant applies the Pallas flash-attention *cost
substitution*: the pure-XLA chunked attention materializes its O(S x block)
probability matrices in HBM (they exceed VMEM, so XLA cannot fuse them
away); the Pallas kernel (repro/kernels/flash_attention.py) keeps every
tile VMEM-resident by construction, so its HBM traffic is exactly
q/k/v/o (+do, dq/dk/dv in backward).  Both sides of the substitution are
computed with the SAME jaxpr walker: we measure the jnp attention's walker
bytes per layer and replace them with the kernel-true bytes.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen3_decode
  PYTHONPATH=src python -m repro.launch.hillclimb --all
"""
import argparse
import json
import pathlib

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.costmodel import jaxpr_cost
from repro.launch.dryrun import lower_cell
from repro.launch.shapes import SHAPES, adjust_config
from repro.models import attention as ATT
from repro.models.common import ModelConfig

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "hillclimb"


# ---------------------------------------------------------------------------
# flash-attention byte substitution
# ---------------------------------------------------------------------------

def attention_bytes_per_layer(cfg: ModelConfig, batch: int, seq: int,
                              training: bool) -> dict:
    """Walker bytes of one layer's jnp chunked attention vs the Pallas
    kernel's true HBM traffic, at global shapes."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jax.ShapeDtypeStruct((batch, seq, h, hd), cfg.dtype)
    k = jax.ShapeDtypeStruct((batch, seq, kv, hd), cfg.dtype)
    v = jax.ShapeDtypeStruct((batch, seq, kv, hd), cfg.dtype)
    pos = jnp.arange(seq)

    def attn(q, k, v):
        return ATT._chunked_attention_dynwin(
            q, k, v, pos, pos, True, jnp.asarray(cfg.window),
            cfg.attn_block)

    fwd = jaxpr_cost(attn, q, k, v)

    def loss(q, k, v):
        return attn(q, k, v).astype(jnp.float32).sum()

    grad = jaxpr_cost(jax.value_and_grad(loss, argnums=(0, 1, 2)), q, k, v)

    el = 2  # bytes (bf16)
    qb = batch * seq * h * hd * el
    kb = batch * seq * kv * hd * el
    kernel_fwd = qb + 2 * kb + qb                      # read q,k,v; write o
    kernel_bwd = (2 * qb + 2 * kb) + qb + (qb + 2 * kb)
    # read q,k,v,o,do; write dq,dk,dv (flash backward recomputes tiles)
    if training:
        # layer remat: forward + (recompute-forward + backward)
        xla = fwd.bytes + grad.bytes
        kernel = kernel_fwd + (kernel_fwd + kernel_bwd)
        xla_flops = fwd.flops + grad.flops
    else:
        xla = fwd.bytes
        kernel = kernel_fwd
        xla_flops = fwd.flops
    return {"xla_bytes": float(xla), "kernel_bytes": float(kernel),
            "delta": float(xla - kernel), "xla_flops": float(xla_flops)}


def block_skip_factor(seq: int, window: int) -> float:
    """Fraction of the full S x S score work a block-skipping kernel
    actually computes (x1.1 block-granularity overhead)."""
    if window and 0 < window < seq:
        valid = seq * window - window * window / 2.0
    else:
        valid = seq * (seq + 1) / 2.0      # causal triangle
    return min(1.0, 1.1 * valid / (seq * seq))


def flops_skip_delta(cfg: ModelConfig, batch: int, seq: int,
                     training: bool) -> float:
    """Total FLOPs removed by causal/window block skipping across layers."""
    delta = 0.0
    wins = [cfg.window if (cfg.attn_pattern or ("global",))[
        i % len(cfg.attn_pattern or ("global",))] == "local" else 0
        for i in range(cfg.n_layers)]
    kinds = cfg.layer_kinds()
    # one walker measurement per distinct window value
    cache = {}
    for i, kind in enumerate(kinds):
        if kind != "attn":
            continue
        w = wins[i]
        if w not in cache:
            c = cfg.replace(window=w)
            cache[w] = attention_bytes_per_layer(c, batch, seq, training)
        factor = block_skip_factor(seq, w)
        delta += cache[w]["xla_flops"] * (1.0 - factor)
    return delta


def apply_flash_substitution(record: dict, cfg: ModelConfig,
                             shape_name: str, skip: bool = False) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return record
    n_attn = sum(1 for kind in cfg.layer_kinds() if kind == "attn")
    sub = attention_bytes_per_layer(cfg, shape.global_batch, shape.seq,
                                    shape.kind == "train")
    r = record["roofline"]
    new_bytes = max(0.0, r["hbm_bytes"] - n_attn * sub["delta"])
    new_flops = r["flops"]
    if skip:
        new_flops = max(0.0, new_flops - flops_skip_delta(
            cfg, shape.global_batch, shape.seq, shape.kind == "train"))
    from repro.core.tpu_model import RooflineTerms
    terms = RooflineTerms(flops=new_flops, hbm_bytes=new_bytes,
                          collective_bytes=r["collective_bytes"],
                          chips=r["chips"])
    r2 = dict(r)
    r2.update(terms.as_dict())
    r2["model_flops"] = r["model_flops"]
    r2["model_flops_ratio"] = (r["model_flops"] / new_flops
                               if new_flops else 0.0)
    r2["flash_substitution"] = {**sub, "n_attn_layers": n_attn,
                                "block_skip": skip}
    out = dict(record)
    out["roofline"] = r2
    return out


# ---------------------------------------------------------------------------
# cells x variants
# ---------------------------------------------------------------------------

CELLS = {
    # worst roofline fraction: decode is cache-read bound AND the baseline
    # per-device KV cache (batch/16 only) does not even fit HBM
    "qwen3_decode": {
        "arch": "qwen3-0.6b", "shape": "decode_32k",
        "variants": {
            "baseline": {},
            "cache2d": {"rules": {"cache_seq": "model"}},
            "cache2d+int8kv": {"rules": {"cache_seq": "model"},
                               "cfg": {"cache_dtype": jnp.int8}},
        },
    },
    # most collective/MoE-bound + worst memory blowup
    "llama4_train": {
        "arch": "llama4-maverick-400b-a17b", "shape": "train_4k",
        "variants": {
            "baseline": {},
            "scatter": {"cfg": {"moe_dispatch": "scatter"}},
            "onehot+blk16k": {"cfg": {"moe_block": 16384}},     # control
            "scatter+blk16k": {"cfg": {"moe_dispatch": "scatter",
                                       "moe_block": 16384}},
            "scatter+blk64k": {"cfg": {"moe_dispatch": "scatter",
                                       "moe_block": 65536}},
        },
    },
    # most representative of the paper's technique (tiling/kernel DSE)
    "gemma3_train": {
        "arch": "gemma3-27b", "shape": "train_4k",
        "variants": {
            "baseline": {},
            "flash": {"flash": True},
            "flash+save_dots": {"flash": True,
                                "cfg": {"remat_policy": "save_dots"}},
            "flash+save_mixer": {"flash": True,
                                 "cfg": {"remat_policy": "save_mixer"}},
            "flash+blk1024": {"flash": True, "cfg": {"attn_block": 1024}},
            "flash+skip": {"flash": True, "skip": True},
        },
    },
}


def run_cell(name: str) -> None:
    spec = CELLS[name]
    ART.mkdir(parents=True, exist_ok=True)
    for vname, v in spec["variants"].items():
        try:
            rec, _ = lower_cell(spec["arch"], spec["shape"], False,
                                rules_override=v.get("rules"),
                                cfg_override=v.get("cfg"))
            if v.get("flash"):
                cfg = adjust_config(get_config(spec["arch"]),
                                    SHAPES[spec["shape"]])
                if v.get("cfg"):
                    cfg = cfg.replace(**v["cfg"])
                rec = apply_flash_substitution(rec, cfg, spec["shape"],
                                               skip=v.get("skip", False))
        except Exception as exc:   # pragma: no cover
            rec = {"status": "error", "error": f"{type(exc).__name__}: {exc}"}
        out = ART / f"{name}.{vname}.json"
        out.write_text(json.dumps(rec, indent=1))
        r = rec.get("roofline", {})
        mem = rec.get("memory", {})
        print(f"{name:14s} {vname:18s} "
              f"t_comp={r.get('t_compute_s', 0):.3f} "
              f"t_mem={r.get('t_memory_s', 0):.3f} "
              f"t_coll={r.get('t_collective_s', 0):.4f} "
              f"bound={r.get('bound', '?'):10s} "
              f"frac={r.get('roofline_fraction', 0):.3f} "
              f"temp={mem.get('temp_bytes', 0) / 1e9:.1f}GB "
              f"{rec.get('error', '')}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    names = list(CELLS) if args.all or not args.cell else [args.cell]
    for n in names:
        run_cell(n)


if __name__ == "__main__":
    main()
