"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
artifacts: per (arch x shape x mesh) the three terms, the dominant bound,
MODEL_FLOPS ratio, and per-device memory.

  PYTHONPATH=src python -m repro.launch.report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def fmt(v, unit=""):
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) < 1e-2 or abs(v) >= 1e4:
            return f"{v:.2e}{unit}"
        return f"{v:.3f}{unit}"
    return str(v)


def load(mesh: str):
    rows = []
    for p in sorted(ART_DIR.glob(f"*.{mesh}.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def render(mesh: str) -> str:
    rows = load(mesh)
    out = [f"### Mesh {mesh}",
           "",
           "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
           " | bound | roofline frac | 6ND/HLO | temp GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"ERROR | — | — | — |")
            continue
        rf = r["roofline"]
        temp = r["memory"]["temp_bytes"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['t_compute_s'])} | "
            f"{fmt(rf['t_memory_s'])} | {fmt(rf['t_collective_s'])} | "
            f"{rf['bound']} | {rf['roofline_fraction']:.3f} | "
            f"{rf['model_flops_ratio']:.2f} | "
            f"{temp / 1e9:.1f} |")
    return "\n".join(out)


def render_improvement(mesh: str = "16x16") -> str:
    """Baseline vs optimized (--optimized sweep) per cell."""
    base = {(r["arch"], r["shape"]): r for r in load(mesh)}
    rows = ["### Baseline vs optimized (winning §Perf variants everywhere)",
            "",
            "| arch | shape | base step (s) | opt step (s) | speedup | "
            "base bound→opt bound | base frac→opt frac |",
            "|---|---|---|---|---|---|---|"]
    for p in sorted(ART_DIR.glob(f"*.{mesh}.opt.json")):
        o = json.loads(p.read_text())
        if o.get("status") != "ok":
            continue
        b = base.get((o["arch"], o["shape"]))
        if not b or b.get("status") != "ok":
            continue
        rb, ro = b["roofline"], o["roofline"]
        sp = rb["step_time_s"] / ro["step_time_s"] if ro["step_time_s"] else 0
        rows.append(
            f"| {o['arch']} | {o['shape']} | {fmt(rb['step_time_s'])} | "
            f"{fmt(ro['step_time_s'])} | {sp:.2f}x | "
            f"{rb['bound']}→{ro['bound']} | "
            f"{rb['roofline_fraction']:.3f}→{ro['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--improvement", action="store_true")
    args = ap.parse_args()
    if args.improvement:
        print(render_improvement(args.mesh or "16x16"))
        return
    meshes = [args.mesh] if args.mesh else ["16x16", "2x16x16"]
    for m in meshes:
        print(render(m))
        print()


if __name__ == "__main__":
    main()
