"""Training driver: train_step construction (pjit-ready) + a runnable
single-host loop with checkpointing, watchdog, and pipeline state.

``make_train_step`` builds the donated, sharding-annotated step used both
by the dry-run (lower/compile only) and by the real loop.  Cross-pod
gradient compression (int8 + error feedback) is available with
``compress_pod_grads=True`` — it wraps the pod-axis reduction explicitly
via shard_map; the within-pod FSDP/TP reductions stay in XLA's lane.

Run (CPU example scale):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 20 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import PipelineState, TokenPipeline
from repro.distributed.fault import Watchdog
from repro.models.common import Rules
from repro.models.frontends import synth_frontend_inputs
from repro.models.transformer import Model
from repro.optim.optimizers import AdamW, cosine_schedule


def make_train_step(model: Model, opt, rules: Optional[Rules]):
    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        def loss_fn(params):
            loss, metrics = model.loss(params, batch, rules)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt, om = opt.update(grads, state["opt"],
                                             state["params"])
        out_metrics = {"loss": loss, **metrics, **om}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_state_shardings(model: Model, opt, rules: Optional[Rules], mesh):
    """NamedSharding pytrees for {'params', 'opt'} under ``mesh``."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    pspecs = model.specs(rules)
    ospecs = opt.state_specs(pspecs)

    def to_ns(spec):
        return NamedSharding(mesh, spec)

    return {
        "params": jax.tree_util.tree_map(to_ns, pspecs),
        "opt": jax.tree_util.tree_map(
            to_ns, ospecs, is_leaf=lambda x: isinstance(x, P)),
    }


# ---------------------------------------------------------------------------
# Single-host training loop (example scale)
# ---------------------------------------------------------------------------

def train_loop(arch: str, steps: int = 20, batch: int = 8, seq: int = 64,
               use_reduced: bool = True, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 10, resume: bool = True,
               lr: float = 3e-3, seed: int = 0,
               stop_after: Optional[int] = None,
               log=print) -> Dict[str, Any]:
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    cfg = cfg.replace(dtype=jnp.float32, remat=False)
    model = Model(cfg)
    opt = AdamW(schedule=cosine_schedule(lr, warmup=max(2, steps // 10),
                                         total=steps))

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=seq,
                         global_batch=batch, seed=seed)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    params = model.init(jax.random.PRNGKey(seed))
    state = {"params": params, "opt": opt.init(params)}
    pstate = PipelineState()
    start_step = 0
    if mgr is not None and resume and mgr.latest_step() is not None:
        s = mgr.latest_step()
        state, extra = mgr.restore(s, state)
        pstate = PipelineState.from_dict(extra["pipeline"])
        start_step = int(extra["train_step"])
        log(f"resumed from checkpoint step {s}")

    step_fn = jax.jit(make_train_step(model, opt, rules=None),
                      donate_argnums=(0,))
    extras = synth_frontend_inputs(cfg, batch)

    losses = []
    stalled = {"flag": False}
    wd = Watchdog(timeout_s=300.0,
                  on_stall=lambda idle: stalled.update(flag=True)).start()
    try:
        it = pipe.iter_from(pstate)
        end = steps if stop_after is None else min(steps, stop_after)
        for step in range(start_step, end):
            pstate, np_batch = next(it)
            batch_dev = {"tokens": jnp.asarray(np_batch["tokens"]), **extras}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_dev)
            loss = float(metrics["loss"])
            losses.append(loss)
            wd.beat()
            log(f"step {step:4d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.perf_counter() - t0) * 1e3:.0f} ms)")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, state,
                         {"pipeline": pstate.to_dict(),
                          "train_step": step + 1})
    finally:
        wd.stop()
    if mgr is not None:
        mgr.save(end, state, {"pipeline": pstate.to_dict(),
                              "train_step": end})
    return {"losses": losses, "state": state, "stalled": stalled["flag"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    out = train_loop(args.arch, steps=args.steps, batch=args.batch,
                     seq=args.seq, use_reduced=args.reduced,
                     ckpt_dir=args.ckpt_dir, lr=args.lr)
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
