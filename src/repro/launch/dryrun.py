import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production mesh and record memory/cost/collective statistics.

The two lines above MUST precede any other import (jax locks the device
count on first init); 512 placeholder host devices back both the single-pod
(16 data x 16 model = 256 chips) and the multi-pod (2 pods x 16 x 16 = 512
chips) meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all          # 40 cells
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Artifacts land in artifacts/dryrun/<arch>.<shape>.<mesh>.json.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch import roofline as RL
from repro.launch.costmodel import jaxpr_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, ShapeSpec, adjust_config,
                                 batch_input_specs, cell_is_runnable,
                                 cell_rules)
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import make_train_step
from repro.models.common import (ModelConfig, ParamDef, abstract_params,
                                 spec as rspec, with_axis_sizes)
from repro.models.transformer import Model
from repro.optim.optimizers import AdamW, constant_schedule

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _batch_shardings(mesh, rules, specs):
    def spec_for(name, sds):
        if name == "tokens":
            return P(rules.get("batch"), None)
        return P(rules.get("batch"), None, None)
    return {k: NamedSharding(mesh, spec_for(k, v)) for k, v in specs.items()}


def _tree_ns(mesh, tree_specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_override=None, cfg_override=None):
    """Lower + compile one cell; returns (record, compiled)."""
    shape = SHAPES[shape_name]
    cfg = adjust_config(get_config(arch), shape)
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    data_size = mesh.shape["data"]
    rules = cell_rules(shape, multi_pod, data_size)
    if rules_override:
        rules.update(rules_override)
    rules = with_axis_sizes(rules, mesh)
    model = Model(cfg)

    params_abs = model.abstract()
    pspecs = model.specs(rules)
    params_ns = _tree_ns(mesh, pspecs)
    in_specs = batch_input_specs(cfg, shape)
    batch_ns = _batch_shardings(mesh, rules, in_specs)

    defs = model.param_defs()
    moe_frac = 1.0
    if cfg.n_experts:
        moe_frac = (cfg.top_k + (1 if cfg.shared_expert else 0)) / cfg.n_experts
    n_total, n_active = RL.count_params(defs, {"expert_frac": moe_frac})

    t0 = time.time()
    cost = None
    with mesh:
        if shape.kind == "train":
            # bf16 optimizer moments for 100B+ models (llama4: 400B x 10B
            # per param would exceed 16GB/chip with f32 moments)
            mv = jnp.bfloat16 if n_total > 100e9 else jnp.float32
            opt = AdamW(schedule=constant_schedule(1e-4), mv_dtype=mv)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_ns = _tree_ns(mesh, opt.state_specs(pspecs))
            state_abs = {"params": params_abs, "opt": opt_abs}
            state_ns = {"params": params_ns, "opt": opt_ns}
            step = make_train_step(model, opt, rules)
            lowered = jax.jit(step, in_shardings=(state_ns, batch_ns),
                              out_shardings=(state_ns, None),
                              donate_argnums=(0,)).lower(state_abs, in_specs)
            cost = jaxpr_cost(step, state_abs, in_specs)
            tokens = shape.global_batch * shape.seq
            training = True
        elif shape.kind == "prefill":
            # cache must hold the token sequence plus any patch prefix
            step = make_prefill_step(model, rules,
                                     max_len=shape.seq + cfg.n_patches + 8)
            lowered = jax.jit(step, in_shardings=(params_ns, batch_ns),
                              ).lower(params_abs, in_specs)
            cost = jaxpr_cost(step, params_abs, in_specs)
            tokens = shape.global_batch * shape.seq
            training = False
        else:  # decode
            cache_abs = model.make_cache(shape.global_batch, shape.seq,
                                         abstract=True)
            cache_specs = _cache_pspecs(model, cache_abs, rules)
            cache_ns = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), cache_specs,
                is_leaf=lambda x: isinstance(x, P))
            step = make_serve_step(model, rules)
            lowered = jax.jit(step,
                              in_shardings=(params_ns, cache_ns,
                                            batch_ns["tokens"]),
                              out_shardings=(None, cache_ns),
                              donate_argnums=(1,)).lower(
                params_abs, cache_abs, in_specs["tokens"])
            cost = jaxpr_cost(step, params_abs, cache_abs,
                              in_specs["tokens"])
            tokens = shape.global_batch
            training = False
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "compile_us": compile_s * 1e6,
        "n_params_total": n_total,
        "n_params_active": n_active,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "roofline": RL.analyze(compiled, chips, n_active, tokens, training,
                               flops=cost.flops if cost else None,
                               hbm_bytes=cost.bytes if cost else None),
    }
    return record, compiled


def _cache_pspecs(model: Model, cache_abs, rules):
    """PartitionSpecs for the decode cache: KV seq/heads per rules; leading
    layer-stack dim unsharded; batch per rules.  Divisibility fallback is
    applied through ``rspec`` (e.g. 5 KV heads on a 16-way axis -> None)."""
    LOGICAL = {
        "k": ("batch", "cache_seq", "cache_heads", None),
        "v": ("batch", "cache_seq", "cache_heads", None),
        "k_scale": ("batch", "cache_seq", "cache_heads"),
        "v_scale": ("batch", "cache_seq", "cache_heads"),
        "ssm": ("batch", "ssm_heads", None, None),
        "h": ("batch", "rnn"),
        "conv": ("batch", None, None),
    }

    def spec_for(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1] if keys else ""
        nd = len(leaf.shape)
        axes = LOGICAL.get(name)
        if axes is None or nd < len(axes):
            return P()
        lead = nd - len(axes)        # leading layer-stack dims (unsharded)
        full = (None,) * lead + axes
        return rspec(rules, *full, shape=leaf.shape)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def optimized_overrides(arch: str, shape_name: str):
    """The winning §Perf variants, generalized to every cell:
    decode -> 2-D cache sharding + dynamic-scale int8 KV;
    MoE train/prefill -> scatter dispatch + 16k dispatch blocks;
    train/prefill -> flash-attention kernel cost substitution."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules, cfgo = {}, {}
    flash = False
    if shape.kind == "decode":
        if shape.global_batch >= 16:
            rules["cache_seq"] = "model"
        cfgo["cache_dtype"] = jnp.int8
    else:
        flash = True
        if cfg.n_experts:
            cfgo["moe_dispatch"] = "scatter"
            cfgo["moe_block"] = 16384
    return rules, cfgo, flash


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, optimized: bool = False) -> dict:
    ok, why = cell_is_runnable(arch, shape_name)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    tag = ".opt" if optimized else ""
    out_path = out_dir / f"{arch}.{shape_name}.{mesh_tag}{tag}.json"
    if not ok:
        record = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "status": "skipped", "reason": why}
    else:
        try:
            if optimized:
                rules_o, cfg_o, flash = optimized_overrides(arch, shape_name)
                record, compiled = lower_cell(arch, shape_name, multi_pod,
                                              rules_override=rules_o,
                                              cfg_override=cfg_o)
                if flash:
                    from repro.launch.hillclimb import \
                        apply_flash_substitution
                    cfg = adjust_config(get_config(arch), SHAPES[shape_name])
                    if cfg_o:
                        cfg = cfg.replace(**cfg_o)
                    record = apply_flash_substitution(record, cfg,
                                                      shape_name, skip=True)
            else:
                record, compiled = lower_cell(arch, shape_name, multi_pod)
            print(f"  memory_analysis: {compiled.memory_analysis()}")
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e}")
        except Exception as exc:
            record = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                      "status": "error", "error": f"{type(exc).__name__}: {exc}",
                      "trace": traceback.format_exc()[-2000:]}
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    r = record.get("roofline", {})
    print(f"[{record['status']:7s}] {arch} x {shape_name} x {mesh_tag}"
          + (f"  bound={r.get('bound')} frac={r.get('roofline_fraction', 0):.3f}"
             if r else (f"  ({record.get('reason', record.get('error', ''))})")))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the winning §Perf variants to every cell")
    ap.add_argument("--out", default=str(ART_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.all:
        archs = ARCHS
        shapes = list(SHAPES)
    else:
        archs = [args.arch] if args.arch else ARCHS[:1]
        shapes = [args.shape] if args.shape else ["train_4k"]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            rec = run_cell(arch, shape, args.multi_pod, out_dir,
                           optimized=args.optimized)
            if rec["status"] == "error":
                n_fail += 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
