"""Scan-aware analytical cost model over jaxprs.

XLA's ``compiled.cost_analysis()`` counts while/scan bodies ONCE (verified
empirically in this repo), which silently undercounts any scanned-layer
program by the layer count.  This walker traverses the (differentiated)
jaxpr instead, multiplying scan bodies by their trip count — the same
analytical-counting philosophy as the paper's SimDIT, applied at the jaxpr
level:

  * FLOPs: dot_general = 2 * batch * M * N * K; elementwise/reduce = 1 per
    output/input element; everything else 0.  Counted on the *global*
    (unsharded) program — the roofline divides by chip count.
  * HBM bytes: fusion-heuristic — an op's output is counted as written
    (and later read by its consumers) unless the op is a cheap elementwise
    producer with a single consumer (assumed fused by XLA).  jaxpr invars
    (params, optimizer state, batch) are counted once per consuming eqn.

Because remat/checkpoint recompute appears explicitly in the
differentiated jaxpr, the FLOP count includes the recompute waste — which
is exactly what the MODEL_FLOPS / HLO_FLOPs usefulness ratio is meant to
expose.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import numpy as np
from jax.extend import core

# ops assumed fusible into their consumer when single-consumer
FUSIBLE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "neg", "sign",
    "floor", "ceil", "round", "abs", "and", "or", "not", "xor",
    "eq", "ne", "ge", "gt", "le", "lt", "select_n", "clamp",
    "convert_element_type", "broadcast_in_dim", "reshape", "transpose",
    "squeeze", "expand_dims", "slice", "rev", "iota", "erf",
    "stop_gradient", "copy", "real", "imag",
}

ZERO_FLOP = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "slice", "rev", "iota", "convert_element_type", "stop_gradient",
    "copy", "concatenate", "pad", "gather", "scatter", "dynamic_slice",
    "dynamic_update_slice", "select_n", "eq", "ne", "ge", "gt", "le",
    "lt", "and", "or", "not", "xor", "sign", "floor", "ceil", "round",
    "argmax", "argmin", "reduce_or", "reduce_and",
}

EXPENSIVE_ELEMWISE = {"exp": 1, "log": 1, "tanh": 1, "logistic": 1,
                      "rsqrt": 1, "sqrt": 1, "div": 1, "pow": 1, "erf": 1}

CALL_PRIMS = {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
              "custom_vjp_call_jaxpr", "remat", "remat2", "checkpoint",
              "custom_lin", "core_call", "xla_call"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)


def _dot_flops(eqn) -> float:
    (contract, batch) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    rhs = eqn.invars[1].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in contract[0]:
        k *= lhs.shape[d]
    return 2.0 * _size(out) * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval      # kernel
    dn = eqn.params["dimension_numbers"]
    kernel_spatial = [rhs.shape[d] for d in dn.rhs_spec[2:]]
    cin = rhs.shape[dn.rhs_spec[1]]
    per_out = 2.0 * cin * float(np.prod(kernel_spatial))
    return _size(out) * per_out


def _consumers(jaxpr) -> Dict[int, int]:
    count: Dict[int, int] = {}
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if isinstance(v, core.Var):
                count[id(v)] = count.get(id(v), 0) + 1
    for v in jaxpr.outvars:
        if isinstance(v, core.Var):
            count[id(v)] = count.get(id(v), 0) + 1
    return count


def _walk(jaxpr, mult: float = 1.0) -> Cost:
    total = Cost()
    consumers = _consumers(jaxpr)
    producers: Dict[int, str] = {}

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        # ---- recurse into sub-jaxprs -------------------------------------
        if name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            length = float(eqn.params["length"])
            total += _walk(body, mult * length)
            # scan I/O (xs slices + ys stacking + carry churn per step)
            io_bytes = sum(_bytes(v.aval) for v in eqn.invars) \
                + sum(_bytes(v.aval) for v in eqn.outvars)
            total += Cost(0.0, io_bytes * mult)
            for v in eqn.outvars:
                producers[id(v)] = name
            continue
        if name == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            total += _walk(body, mult)      # trip count unknown: 1x, flagged
            continue
        if name == "cond":
            for br in eqn.params["branches"]:
                total += _walk(br.jaxpr, mult / max(1, len(
                    eqn.params["branches"])))
            continue
        sub = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in eqn.params:
                sub = eqn.params[key]
                break
        if sub is not None:
            sub_jaxpr = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            total += _walk(sub_jaxpr, mult)
            for v in eqn.outvars:
                producers[id(v)] = "call"
            continue

        # ---- flops --------------------------------------------------------
        flops = 0.0
        if name == "dot_general":
            flops = _dot_flops(eqn)
        elif name == "conv_general_dilated":
            flops = _conv_flops(eqn)
        elif name in ("reduce_sum", "reduce_max", "reduce_min",
                      "reduce_prod", "cumsum", "cumlogsumexp", "cummax"):
            flops = float(_size(eqn.invars[0].aval))
        elif name in ZERO_FLOP:
            flops = 0.0
        else:
            out_elems = float(sum(_size(v.aval) for v in eqn.outvars))
            flops = out_elems * EXPENSIVE_ELEMWISE.get(name, 1)

        # ---- bytes (fusion heuristic) --------------------------------------
        by = 0.0
        fused_out = (name in FUSIBLE
                     and all(consumers.get(id(v), 0) <= 1
                             for v in eqn.outvars))
        if not fused_out:
            by += sum(_bytes(v.aval) for v in eqn.outvars)
        for v in eqn.invars:
            if isinstance(v, core.Literal):
                continue
            prod = producers.get(id(v))
            if prod is None:
                by += _bytes(v.aval)          # jaxpr invar / const
            elif prod == "materialized":
                by += _bytes(v.aval)
        total += Cost(flops * mult, by * mult)
        tag = "fused" if fused_out else "materialized"
        for v in eqn.outvars:
            producers[id(v)] = tag
    return total


def jaxpr_cost(fn, *abstract_args, **abstract_kwargs) -> Cost:
    """Trace ``fn`` with abstract args and walk the resulting jaxpr."""
    closed = jax.make_jaxpr(fn)(*abstract_args, **abstract_kwargs)
    return _walk(closed.jaxpr)
