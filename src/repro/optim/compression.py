"""Gradient compression for cross-pod all-reduce: int8 block quantization
with error feedback.

At 1000+-node scale the cross-pod (DCN) gradient reduce is the scarcest
bandwidth; quantizing the pod-level gradient to int8 with per-block scales
cuts that traffic 4x (bf16 -> int8 + 1 scale / 256 values).  Error feedback
(residual carried to the next step) keeps SGD convergence unbiased in
practice.  Implemented as a pure function pair so it drops into the train
step around the ``psum`` over the ``pod`` axis.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """g + err -> (int8 values, f32 scales per block, new error)."""
    comp = g.astype(jnp.float32) + err
    flat, _ = _pad_to_block(comp)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(flat.shape)[
        :comp.size].reshape(comp.shape)
    new_err = comp - deq
    return q, scale[:, 0], new_err


def dequantize(q: jax.Array, scale: jax.Array, shape, size: int
               ) -> jax.Array:
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    return deq.reshape(shape)


def compressed_psum(tree, err_tree, axis_name: str):
    """All-reduce ``tree`` over ``axis_name`` in int8 with error feedback.

    Returns (reduced f32 tree, new error tree).  The int8 values and f32
    scales are what actually cross the interconnect (4x less than bf16;
    scales add 1/256 overhead)."""
    def one(g, err):
        q, scale, new_err = quantize(g, err)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # scales differ per participant -> reduce the dequantized mean scale
        scale_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        avg_scale = scale_sum / n
        deq = (q_sum.astype(jnp.float32) / n * avg_scale[:, None]
               ).reshape(-1)[:g.size].reshape(g.shape)
        return deq * n, new_err   # sum semantics like plain psum

    flat_g, tdef = jax.tree_util.tree_flatten(tree)
    flat_e = tdef.flatten_up_to(err_tree)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
