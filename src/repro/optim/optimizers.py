"""Optimizers (AdamW, SGD+momentum), LR schedules, global-norm clipping.

Self-contained (no optax dependency).  Optimizer state is a pytree shaped
like the parameters, so the same ``param_specs`` sharding rules apply —
m/v are sharded exactly like their parameters (ZeRO over the FSDP axis
comes for free).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(base_lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


# ---------------------------------------------------------------------------
# Clipping
# ---------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # moment dtype: f32 default; bf16 halves optimizer HBM (standard for
    # 100B+ models — the llama4-maverick cell needs it to fit 16GB/chip)
    mv_dtype: Any = jnp.float32

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self.mv_dtype)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        step = state["step"] + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (m_new.astype(self.mv_dtype),
                    v_new.astype(self.mv_dtype),
                    (p.astype(jnp.float32) - lr * delta).astype(p.dtype))

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        new_m = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        new_p = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}

    def state_specs(self, pspecs):
        """Optimizer-state PartitionSpecs mirroring the param specs."""
        from jax.sharding import PartitionSpec as P
        return {
            "m": pspecs,
            "v": pspecs,
            "step": P(),
        }


@dataclass(frozen=True)
class SGDM:
    schedule: Callable
    momentum: float = 0.9
    clip_norm: float = 0.0

    def init(self, params):
        return {
            "mom": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        gnorm = global_norm(grads)
        if self.clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        step = state["step"] + 1
        lr = self.schedule(step)

        def upd(g, m, p):
            m_new = self.momentum * m + g.astype(jnp.float32)
            return m_new, (p.astype(jnp.float32) - lr * m_new).astype(p.dtype)

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state["mom"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        new_m = tdef.unflatten([o[0] for o in out])
        new_p = tdef.unflatten([o[1] for o in out])
        return new_p, {"mom": new_m, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}

    def state_specs(self, pspecs):
        from jax.sharding import PartitionSpec as P
        return {"mom": pspecs, "step": P()}
