"""Source loading for the analysis passes: parsed AST with parent links
plus the line-level annotations the passes consume.

Recognized trailing comments:

``# guarded-by: <lock>``
    On an assignment, declares the assigned module-global (or
    ``self.<attr>`` instance attribute) as shared state that must only
    be accessed while holding ``<lock>`` (a name like ``_CACHE_LOCK`` or
    a dotted expression like ``self._lock``).

``# holds-lock: <lock>``
    On a ``def``, declares a caller-holds-lock helper: the body is
    analyzed as if it ran inside ``with <lock>:``.  The ``_locked`` name
    suffix alone also marks a helper, but without naming the lock it
    merely exempts the body from guarded-access checks.

``# analysis: allow[CODE]`` / ``# analysis: allow[pass]``
    Waives findings with that code (or from that pass) on this line.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w.]*)")
_HOLDS_RE = re.compile(r"#\s*holds-lock:\s*([A-Za-z_][\w.]*)")
_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\[([^\]]+)\]")


def scope_name(node: ast.AST) -> str:
    """Dotted name of the enclosing defs/classes (fingerprint anchor)."""
    parts: List[str] = []
    n = getattr(node, "parent", None)
    while n is not None:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            parts.append(n.name)
        n = getattr(n, "parent", None)
    return ".".join(reversed(parts)) or "<module>"


def expr_text(node: ast.AST) -> str:
    """Minimal unparse for lock expressions and call targets: dotted
    Name/Attribute chains (``self._lock``, ``faultinject.fire``); other
    shapes render as ``<expr>`` and never match a declared lock."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return f"{expr_text(node.value)}.{node.attr}"
    if isinstance(node, ast.Call):
        return f"{expr_text(node.func)}()"
    return "<expr>"


@dataclass
class SourceFile:
    path: Path                       # as given (absolute or relative)
    rel: str                         # repo-relative posix path
    text: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)
    guards: Dict[int, str] = field(default_factory=dict)      # line -> lock
    holds: Dict[int, str] = field(default_factory=dict)       # line -> lock
    allow: Dict[int, Set[str]] = field(default_factory=dict)  # line -> tokens

    @classmethod
    def parse(cls, path: Path, rel: Optional[str] = None) -> "SourceFile":
        text = Path(path).read_text()
        tree = ast.parse(text, filename=str(path))
        for node in ast.walk(tree):          # parent links for scope lookup
            for child in ast.iter_child_nodes(node):
                child.parent = node          # type: ignore[attr-defined]
        sf = cls(path=Path(path), rel=rel or Path(path).as_posix(),
                 text=text, tree=tree, lines=text.splitlines())
        for i, line in enumerate(sf.lines, start=1):
            if "#" not in line:
                continue
            if (m := _GUARDED_RE.search(line)):
                sf.guards[i] = m.group(1)
            if (m := _HOLDS_RE.search(line)):
                sf.holds[i] = m.group(1)
            if (m := _ALLOW_RE.search(line)):
                sf.allow[i] = {t.strip() for t in m.group(1).split(",")}
        return sf

    def allowed(self, line: int, code: str, pass_id: str) -> bool:
        toks = self.allow.get(line, ())
        return bool(toks) and bool({code, pass_id, "*"} & set(toks))

    def matches(self, suffix: str) -> bool:
        """Path-suffix match used by the manifest scoping (so
        ``repro/core/dse.py`` matches ``src/repro/core/dse.py``)."""
        return self.rel.endswith(suffix)


def collect_sources(paths: Iterable[Path],
                    root: Optional[Path] = None) -> List[SourceFile]:
    """Parse every ``.py`` under ``paths`` (files or directories),
    relativized against ``root`` (default: cwd) for stable finding
    paths.  Files that fail to parse are skipped — syntax errors are the
    interpreter's job, not this suite's."""
    root = Path(root) if root is not None else Path.cwd()
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    out: List[SourceFile] = []
    for f in files:
        try:
            rel = f.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            out.append(SourceFile.parse(f, rel=rel))
        except SyntaxError:
            continue
    return out
