"""Pass 3 — x64-guard check on the device-backend modules.

jax defaults to int32/float32; the grid backends carry int64 cycle
counts, so every public entry point that touches jax/jnp/pallas must run
under ``jax.experimental.enable_x64()`` — via the ``@_x64`` decorator or
by wrapping its whole body in ``with enable_x64():``.  An unguarded
entry silently truncates grids past 2**31.

``X64001``  a public function in an ``x64_modules`` file touches a
            numeric root (``jnp``/``pl``/``pltpu``/``jax.jit``/...) or an
            unguarded module-level jit binding without the guard.
``X64002``  a module-level binding wraps a jax transform
            (``jax.jit(...)``) without the guard wrapper
            (``_x64(jax.jit(...))``).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from .manifest import Manifest
from .report import Finding
from .source import SourceFile, expr_text

PASS_ID = "x64"


def _contains_jax_transform(node: ast.AST, manifest: Manifest) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute):
            text = expr_text(n)
            parts = text.split(".")
            if parts[0] == "jax" and len(parts) > 1 \
                    and parts[1] in manifest.x64_jax_attrs:
                return True
        if isinstance(n, ast.Name) and n.id in manifest.x64_numeric_roots:
            return True
    return False


def _guard_wrapped(value: ast.AST, manifest: Manifest) -> bool:
    """``_x64(jax.jit(...))`` — outermost call is the guard wrapper."""
    return (isinstance(value, ast.Call)
            and expr_text(value.func).split(".")[-1]
            in manifest.x64_guard_decorators)


def _is_guarded(fn: ast.FunctionDef, manifest: Manifest) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if expr_text(target).split(".")[-1] in manifest.x64_guard_decorators:
            return True
    body = fn.body
    if body and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Constant) \
            and isinstance(body[0].value.value, str):
        body = body[1:]
    if len(body) == 1 and isinstance(body[0], ast.With):
        for item in body[0].items:
            text = expr_text(item.context_expr).removesuffix("()")
            if text.split(".")[-1] == manifest.x64_guard_context:
                return True
    return False


def _device_use(fn: ast.FunctionDef, manifest: Manifest,
                unguarded_bindings: Set[str]) -> Optional[str]:
    for n in ast.walk(fn):
        if isinstance(n, ast.Name):
            if n.id in manifest.x64_numeric_roots:
                return f"uses {n.id!r}"
            if n.id in unguarded_bindings:
                return f"calls unguarded binding {n.id!r}"
        if isinstance(n, ast.Attribute):
            text = expr_text(n)
            parts = text.split(".")
            if parts[0] in manifest.x64_numeric_roots:
                return f"uses {text!r}"
            if parts[0] == "jax" and len(parts) > 1 \
                    and parts[1] in manifest.x64_jax_attrs:
                return f"uses {text!r}"
    return None


def run(files: Sequence[SourceFile], manifest: Manifest) -> List[Finding]:
    findings: List[Finding] = []
    for sf in files:
        if not any(sf.matches(m) for m in manifest.x64_modules):
            continue
        unguarded: Set[str] = set()
        for node in sf.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and _contains_jax_transform(node.value, manifest):
                name = node.targets[0].id
                if not _guard_wrapped(node.value, manifest):
                    unguarded.add(name)
                    findings.append(Finding(
                        sf.rel, node.lineno, node.col_offset, PASS_ID,
                        "X64002",
                        f"module binding {name!r} wraps a jax transform "
                        f"without the x64 guard "
                        f"({manifest.x64_guard_decorators[0]}(...))",
                        symbol=name))
        for node in sf.tree.body:
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name.startswith("_"):
                continue
            if _is_guarded(node, manifest):
                continue
            reason = _device_use(node, manifest, unguarded)
            if reason is not None:
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset, PASS_ID, "X64001",
                    f"public entry {node.name!r} {reason} without the x64 "
                    f"guard: int64 grids truncate to int32",
                    symbol=node.name))
    return findings
