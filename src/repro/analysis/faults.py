"""Pass 4 — fault-point consistency.

``core.faultinject`` identifies fault points by bare strings; a typo at
an injection site (or in a test's ``arm(...)``/``REPRO_FAULTS`` spec)
silently disables the fault — the recovery test then passes by testing
nothing.  This pass cross-checks three sets of names:

``FP000``  the registry (``FAULT_POINTS``) is missing from the fault
           module entirely.
``FP001``  a string point passed to ``fire``/``arm``/``armed``/
           ``fired``/``disarm`` (in src *or* tests) is not registered.
``FP002``  a registered point is never ``fire``d anywhere in src — dead
           registry entry or missing injection site.
``FP003``  a registered point never appears in any test (string scan,
           splitting ``REPRO_FAULTS``-style ``a:2,b`` specs) — the
           recovery path is never exercised.  Skipped when the analyzed
           fileset contains no tests directory.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .manifest import Manifest
from .report import Finding
from .source import SourceFile, expr_text

PASS_ID = "faults"


def _fault_aliases(sf: SourceFile, manifest: Manifest
                   ) -> Tuple[Dict[str, str], Set[str]]:
    """(direct imports name->orig fn, module aliases)."""
    direct: Dict[str, str] = {}
    mods: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == "faultinject":      # from . import faultinject
                    mods.add(a.asname or a.name)
                elif node.module and node.module.endswith("faultinject"):
                    direct[a.asname or a.name] = a.name
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.endswith("faultinject"):
                    mods.add(a.asname or a.name.rsplit(".", 1)[-1])
    return direct, mods


def _fault_call(node: ast.Call, direct: Dict[str, str], mods: Set[str],
                manifest: Manifest) -> Optional[str]:
    text = expr_text(node.func)
    parts = text.split(".")
    if len(parts) == 1:
        orig = direct.get(parts[0])
        if orig in manifest.fault_call_names:
            return orig
    elif len(parts) >= 2 and parts[-2] in mods \
            and parts[-1] in manifest.fault_call_names:
        return parts[-1]
    return None


def run(files: Sequence[SourceFile], manifest: Manifest) -> List[Finding]:
    findings: List[Finding] = []
    fault_sf = next((sf for sf in files
                     if sf.matches(manifest.fault_module)), None)
    if fault_sf is None:
        return findings

    registry: Dict[str, int] = {}
    found = False
    for node in fault_sf.tree.body:
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target] if isinstance(node, ast.AnnAssign) else []
        if any(isinstance(t, ast.Name)
               and t.id == manifest.fault_registry_name
               for t in targets) \
                and isinstance(node.value, ast.Dict):
            found = True
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    registry[k.value] = k.lineno
    if not found:
        findings.append(Finding(
            fault_sf.rel, 1, 0, PASS_ID, "FP000",
            f"fault registry {manifest.fault_registry_name!r} not found "
            f"in the fault module",
            symbol=manifest.fault_registry_name))
        return findings

    def is_test(sf: SourceFile) -> bool:
        return f"/{manifest.tests_dir_name}/" in f"/{sf.rel}"

    fired_in_src: Set[str] = set()
    for sf in files:
        if sf is fault_sf:
            continue
        direct, mods = _fault_aliases(sf, manifest)
        if not direct and not mods:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = _fault_call(node, direct, mods, manifest)
            if fname is None or not node.args:
                continue
            arg = node.args[0]
            if not (isinstance(arg, ast.Constant)
                    and isinstance(arg.value, str)):
                continue
            point = arg.value
            if fname == "fire" and not is_test(sf):
                fired_in_src.add(point)
            if point not in registry:
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset, PASS_ID, "FP001",
                    f"unknown fault point {point!r} passed to {fname}() — "
                    f"not in {manifest.fault_registry_name}",
                    symbol=point))

    for point, line in sorted(registry.items()):
        if point not in fired_in_src:
            findings.append(Finding(
                fault_sf.rel, line, 0, PASS_ID, "FP002",
                f"registered fault point {point!r} is never fired from "
                f"src — dead entry or missing injection site",
                symbol=point))

    test_files = [sf for sf in files if is_test(sf)]
    if test_files:
        covered: Set[str] = set()
        for sf in test_files:
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Constant) \
                        and isinstance(node.value, str):
                    for tok in re.split(r"[,:]", node.value):
                        covered.add(tok.strip())
        for point, line in sorted(registry.items()):
            if point not in covered:
                findings.append(Finding(
                    fault_sf.rel, line, 0, PASS_ID, "FP003",
                    f"registered fault point {point!r} is never armed in "
                    f"any test — recovery path unexercised",
                    symbol=point))
    return findings
