"""Pass 5 — determinism lint on the pricing paths.

The serving tier dedups identical queries on the promise that the same
query always prices to the same answer, and the persistence layer's
self-check re-derives stored points expecting bit-identical cycles.
Within the manifest's ``determinism_modules``:

``DT001``  wall-clock reads (``time.time``, ``datetime.now``, ...) —
           ``time.monotonic``/``perf_counter`` stay legal (timeouts are
           not priced).
``DT002``  unseeded randomness: ``np.random.default_rng()``/``Random()``
           with no seed, or any call on the global ``random``/
           ``np.random`` state.
``DT003``  iteration over a set (``for``/``list()``/``tuple()``) —
           nondeterministic order under hash randomization; wrap in
           ``sorted(...)``.
``DT004``  builtin ``hash()`` — varies per process under
           ``PYTHONHASHSEED`` randomization.
"""
from __future__ import annotations

import ast
from typing import List, Sequence, Set

from .manifest import Manifest
from .report import Finding
from .source import SourceFile, expr_text, scope_name

PASS_ID = "determinism"


def _is_set_expr(e: ast.AST, setvars: Set[str]) -> bool:
    if isinstance(e, (ast.Set, ast.SetComp)):
        return True
    if isinstance(e, ast.Call) and expr_text(e.func) in ("set", "frozenset"):
        return True
    return isinstance(e, ast.Name) and e.id in setvars


def _local_nodes(scope: ast.AST) -> List[ast.AST]:
    """Nodes of one scope, not descending into nested defs/lambdas
    (their locals are their own; checked in their own scope walk)."""
    out: List[ast.AST] = []
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            continue
        out.append(n)
        stack.extend(ast.iter_child_nodes(n))
    return out


def _set_findings(sf: SourceFile, scope: ast.AST) -> List[Finding]:
    local = _local_nodes(scope)
    setvars: Set[str] = set()
    for n in local:
        if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                and isinstance(n.targets[0], ast.Name) \
                and _is_set_expr(n.value, setvars):
            setvars.add(n.targets[0].id)
    out: List[Finding] = []
    for n in local:
        bad = None
        if isinstance(n, ast.For) and _is_set_expr(n.iter, setvars):
            bad = "iteration over a set"
        elif isinstance(n, ast.Call) \
                and expr_text(n.func) in ("list", "tuple") \
                and n.args and _is_set_expr(n.args[0], setvars):
            bad = f"{expr_text(n.func)}() over a set"
        if bad is not None:
            out.append(Finding(
                sf.rel, n.lineno, n.col_offset, PASS_ID, "DT003",
                f"{bad} has nondeterministic order under hash "
                f"randomization; wrap in sorted(...)",
                symbol=f"{scope_name(n)}:set-iter"))
    return out


def run(files: Sequence[SourceFile], manifest: Manifest) -> List[Finding]:
    findings: List[Finding] = []
    for sf in files:
        if not any(sf.matches(m) for m in manifest.determinism_modules):
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            text = expr_text(node.func)
            if text in manifest.banned_clock_calls:
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset, PASS_ID, "DT001",
                    f"wall-clock read {text}() in a pricing path",
                    symbol=f"{scope_name(node)}:{text}"))
                continue
            if text == "hash":
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset, PASS_ID, "DT004",
                    "builtin hash() varies per process under "
                    "PYTHONHASHSEED randomization",
                    symbol=f"{scope_name(node)}:hash"))
                continue
            for root in manifest.banned_rng_roots:
                # pure dotted chains only: a call in the middle
                # ("random.Random(seed).random") is an instance method
                # on a seeded RNG, not the module-global state
                if "(" in text or not text.startswith(root + "."):
                    continue
                last = text.rsplit(".", 1)[-1]
                if last in manifest.seeded_rng_ctors:
                    if not node.args and not node.keywords:
                        findings.append(Finding(
                            sf.rel, node.lineno, node.col_offset, PASS_ID,
                            "DT002",
                            f"unseeded RNG constructor {text}() in a "
                            f"pricing path", symbol=f"{scope_name(node)}:"
                                                    f"{text}"))
                else:
                    findings.append(Finding(
                        sf.rel, node.lineno, node.col_offset, PASS_ID,
                        "DT002",
                        f"call on the global (unseeded) RNG state: {text}",
                        symbol=f"{scope_name(node)}:{text}"))
                break
        findings.extend(_set_findings(sf, sf.tree))
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(_set_findings(sf, node))
    return findings
