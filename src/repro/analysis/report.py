"""Findings, fingerprints, and the baseline ratchet.

A ``Finding`` is one violation at one source location.  Its
*fingerprint* deliberately excludes the line number — it hashes the pass,
code, file, and the enclosing scope/symbol — so unrelated edits that
shift lines do not churn the baseline; only the k-th identical violation
in the same scope gets a ``#k`` suffix.  The baseline file maps
fingerprints to their last-seen location: CI fails on fingerprints not
in the baseline (*new* violations) and reports baseline entries that no
longer occur (*stale* — ratchet the file down with ``--write-baseline``).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Sequence, Tuple


@dataclass(frozen=True, order=True)
class Finding:
    """One violation: location + pass/code + human message + the stable
    ``symbol`` anchor (enclosing scope and offending name) that makes its
    fingerprint survive line drift."""
    path: str                  # repo-relative posix path
    line: int
    col: int
    pass_id: str               # "locks" | "exact" | "x64" | "faults" | "determinism"
    code: str                  # e.g. "LOCK001"
    message: str
    symbol: str = ""           # "Scope.func:name" — fingerprint anchor

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def render(self) -> str:
        return f"{self.location()}: {self.code} [{self.pass_id}] {self.message}"


def fingerprints(findings: Sequence[Finding]) -> Dict[str, Finding]:
    """Stable fingerprint per finding: hash of (pass, code, path, symbol)
    plus an occurrence counter for repeats of the same anchor."""
    seen: Dict[str, int] = {}
    out: Dict[str, Finding] = {}
    for f in sorted(findings):
        base = f"{f.pass_id}|{f.code}|{f.path}|{f.symbol}"
        h = hashlib.sha256(base.encode()).hexdigest()[:16]
        k = seen.get(h, 0)
        seen[h] = k + 1
        out[h if k == 0 else f"{h}#{k}"] = f
    return out


@dataclass
class Baseline:
    """The committed known-violations file (``analysis-baseline.json``)."""
    version: int = 1
    findings: Dict[str, dict] = field(default_factory=dict)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not Path(path).exists():
            return cls()
        data = json.loads(Path(path).read_text())
        return cls(version=int(data.get("version", 1)),
                   findings=dict(data.get("findings", {})))

    @classmethod
    def from_findings(cls, findings: Sequence[Finding]) -> "Baseline":
        return cls(findings={fp: asdict(f)
                             for fp, f in fingerprints(findings).items()})

    def save(self, path: Path) -> None:
        payload = {"version": self.version,
                   "findings": {fp: self.findings[fp]
                                for fp in sorted(self.findings)}}
        Path(path).write_text(json.dumps(payload, indent=2,
                                         sort_keys=True) + "\n")


def diff_against_baseline(findings: Sequence[Finding], baseline: Baseline
                          ) -> Tuple[Dict[str, Finding], List[str]]:
    """``(new, stale)``: findings whose fingerprint the baseline does not
    know (CI failures), and baseline fingerprints no longer produced
    (candidates for ratcheting the baseline down)."""
    fps = fingerprints(findings)
    new = {fp: f for fp, f in fps.items() if fp not in baseline.findings}
    stale = [fp for fp in baseline.findings if fp not in fps]
    return new, stale


def findings_to_json(findings: Sequence[Finding]) -> dict:
    """Machine-readable report payload (the CI artifact)."""
    fps = fingerprints(findings)
    per_pass: Dict[str, int] = {}
    for f in findings:
        per_pass[f.pass_id] = per_pass.get(f.pass_id, 0) + 1
    return {
        "total": len(findings),
        "by_pass": per_pass,
        "findings": [dict(asdict(f), fingerprint=fp)
                     for fp, f in fps.items()],
    }
