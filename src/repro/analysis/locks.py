"""Pass 1 — lock-discipline race detector.

Checks three things over the ``# guarded-by:`` registry (see
``source.py`` for the annotation grammar):

``LOCK001``  a guarded module-global (or guarded ``self.<attr>``) is
             read or written outside a ``with <lock>:`` scope, outside a
             ``# holds-lock:``-annotated / ``_locked``-suffixed
             caller-holds-lock helper, and outside ``__init__``
             (construction happens-before publication).
``LOCK002``  a ``_locked``-suffixed helper is *called* while no declared
             lock is held.
``LOCK003``  lock-order violation: lock B acquired (directly or through
             a resolved call) while holding lock A, where the manifest's
             global order does not place A strictly before B — the
             static ABBA/deadlock check.
``LOCK004``  a ``guarded-by``/``holds-lock`` annotation names a lock
             never acquired anywhere in that file (typo guard).

Scope rules are conservative and syntactic: entering a nested ``def`` or
``lambda`` clears the held-lock stack (closures execute later, not under
the enclosing ``with``), and module/class body statements are exempt
(import is single-threaded).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .manifest import Manifest
from .report import Finding
from .source import SourceFile, expr_text

PASS_ID = "locks"


@dataclass
class _Guard:
    name: str                  # global name, or attr for instance guards
    lock: str                  # lock expression text as annotated
    cls: Optional[str] = None  # owning class for self.<attr> guards
    line: int = 0


@dataclass
class _Func:
    qual: str                  # "<rel>:<Class.>name"
    node: ast.AST
    sf: SourceFile
    cls: Optional[str]
    direct_locks: Set[str] = field(default_factory=set)   # lock ids
    calls: List[str] = field(default_factory=list)        # rendered call texts


def _lock_id(sf: SourceFile, cls: Optional[str], text: str) -> str:
    """Canonical id of a lock expression in a given file/class scope."""
    if text.endswith("()"):
        text = text[:-2]
    if text.startswith("self.") and cls:
        return f"{sf.rel}:{cls}.{text}"
    return f"{sf.rel}:{text}"


def _order_index(manifest: Manifest, lock_id: str) -> Optional[int]:
    """Position of a lock in the declared total order.  Matching is by
    the name part — a lock imported into another file keeps its
    identity — with the path part disambiguating duplicate names."""
    lpath, _, lname = lock_id.partition(":")
    cands = [(i, e) for i, e in enumerate(manifest.lock_order)
             if e.partition(":")[2] == lname]
    if len(cands) == 1:
        return cands[0][0]
    for i, e in cands:
        if lpath.endswith(e.partition(":")[0]):
            return i
    return None


def _collect_guards(sf: SourceFile) -> Tuple[Dict[str, _Guard],
                                             Dict[Tuple[str, str], _Guard]]:
    """(module-global guards by name, instance guards by (class, attr))."""
    globals_: Dict[str, _Guard] = {}
    instance: Dict[Tuple[str, str], _Guard] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        lock = sf.guards.get(node.lineno)
        if lock is None:
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        cls = _enclosing_class(node)
        for t in targets:
            if isinstance(t, ast.Name) and cls is None:
                globals_[t.id] = _Guard(t.id, lock, line=node.lineno)
            elif (isinstance(t, ast.Attribute)
                  and isinstance(t.value, ast.Name)
                  and t.value.id == "self" and cls is not None):
                instance[(cls, t.attr)] = _Guard(t.attr, lock, cls,
                                                 node.lineno)
    return globals_, instance


def _enclosing_class(node: ast.AST) -> Optional[str]:
    n = getattr(node, "parent", None)
    while n is not None:
        if isinstance(n, ast.ClassDef):
            return n.name
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # keep climbing: methods report their class
            pass
        n = getattr(n, "parent", None)
    return None


def _functions(sf: SourceFile) -> List[_Func]:
    out = []
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            cls = _enclosing_class(node)
            qual = f"{sf.rel}:{cls + '.' if cls else ''}{node.name}"
            out.append(_Func(qual, node, sf, cls))
    return out


def _is_exempt(fn: ast.AST, sf: SourceFile, manifest: Manifest) -> bool:
    name = getattr(fn, "name", "")
    if name in ("__init__", "__del__", "__new__"):
        return True
    return (name.endswith(manifest.locked_suffix)
            and fn.lineno not in sf.holds)


class _FnVisitor(ast.NodeVisitor):
    """Walks ONE function body tracking the held-lock stack; records
    guarded accesses, direct acquisitions, and rendered calls."""

    def __init__(self, fn: _Func, manifest: Manifest,
                 globals_: Dict[str, _Guard],
                 instance: Dict[Tuple[str, str], _Guard]):
        self.fn = fn
        self.manifest = manifest
        self.globals = globals_
        self.instance = instance
        self.held_texts: List[str] = []      # lock exprs as written
        self.violations: List[Finding] = []
        held = fn.sf.holds.get(fn.node.lineno)
        if held is not None:
            self.held_texts.append(held)
        self.exempt = _is_exempt(fn.node, fn.sf, manifest)

    # -- helpers ------------------------------------------------------------

    def _finding(self, node: ast.AST, code: str, msg: str,
                 symbol: str) -> None:
        self.violations.append(Finding(
            self.fn.sf.rel, node.lineno, node.col_offset, PASS_ID, code,
            msg, symbol=f"{self.fn.qual}:{symbol}"))

    def _holding(self, lock_text: str) -> bool:
        return lock_text in self.held_texts

    # -- traversal ----------------------------------------------------------

    def run(self) -> None:
        node = self.fn.node
        for stmt in node.body:
            self.visit(stmt)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested defs execute later, not under the enclosing with
        return

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        return

    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            text = expr_text(item.context_expr)
            if text.endswith("()"):
                base = text[:-2]
                if base.split(".")[-1].endswith(self.manifest.locked_suffix):
                    self.held_texts.append(text)
                    pushed += 1
            else:
                self.held_texts.append(text)
                pushed += 1
            lock_id = _lock_id(self.fn.sf, self.fn.cls, text)
            if _order_index(self.manifest, lock_id) is not None:
                self.fn.direct_locks.add(lock_id)
                self._order_check(node, lock_id, pushed)
        for stmt in node.body:
            self.visit(stmt)
        del self.held_texts[len(self.held_texts) - pushed:]

    def _order_check(self, node: ast.AST, acquired: str,
                     pushed_now: int) -> None:
        ai = _order_index(self.manifest, acquired)
        for held_text in self.held_texts[:len(self.held_texts) - pushed_now]:
            held_id = _lock_id(self.fn.sf, self.fn.cls, held_text)
            hi = _order_index(self.manifest, held_id)
            if hi is None or held_id == acquired:
                continue
            if ai is not None and hi >= ai:
                self._finding(
                    node, "LOCK003",
                    f"acquires {acquired.split(':')[-1]} while holding "
                    f"{held_id.split(':')[-1]}: violates the declared lock "
                    f"order", symbol=f"{held_id}->{acquired}")

    def visit_Call(self, node: ast.Call) -> None:
        text = expr_text(node.func)
        self.fn.calls.append(text)
        callee = text.split(".")[-1]
        if (callee.endswith(self.manifest.locked_suffix)
                and not self.held_texts and not self.exempt
                and not isinstance(getattr(node, "parent", None), ast.With)
                and not (isinstance(getattr(node, "parent", None),
                                    ast.withitem))):
            self._finding(node, "LOCK002",
                          f"call to caller-holds-lock helper {callee!r} "
                          f"with no lock held", symbol=callee)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        g = self.globals.get(node.id)
        if g is not None and not self.exempt \
                and node.lineno != g.line and not self._holding(g.lock):
            self._finding(node, "LOCK001",
                          f"access to {node.id!r} (guarded by {g.lock}) "
                          f"without holding the lock", symbol=node.id)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (isinstance(node.value, ast.Name) and node.value.id == "self"
                and self.fn.cls is not None):
            g = self.instance.get((self.fn.cls, node.attr))
            if g is not None and not self.exempt \
                    and node.lineno != g.line \
                    and not self._holding(g.lock):
                self._finding(
                    node, "LOCK001",
                    f"access to self.{node.attr!r} (guarded by {g.lock}) "
                    f"without holding the lock", symbol=f"self.{node.attr}")
        self.generic_visit(node)


def _resolve_call(text: str, fn: _Func, funcs_by_qual: Dict[str, _Func],
                  by_file_name: Dict[Tuple[str, str], _Func],
                  by_stem_name: Dict[Tuple[str, str], _Func],
                  manifest: Manifest) -> Optional[_Func]:
    hint = manifest.call_patterns.get(text)
    if hint is not None:
        hpath, _, hname = hint.partition(":")
        for qual, f in funcs_by_qual.items():
            qpath, _, qname = qual.partition(":")
            if qname == hname and qpath.endswith(hpath):
                return f
        return None
    parts = text.split(".")
    if len(parts) == 1:
        return by_file_name.get((fn.sf.rel, parts[0]))
    if parts[0] == "self" and len(parts) == 2 and fn.cls:
        return funcs_by_qual.get(f"{fn.sf.rel}:{fn.cls}.{parts[1]}")
    if len(parts) == 2:
        return by_stem_name.get((parts[0], parts[1]))
    return None


def run(files: Sequence[SourceFile], manifest: Manifest) -> List[Finding]:
    findings: List[Finding] = []
    all_funcs: List[_Func] = []
    for sf in files:
        globals_, instance = _collect_guards(sf)
        funcs = _functions(sf)
        all_funcs.extend(funcs)
        if globals_ or instance:
            # LOCK004: annotated locks never acquired in this file
            acquired_texts = {expr_text(i.context_expr).removesuffix("()")
                              for n in ast.walk(sf.tree)
                              if isinstance(n, ast.With) for i in n.items}
            for g in list(globals_.values()) + list(instance.values()):
                if g.lock.removesuffix("()") not in acquired_texts \
                        and g.lock not in sf.holds.values():
                    findings.append(Finding(
                        sf.rel, g.line, 0, PASS_ID, "LOCK004",
                        f"guarded-by names {g.lock!r}, which is never "
                        f"acquired in this file (typo?)",
                        symbol=f"{g.cls or ''}.{g.name}:{g.lock}"))
        for fn in funcs:
            v = _FnVisitor(fn, manifest, globals_, instance)
            v.run()
            findings.extend(v.violations)

    # ---- interprocedural lock-order edges ---------------------------------
    funcs_by_qual = {f.qual: f for f in all_funcs}
    by_file_name: Dict[Tuple[str, str], _Func] = {}
    by_stem_name: Dict[Tuple[str, str], _Func] = {}
    for f in all_funcs:
        name = f.qual.partition(":")[2].split(".")[-1]
        if f.cls is None:
            by_file_name.setdefault((f.sf.rel, name), f)
            stem = f.sf.rel.rsplit("/", 1)[-1].removesuffix(".py")
            by_stem_name.setdefault((stem, name), f)
    # transitive closure of acquired locks through resolved calls
    acquires: Dict[str, Set[str]] = {f.qual: set(f.direct_locks)
                                     for f in all_funcs}
    changed = True
    while changed:
        changed = False
        for f in all_funcs:
            for text in f.calls:
                g = _resolve_call(text, f, funcs_by_qual, by_file_name,
                                  by_stem_name, manifest)
                if g is None:
                    continue
                extra = acquires[g.qual] - acquires[f.qual]
                if extra:
                    acquires[f.qual] |= extra
                    changed = True
    # re-walk: inside each with-lock region, calls imply edges
    for f in all_funcs:
        findings.extend(_call_edges(f, acquires, funcs_by_qual,
                                    by_file_name, by_stem_name, manifest))
    return findings


def _call_edges(fn: _Func, acquires: Dict[str, Set[str]],
                funcs_by_qual, by_file_name, by_stem_name,
                manifest: Manifest) -> List[Finding]:
    """Edges lock->lock implied by calls made while a lock is held."""
    out: List[Finding] = []
    seen: Set[Tuple[str, str]] = set()

    def walk(node: ast.AST, held: List[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn.node:
            return
        if isinstance(node, ast.With):
            pushed = []
            for item in node.items:
                lock_id = _lock_id(fn.sf, fn.cls,
                                   expr_text(item.context_expr))
                if _order_index(manifest, lock_id) is not None:
                    pushed.append(lock_id)
            held = held + pushed
            for stmt in node.body:
                walk(stmt, held)
            return
        if isinstance(node, ast.Call) and held:
            g = _resolve_call(expr_text(node.func), fn, funcs_by_qual,
                              by_file_name, by_stem_name, manifest)
            if g is not None:
                for m in acquires.get(g.qual, ()):
                    for h in held:
                        if h == m or (h, m) in seen:
                            continue
                        seen.add((h, m))
                        hi, mi = (_order_index(manifest, h),
                                  _order_index(manifest, m))
                        if hi is not None and mi is not None and hi >= mi:
                            out.append(Finding(
                                fn.sf.rel, node.lineno, node.col_offset,
                                PASS_ID, "LOCK003",
                                f"call into {g.qual} acquires "
                                f"{m.split(':')[-1]} while holding "
                                f"{h.split(':')[-1]}: violates the "
                                f"declared lock order",
                                symbol=f"{fn.qual}:{h}->{m}"))
        for child in ast.iter_child_nodes(node):
            walk(child, held)

    held0: List[str] = []
    holds = fn.sf.holds.get(fn.node.lineno)
    if holds is not None:
        hid = _lock_id(fn.sf, fn.cls, holds)
        if _order_index(manifest, hid) is not None:
            held0.append(hid)
    for stmt in fn.node.body:
        walk(stmt, held0)
    return out
