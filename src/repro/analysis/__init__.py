"""``repro.analysis`` — static invariant checks for the repo's own source.

Every fast path in this repo (batched tables, the jax/jax-fused grid
backends, the serving tier) is pinned *bit-identical* to the paper's
scalar reference walk.  Those pins rest on contracts the test suite can
only sample, never prove:

  * **Lock discipline** — the process-lifetime table caches, the fault
    registry, and the serving-tier state are mutated from many threads;
    every access must hold the declared lock (``# guarded-by:``), and
    locks must nest in one global order (no ABBA deadlocks).
  * **int64 exactness** — the cycle-count call graph must never
    introduce a float that cannot represent its integers exactly
    (bare ``/`` where ``//`` or a ceil-div is meant, ``np.mean``,
    non-integral float literals, float32 anywhere).
  * **x64 guard** — every public jnp-touching entry point must execute
    under ``jax.experimental.enable_x64()`` or int64 grids silently
    truncate to int32 past 2**31.
  * **Fault-point consistency** — ``core.faultinject`` names used at
    injection sites, the registry, and the tests arming them must agree;
    a typo'd point silently disables a recovery test.
  * **Determinism** — pricing paths must not depend on wall-clock time,
    unseeded RNG, builtin ``hash`` randomization, or set iteration
    order; "same query, same answer" is the serving dedup contract.

This package machine-checks all five as AST passes over ``src/`` —
``python -m repro.analysis src/`` — with machine-readable findings and a
committed baseline (``analysis-baseline.json``) so CI fails only on
*new* violations and the baseline can only ratchet down.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence

from .manifest import DEFAULT_MANIFEST, Manifest
from .report import (Baseline, Finding, diff_against_baseline, fingerprints,
                     findings_to_json)
from .source import SourceFile, collect_sources

__all__ = [
    "Baseline", "DEFAULT_MANIFEST", "Finding", "Manifest", "SourceFile",
    "collect_sources", "diff_against_baseline", "findings_to_json",
    "fingerprints", "run_passes", "PASSES",
]


def _load_passes():
    from . import determinism, exactness, faults, locks, x64
    return (locks, exactness, x64, faults, determinism)


PASSES = tuple(p.PASS_ID for p in _load_passes())


def run_passes(files: Sequence[SourceFile],
               manifest: Manifest = DEFAULT_MANIFEST, *,
               only: Iterable[str] = ()) -> List[Finding]:
    """Run the analysis passes over ``files`` and return sorted findings.
    ``only`` restricts to a subset of pass ids (default: all)."""
    wanted = set(only)
    out: List[Finding] = []
    for mod in _load_passes():
        if wanted and mod.PASS_ID not in wanted:
            continue
        out.extend(mod.run(files, manifest))
    # drop findings the source explicitly waives on that line
    by_file = {f.rel: f for f in files}
    out = [f for f in out
           if (sf := by_file.get(f.path)) is None
           or not sf.allowed(f.line, f.code, f.pass_id)]
    return sorted(out)
