"""Pass 2 — int64-exactness lint on the cycle-count call graph.

The paper's cycle/energy quantities are integers; the repo carries them
in float64 (exact below 2**53) and in int64 device grids.  Within the
manifest's ``exact_scope`` roots — expanded through same-scope calls —
the following introduce values that break bit-exactness:

``EX001``  a bare ``/`` not directly inside a ``ceil``/``floor``/``round``
           call (the sanctioned exact ceil-of-integer-division idiom);
           ``//`` is what integer math wants.
``EX002``  a call to a float-producing reduction (``mean``, ``average``,
           ``true_divide``, ...) from ``exact_banned_calls``.
``EX003``  a non-integral float literal (``0.5`` — ``2.0`` is fine).
``EX004``  any reference to ``float32`` (name, attribute, or dtype
           string) — float32 cannot hold cycle counts past 2**24.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Sequence, Set, Tuple

from .manifest import Manifest
from .report import Finding
from .source import SourceFile, expr_text, scope_name

PASS_ID = "exact"

_DEF = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _roots(files: Sequence[SourceFile], manifest: Manifest
           ) -> List[Tuple[SourceFile, ast.AST]]:
    out = []
    for suffix, names in manifest.exact_scope.items():
        for sf in files:
            if not sf.matches(suffix):
                continue
            for node in sf.tree.body:
                if isinstance(node, _DEF) and (names == ("*",)
                                               or node.name in names):
                    out.append((sf, node))
    return out


def _expand(roots: List[Tuple[SourceFile, ast.AST]],
            files: Sequence[SourceFile], manifest: Manifest
            ) -> List[Tuple[SourceFile, ast.AST]]:
    """Closure of the roots over calls that resolve to a *unique*
    top-level definition inside the exact-scope fileset."""
    defs: Dict[str, List[Tuple[SourceFile, ast.AST]]] = {}
    for sf in files:
        if not any(sf.matches(s) for s in manifest.exact_scope):
            continue
        for node in sf.tree.body:
            if isinstance(node, _DEF):
                defs.setdefault(node.name, []).append((sf, node))
    seen: Set[int] = {id(n) for _, n in roots}
    work = list(roots)
    queue = list(roots)
    while queue:
        sf, node = queue.pop()
        for n in ast.walk(node):
            if not isinstance(n, ast.Call):
                continue
            name = expr_text(n.func).split(".")[-1]
            cands = defs.get(name, [])
            if len(cands) == 1 and id(cands[0][1]) not in seen:
                seen.add(id(cands[0][1]))
                work.append(cands[0])
                queue.append(cands[0])
    return work


def _div_sanctioned(node: ast.BinOp, manifest: Manifest) -> bool:
    """True iff the division sits (through arithmetic) directly inside a
    ``ceil``/``floor``/``round`` call — the exact-div idiom."""
    n: ast.AST = node
    p = getattr(n, "parent", None)
    while isinstance(p, (ast.BinOp, ast.UnaryOp)):
        n = p
        p = getattr(p, "parent", None)
    if isinstance(p, ast.Call):
        fname = expr_text(p.func).split(".")[-1]
        return fname in manifest.exact_div_wrappers and n in p.args
    return False


def run(files: Sequence[SourceFile], manifest: Manifest) -> List[Finding]:
    findings: List[Finding] = []
    scoped = _expand(_roots(files, manifest), files, manifest)
    checked: Set[int] = set()
    for sf, root in scoped:
        for node in ast.walk(root):
            if id(node) in checked:
                continue
            checked.add(id(node))
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div) \
                    and not _div_sanctioned(node, manifest):
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset, PASS_ID, "EX001",
                    "bare '/' in int64-exact scope: use '//' or wrap the "
                    "ceil-div in np.ceil(...)",
                    symbol=f"{scope_name(node)}:/"))
            elif isinstance(node, ast.Call):
                fname = expr_text(node.func).split(".")[-1]
                if fname in manifest.exact_banned_calls:
                    findings.append(Finding(
                        sf.rel, node.lineno, node.col_offset, PASS_ID,
                        "EX002",
                        f"float-producing call {fname!r} in int64-exact "
                        f"scope", symbol=f"{scope_name(node)}:{fname}"))
            elif isinstance(node, ast.Constant):
                if isinstance(node.value, float) \
                        and not node.value.is_integer():
                    findings.append(Finding(
                        sf.rel, node.lineno, node.col_offset, PASS_ID,
                        "EX003",
                        f"non-integral float literal {node.value!r} in "
                        f"int64-exact scope",
                        symbol=f"{scope_name(node)}:{node.value!r}"))
                elif node.value == "float32":
                    findings.append(Finding(
                        sf.rel, node.lineno, node.col_offset, PASS_ID,
                        "EX004",
                        "float32 dtype in int64-exact scope: cannot hold "
                        "cycle counts past 2**24",
                        symbol=f"{scope_name(node)}:float32"))
            elif (isinstance(node, ast.Attribute)
                  and node.attr == "float32") \
                    or (isinstance(node, ast.Name)
                        and node.id == "float32"):
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset, PASS_ID, "EX004",
                    "float32 reference in int64-exact scope: cannot hold "
                    "cycle counts past 2**24",
                    symbol=f"{scope_name(node)}:float32"))
    return findings
