"""CLI: ``python -m repro.analysis [--baseline F] [paths...]``.

Runs every pass over the given paths (default ``src``), auto-including
the sibling ``tests/`` directory so the fault-coverage check can see the
arming tests.  Exit status 0 means no findings outside the baseline;
1 means new violations (printed, and written to ``--json`` if given).
``--write-baseline`` accepts the current findings as the new baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import (Baseline, DEFAULT_MANIFEST, PASSES, collect_sources,
               diff_against_baseline, findings_to_json, run_passes)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checks over the repro source tree.")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="known-violations file; fail only on NEW findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings to --baseline and exit")
    ap.add_argument("--json", type=Path, default=None, metavar="OUT",
                    help="write the machine-readable findings report here")
    ap.add_argument("--tests", type=Path, default=None,
                    help="tests directory for fault-coverage (default: "
                         "sibling 'tests' of the first path)")
    ap.add_argument("--only", action="append", default=[], choices=PASSES,
                    help="run only this pass (repeatable)")
    ap.add_argument("--root", type=Path, default=None,
                    help="path-relativization root (default: cwd)")
    args = ap.parse_args(argv)

    paths = [Path(p) for p in (args.paths or ["src"])]
    tests = args.tests
    if tests is None:
        cand = paths[0].resolve().parent / "tests"
        tests = cand if cand.is_dir() else None
    scan = list(paths) + ([tests] if tests else [])
    files = collect_sources(scan, root=args.root)
    if not files:
        print(f"repro.analysis: no python sources under {paths}",
              file=sys.stderr)
        return 2

    findings = run_passes(files, DEFAULT_MANIFEST, only=args.only)

    if args.json is not None:
        args.json.write_text(
            json.dumps(findings_to_json(findings), indent=2) + "\n")

    if args.write_baseline:
        if args.baseline is None:
            ap.error("--write-baseline requires --baseline")
        Baseline.from_findings(findings).save(args.baseline)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = Baseline.load(args.baseline) if args.baseline else Baseline()
    new, stale = diff_against_baseline(findings, baseline)

    n_files = len(files)
    print(f"repro.analysis: {n_files} file(s), {len(findings)} finding(s), "
          f"{len(new)} new, {len(stale)} stale baseline entr"
          f"{'y' if len(stale) == 1 else 'ies'}")
    for fp, f in sorted(new.items(), key=lambda kv: kv[1]):
        print(f"  NEW {f.render()}  [{fp}]")
    for fp in stale:
        old = baseline.findings.get(fp, {})
        loc = f"{old.get('path', '?')}:{old.get('line', '?')}"
        print(f"  stale baseline entry {fp} ({old.get('code', '?')} at "
              f"{loc}) — fixed; ratchet with --write-baseline")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
