"""The module manifest: what each pass checks, over which files.

The annotations in the source (``# guarded-by:``, ``# holds-lock:``)
declare *what* is protected; this manifest declares the repo-wide facts
no single file can state — the global lock acquisition order, which
modules form the int64 cycle-count call graph, which modules must run
under the x64 guard, where the fault registry lives, and which modules
are pricing paths under the determinism contract.  Tests construct
custom ``Manifest`` instances over fixture snippets; the repo's own run
uses ``DEFAULT_MANIFEST``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence, Tuple


@dataclass(frozen=True)
class Manifest:
    # ---- locks pass --------------------------------------------------------
    # Global acquisition order, outermost first.  Acquiring lock B while
    # holding lock A is legal iff A appears strictly before B here.
    # Lock ids: "<path-suffix>:<name>" for module globals,
    # "<path-suffix>:<Class>.self.<attr>" for instance locks,
    # "<path-suffix>:<Class>.<method>" for context-manager methods.
    lock_order: Tuple[str, ...] = ()
    # Caller-holds-lock helper suffix (``# holds-lock:`` names the lock).
    locked_suffix: str = "_locked"
    # Call-site resolution hints for the lock-order graph: the rendered
    # call expression (``self.metrics.count``, ``store.save``) -> the
    # qualified function id whose acquisitions the call implies.
    call_patterns: Mapping[str, str] = field(default_factory=dict)

    # ---- exactness pass ----------------------------------------------------
    # path-suffix -> ("*",) for the whole module, or a tuple of top-level
    # function/class names forming the int64 cycle-math roots there.  The
    # pass expands the roots through same-fileset calls (the call graph).
    exact_scope: Mapping[str, Tuple[str, ...]] = field(default_factory=dict)
    # Call names that introduce floats a cycle path must never see.
    exact_banned_calls: Tuple[str, ...] = (
        "mean", "average", "true_divide", "divide", "float_power")
    # ``/`` is legal only directly inside one of these (the exact
    # ceil-of-integer-division idiom: all operands integral, < 2**53).
    exact_div_wrappers: Tuple[str, ...] = ("ceil", "floor", "round")

    # ---- x64 pass ----------------------------------------------------------
    x64_modules: Tuple[str, ...] = ()
    x64_guard_decorators: Tuple[str, ...] = ("_x64",)
    x64_guard_context: str = "enable_x64"
    # jnp-ish root names whose use marks a function as device-touching.
    x64_numeric_roots: Tuple[str, ...] = ("jnp", "pl", "pltpu")
    # jax.<attr> uses that are numeric (jax.default_backend etc. are not).
    x64_jax_attrs: Tuple[str, ...] = ("jit", "vmap", "lax", "numpy", "grad",
                                      "pmap", "experimental")

    # ---- faults pass -------------------------------------------------------
    fault_module: str = "repro/core/faultinject.py"
    fault_registry_name: str = "FAULT_POINTS"
    fault_call_names: Tuple[str, ...] = ("fire", "arm", "armed", "fired",
                                         "disarm")
    tests_dir_name: str = "tests"

    # ---- determinism pass --------------------------------------------------
    determinism_modules: Tuple[str, ...] = ()
    banned_clock_calls: Tuple[str, ...] = (
        "time.time", "time.time_ns", "datetime.now", "datetime.utcnow",
        "datetime.datetime.now", "datetime.datetime.utcnow", "date.today")
    # attribute calls on the *global* (unseeded) RNGs
    banned_rng_roots: Tuple[str, ...] = ("random", "np.random",
                                         "numpy.random")
    seeded_rng_ctors: Tuple[str, ...] = ("Random", "default_rng",
                                         "RandomState", "PRNGKey", "SeedSequence")
    # order-insensitive consumers that sanction set iteration
    order_safe_calls: Tuple[str, ...] = ("sorted", "min", "max", "sum",
                                         "len", "any", "all", "frozenset",
                                         "set")


# ---------------------------------------------------------------------------
# The repo's own manifest
# ---------------------------------------------------------------------------

DEFAULT_MANIFEST = Manifest(
    lock_order=(
        # serving tier first (outermost): the dispatcher/client threads
        # take service state locks, then fan into the shared caches
        "repro/serve/service.py:DSEService.self._lock",
        "repro/serve/metrics.py:ServiceMetrics.self._lock",
        # the process-lifetime table caches
        "repro/core/dse.py:_CACHE_LOCK",
        # leaves: held strictly inside a cache critical section
        "repro/core/store.py:TableStore._locked",
        "repro/core/faultinject.py:_FAULT_LOCK",
    ),
    call_patterns={
        # service -> metrics accumulator (all mutators lock internally)
        "self.metrics.count": "repro/serve/metrics.py:ServiceMetrics.count",
        "self.metrics.batch": "repro/serve/metrics.py:ServiceMetrics.batch",
        "self.metrics.search": "repro/serve/metrics.py:ServiceMetrics.search",
        "self.metrics.completed":
            "repro/serve/metrics.py:ServiceMetrics.completed",
        "self.metrics.failed": "repro/serve/metrics.py:ServiceMetrics.failed",
        "self.metrics.snapshot":
            "repro/serve/metrics.py:ServiceMetrics.snapshot",
        # cache layer -> persistent store (fcntl critical sections)
        "store.save": "repro/core/store.py:TableStore.save",
        "store.load": "repro/core/store.py:TableStore.load",
        "store.contains": "repro/core/store.py:TableStore.contains",
        # anything -> fault registry
        "faultinject.fire": "repro/core/faultinject.py:fire",
        "faultinject.arm": "repro/core/faultinject.py:arm",
        "faultinject.armed": "repro/core/faultinject.py:armed",
        "faultinject.fired": "repro/core/faultinject.py:fired",
        "faultinject.reset": "repro/core/faultinject.py:reset",
    },
    exact_scope={
        # the paper's cycle/energy quantity derivations: whole modules
        "repro/core/conv_model.py": ("*",),
        "repro/core/simd_model.py": ("*",),
        "repro/core/gemm_model.py": ("*",),
        "repro/core/tiling.py": ("*",),
        # dse.py mixes cycle math with float scoring/reporting; only the
        # cost-table classes (and everything they call) are int64-exact
        "repro/core/dse.py": ("ConvTable", "SimdTable", "GemmTable"),
    },
    x64_modules=(
        "repro/core/gridax.py",
        "repro/kernels/reduce.py",
    ),
    determinism_modules=(
        "repro/core/dse.py",
        "repro/core/tiling.py",
        "repro/core/conv_model.py",
        "repro/core/simd_model.py",
        "repro/core/gemm_model.py",
        "repro/core/optimize.py",
        "repro/core/study.py",
        "repro/core/objectives.py",
        "repro/core/energy.py",
        "repro/core/backward.py",
        "repro/core/gridax.py",
    ),
)
