"""Tiling generator: outer/inner tile template (paper Sec. IV-B).

Outer tiles must fit the double-buffered on-chip SRAMs (half of each
buffer usable); inner tiles are fixed by the compute array: the systolic
GEMM mapping uses t_ic = J, t_oc = K, every other inner tile parameter = 1
(paper Fig. 4); the SIMD mapping uses t_c = K, t_h = t_w = t_n = 1
(paper Fig. 7).

The generator mirrors the paper's "tiling generator that generates valid
tiling parameters for each type of layer using the configuration of the
hardware" (Sec. VII): it is a deterministic greedy that
  1. keeps the full kernel window (T_kh=Kh, T_kw=Kw) when it fits and
     shrinks kernel dims only when forced (the *training* case the paper
     calls out, with kernels up to 223x223),
  2. maximizes T_ic (J-aligned) to reduce psum spill, then grows T_oc
     (K-aligned) within WBuf,
  3. fills IBuf/OBuf with spatial/batch tile extent,
  4. finishes every growth axis with an exact, padding-aware remainder
     fill (the extent in [current, largest-that-fits] minimizing the
     ceil-padded extent), so *arbitrary* integer buffer sizes — not just
     powers of two — translate into distinct tilings.  This is what gives
     the off-lattice DSE optimizer (``core/optimize.py``) a
     finer-than-power-of-two design space to search over.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

from .hardware import HardwareSpec
from .layers import ConvLayer, SimdLayer


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Tiling caches
#
# Tilings depend on a small subset of the HardwareSpec (buffer sizes, bit
# widths, array dims) and on the layer *shape* — never on layer names,
# phases, or DRAM bandwidths.  Keying the cache on exactly that subset means
# e.g. a bandwidth-only sensitivity sweep, or a DSE bandwidth sweep at fixed
# buffer sizes, hits the cache on every call, and identically-shaped layers
# with different names share one entry.
# ---------------------------------------------------------------------------

_CONV_TILING_CACHE: Dict[tuple, "ConvTiling"] = {}
_SIMD_TILING_CACHE: Dict[tuple, "SimdTiling"] = {}


def clear_tiling_caches() -> None:
    """Drop all memoized tilings (used by benchmarks for fair timing)."""
    _CONV_TILING_CACHE.clear()
    _SIMD_TILING_CACHE.clear()


def _conv_hw_key(hw: HardwareSpec) -> tuple:
    return (hw.wbuf, hw.ibuf, hw.obuf, hw.bbuf,
            hw.b_w, hw.b_b, hw.b_i, hw.b_p, hw.J, hw.K)


def _conv_layer_key(layer: ConvLayer) -> tuple:
    return (layer.n, layer.ic, layer.ih, layer.iw, layer.oc, layer.oh,
            layer.ow, layer.kh, layer.kw, layer.s, layer.has_bias)


def _simd_hw_key(hw: HardwareSpec) -> tuple:
    return (hw.vmem, hw.b_in, hw.K)


def _simd_layer_key(layer: SimdLayer) -> tuple:
    return (layer.h, layer.w, layer.n, layer.c, layer.parts)


def _align_down(v: int, a: int) -> int:
    return max(a, (v // a) * a) if v >= a else v


def _max_fit(lo: int, hi: int, fits) -> int:
    """Largest v in [lo, hi] with fits(v), assuming fits is monotone
    decreasing in v and fits(lo) holds (binary search)."""
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def _fill_dim(cur: int, dim: int, fits) -> int:
    """Exact remainder fill for one tile extent: among the extents in
    [cur, largest-that-fits], pick the one minimizing the ceil-padded
    extent ``ceil(dim/T) * T`` (tile-grid traffic is proportional to it —
    growing 8 -> 13 over a dim of 14 would *double* the padded extent),
    tie-breaking toward the largest T (fewest tiles, least setup
    overhead).  Never shrinks below ``cur``, so it can only improve on
    the doubling pass it follows."""
    if cur >= dim:
        return cur
    hi = _max_fit(cur, dim, fits)
    best_t, best_ext = cur, ceil_div(dim, cur) * cur
    for m in range(1, ceil_div(dim, cur) + 1):
        t = ceil_div(dim, m)          # smallest T yielding m tiles
        if t < cur:
            break
        if t > hi:
            continue
        ext = m * t
        if ext < best_ext or (ext == best_ext and t > best_t):
            best_t, best_ext = t, ext
    return best_t


# ---------------------------------------------------------------------------
# Conv tiling
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvTiling:
    """Outer tile sizes T_phi and inner tile sizes t_phi (paper Fig. 4)."""
    T_oh: int; T_ow: int; T_n: int
    T_kh: int; T_kw: int; T_ic: int; T_oc: int
    t_ic: int; t_oc: int
    # inner tiles for the remaining dims are 1 by construction

    def ih_extent(self, s: int) -> int:
        return (self.T_oh - 1) * s + self.T_kh

    def iw_extent(self, s: int) -> int:
        return (self.T_ow - 1) * s + self.T_kw

    def weight_tile_elems(self) -> int:
        return self.T_kh * self.T_kw * self.T_ic * self.T_oc

    def ifmap_tile_elems(self, s: int) -> int:
        return self.ih_extent(s) * self.iw_extent(s) * self.T_n * self.T_ic

    def psum_tile_elems(self) -> int:
        return self.T_oh * self.T_ow * self.T_n * self.T_oc


def conv_tile_fits(hw: HardwareSpec, layer: ConvLayer, t: ConvTiling) -> bool:
    """Validity: every outer tile fits its (half, double-buffered) SRAM."""
    if t.weight_tile_elems() * hw.b_w // 8 > hw.wbuf // 2:
        return False
    if t.ifmap_tile_elems(layer.s) * hw.b_i // 8 > hw.ibuf // 2:
        return False
    if t.psum_tile_elems() * hw.b_p // 8 > hw.obuf // 2:
        return False
    if layer.has_bias and t.T_oc * hw.b_b // 8 > hw.bbuf // 2:
        return False
    for tv, dim in ((t.T_oh, layer.oh), (t.T_ow, layer.ow), (t.T_n, layer.n),
                    (t.T_kh, layer.kh), (t.T_kw, layer.kw),
                    (t.T_ic, layer.ic), (t.T_oc, layer.oc)):
        if not (1 <= tv <= dim):
            return False
    return True


def make_conv_tiling(hw: HardwareSpec, layer: ConvLayer) -> ConvTiling:
    """Memoized front-end to the greedy tiling derivation below."""
    key = (_conv_hw_key(hw), _conv_layer_key(layer))
    t = _CONV_TILING_CACHE.get(key)
    if t is None:
        t = _CONV_TILING_CACHE[key] = _derive_conv_tiling(hw, layer)
    return t


def _derive_conv_tiling(hw: HardwareSpec, layer: ConvLayer) -> ConvTiling:
    wcap = hw.wbuf // 2 * 8 // hw.b_w          # weight elems per half-buffer
    icap = hw.ibuf // 2 * 8 // hw.b_i
    ocap = hw.obuf // 2 * 8 // hw.b_p

    # 1) kernel window: keep full, shrink only if a single (J, K) weight
    #    slice with the window would not fit (training-phase huge kernels).
    T_kh, T_kw = layer.kh, layer.kw
    j0 = min(hw.J, layer.ic)
    k0 = min(hw.K, layer.oc)
    while T_kh * T_kw * j0 * k0 > wcap and T_kw > 1:
        T_kw = max(1, T_kw // 2)
    while T_kh * T_kw * j0 * k0 > wcap and T_kh > 1:
        T_kh = max(1, T_kh // 2)

    # 2) maximize T_ic (J-aligned) with minimal T_oc, then grow T_oc:
    #    doubling first, then an exact remainder fill to the largest
    #    K-aligned value the capacity admits (full oc when it fits).  The
    #    fill is what makes *arbitrary* — non-power-of-two — buffer sizes
    #    meaningful: without it every capacity between two powers of two
    #    collapses onto the lower one's tiling.
    T_ic = min(layer.ic, _align_down(wcap // (T_kh * T_kw * k0), hw.J))
    T_ic = max(1, min(T_ic, layer.ic))
    T_oc = k0
    while T_oc * 2 <= layer.oc and T_kh * T_kw * T_ic * T_oc * 2 <= wcap:
        T_oc *= 2
    T_oc = min(T_oc, layer.oc)
    cap_oc = wcap // (T_kh * T_kw * T_ic)
    if cap_oc >= layer.oc:
        T_oc = layer.oc
    elif cap_oc >= k0:
        T_oc = max(T_oc, min(layer.oc, _align_down(cap_oc, k0)))

    # ifmap cap may also bound T_ic (for 1x1-spatial minimum tiles)
    while T_ic > 1 and (T_kh * T_kw * T_ic) > icap:
        T_ic = max(1, T_ic // 2)

    # 3) spatial/batch tile growth under IBuf and OBuf.
    T_oh = T_ow = T_n = 1

    def fits(oh: int, ow: int, n: int) -> bool:
        ih = (oh - 1) * layer.s + T_kh
        iw = (ow - 1) * layer.s + T_kw
        return (ih * iw * n * T_ic <= icap) and (oh * ow * n * T_oc <= ocap)

    grew = True
    while grew:
        grew = False
        for dim in ("ow", "oh", "n"):
            oh, ow, n = T_oh, T_ow, T_n
            if dim == "ow" and T_ow < layer.ow and fits(oh, min(ow * 2, layer.ow), n):
                T_ow = min(T_ow * 2, layer.ow); grew = True
            elif dim == "oh" and T_oh < layer.oh and fits(min(oh * 2, layer.oh), ow, n):
                T_oh = min(T_oh * 2, layer.oh); grew = True
            elif dim == "n" and T_n < layer.n and fits(oh, ow, min(n * 2, layer.n)):
                T_n = min(T_n * 2, layer.n); grew = True

    # 4) remainder fill: grow each spatial/batch dim to the padding-aware
    #    best extent that still fits (doubling alone strands up to half of
    #    each capacity, and all of any capacity between two powers of two).
    grew = True
    while grew:
        grew = False
        v = _fill_dim(T_ow, layer.ow, lambda x: fits(T_oh, x, T_n))
        if v > T_ow:
            T_ow = v; grew = True
        v = _fill_dim(T_oh, layer.oh, lambda x: fits(x, T_ow, T_n))
        if v > T_oh:
            T_oh = v; grew = True
        v = _fill_dim(T_n, layer.n, lambda x: fits(T_oh, T_ow, x))
        if v > T_n:
            T_n = v; grew = True

    t = ConvTiling(T_oh=T_oh, T_ow=T_ow, T_n=T_n, T_kh=T_kh, T_kw=T_kw,
                   T_ic=T_ic, T_oc=T_oc,
                   t_ic=min(hw.J, T_ic), t_oc=min(hw.K, T_oc))
    if not conv_tile_fits(hw, layer, t):
        # Last-resort fallback: unit tiles along everything but ic/oc lanes.
        t = ConvTiling(1, 1, 1, 1, 1, min(hw.J, layer.ic), min(hw.K, layer.oc),
                       t_ic=min(hw.J, layer.ic), t_oc=min(hw.K, layer.oc))
    return t


# ---------------------------------------------------------------------------
# SIMD tiling
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimdTiling:
    T_h: int; T_w: int; T_n: int; T_c: int
    t_c: int


def simd_tile_bytes(hw: HardwareSpec, layer: SimdLayer, t: "SimdTiling") -> int:
    """VMem bytes needed by the *largest* part's resident tiles."""
    worst = 0
    v4 = t.T_h * t.T_w * t.T_n * t.T_c
    for part in layer.parts:
        tot = 0
        for ref in part.tensors:
            if ref.rank == "4d":
                tot += int(math.ceil(v4 * ref.scale)) * hw.b_in // 8
            else:
                tot += t.T_c * hw.b_in // 8
        worst = max(worst, tot)
    return worst


def simd_tile_fits(hw: HardwareSpec, layer: SimdLayer, t: "SimdTiling") -> bool:
    if not (1 <= t.T_h <= layer.h and 1 <= t.T_w <= layer.w
            and 1 <= t.T_n <= layer.n and 1 <= t.T_c <= layer.c):
        return False
    return simd_tile_bytes(hw, layer, t) <= hw.vmem   # single-buffered: full VMem


def make_simd_tiling(hw: HardwareSpec, layer: SimdLayer) -> SimdTiling:
    """Memoized front-end to the greedy tiling derivation below."""
    key = (_simd_hw_key(hw), _simd_layer_key(layer))
    t = _SIMD_TILING_CACHE.get(key)
    if t is None:
        t = _SIMD_TILING_CACHE[key] = _derive_simd_tiling(hw, layer)
    return t


def _derive_simd_tiling(hw: HardwareSpec, layer: SimdLayer) -> SimdTiling:
    T_c = min(layer.c, max(hw.K, _align_down(layer.c, hw.K)))
    t = SimdTiling(1, 1, 1, T_c, t_c=min(hw.K, T_c))
    while not simd_tile_fits(hw, layer, t) and t.T_c > 1:
        t = SimdTiling(1, 1, 1, max(1, t.T_c // 2), t_c=min(hw.K, max(1, t.T_c // 2)))

    def with_dims(h: int, w: int, n: int, c: int) -> SimdTiling:
        return SimdTiling(T_h=h, T_w=w, T_n=n, T_c=c, t_c=min(hw.K, c))

    # exact channel fill: the halving loop above lands on a power-of-two
    # fraction of the K-aligned start; any capacity between two such
    # fractions (non-power-of-two VMem sizes) admits a larger tile.
    if t.T_c < layer.c:
        c = _fill_dim(t.T_c, layer.c,
                      lambda x: simd_tile_fits(hw, layer, with_dims(
                          t.T_h, t.T_w, t.T_n, x)))
        t = with_dims(t.T_h, t.T_w, t.T_n, c)

    grew = True
    while grew:
        grew = False
        for dim in ("w", "h", "n"):
            cand = SimdTiling(
                T_h=min(t.T_h * 2, layer.h) if dim == "h" else t.T_h,
                T_w=min(t.T_w * 2, layer.w) if dim == "w" else t.T_w,
                T_n=min(t.T_n * 2, layer.n) if dim == "n" else t.T_n,
                T_c=t.T_c, t_c=t.t_c)
            if cand != t and simd_tile_fits(hw, layer, cand):
                t = cand; grew = True

    # remainder fill on the spatial/batch dims, mirroring the conv path.
    grew = True
    while grew:
        grew = False
        for dim in ("w", "h", "n"):
            cur = getattr(t, f"T_{dim}")
            limit = getattr(layer, dim)
            if cur >= limit:
                continue
            v = _fill_dim(cur, limit,
                          lambda x: simd_tile_fits(hw, layer, with_dims(
                              x if dim == "h" else t.T_h,
                              x if dim == "w" else t.T_w,
                              x if dim == "n" else t.T_n, t.T_c)))
            if v > cur:
                t = with_dims(v if dim == "h" else t.T_h,
                              v if dim == "w" else t.T_w,
                              v if dim == "n" else t.T_n, t.T_c)
                grew = True
    return t
