"""Tiling generator: outer/inner tile template (paper Sec. IV-B).

Outer tiles must fit the double-buffered on-chip SRAMs (half of each
buffer usable); inner tiles are fixed by the compute array: the systolic
GEMM mapping uses t_ic = J, t_oc = K, every other inner tile parameter = 1
(paper Fig. 4); the SIMD mapping uses t_c = K, t_h = t_w = t_n = 1
(paper Fig. 7).

The generator mirrors the paper's "tiling generator that generates valid
tiling parameters for each type of layer using the configuration of the
hardware" (Sec. VII): it is a deterministic greedy that
  1. keeps the full kernel window (T_kh=Kh, T_kw=Kw) when it fits and
     shrinks kernel dims only when forced (the *training* case the paper
     calls out, with kernels up to 223x223),
  2. maximizes T_ic (J-aligned) to reduce psum spill, then grows T_oc
     (K-aligned) within WBuf — re-offering any capacity an IBuf-forced
     T_ic shrink frees back to T_oc,
  3. fills IBuf/OBuf with spatial/batch tile extent,
  4. finishes every growth axis with an exact, padding-aware remainder
     fill (the extent in [current, largest-that-fits] minimizing the
     ceil-padded extent), so *arbitrary* integer buffer sizes — not just
     powers of two — translate into distinct tilings.  This is what gives
     the off-lattice DSE optimizer (``core/optimize.py``) a
     finer-than-power-of-two design space to search over.

The production derivation is *vectorized over buffer-size candidates*:
``derive_conv_tilings_batch``/``derive_simd_tilings_batch`` run every
greedy phase as masked numpy updates over the whole candidate axis at
once — capacities become per-candidate vectors, the kernel-shrink /
T_ic-maximize / T_oc-grow / spatial-doubling phases become masked array
updates, and the remainder fill becomes a batched distinct-quotient
reduction — so a DSE lattice's worth of tilings (hundreds of size triples
x every layer shape) costs one numpy pass per layer instead of one Python
walk per (triple, layer) pair.  ``make_conv_tiling``/``make_simd_tiling``
are thin memoized scalar wrappers over the same kernel (one code path, no
drift); ``derive_conv_tiling_reference``/``derive_simd_tiling_reference``
retain the original scalar greedy for equivalence tests and benchmarks,
and the batch must stay bit-identical to it (asserted per-field in
``tests/test_tiling_batch.py`` over the full Table VIII lattices).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .hardware import HardwareSpec
from .layers import ConvLayer, GemmLayer, SimdLayer


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Tiling caches
#
# Tilings depend on a small subset of the HardwareSpec (buffer sizes, bit
# widths, array dims) and on the layer *shape* — never on layer names,
# phases, or DRAM bandwidths.  Keying the cache on exactly that subset means
# e.g. a bandwidth-only sensitivity sweep, or a DSE bandwidth sweep at fixed
# buffer sizes, hits the cache on every call, and identically-shaped layers
# with different names share one entry.
# ---------------------------------------------------------------------------

_CONV_TILING_CACHE: Dict[tuple, "ConvTiling"] = {}
_SIMD_TILING_CACHE: Dict[tuple, "SimdTiling"] = {}
_GEMM_TILING_CACHE: Dict[tuple, "GemmTiling"] = {}


def clear_tiling_caches() -> None:
    """Drop all memoized tilings (used by benchmarks for fair timing)."""
    _CONV_TILING_CACHE.clear()
    _SIMD_TILING_CACHE.clear()
    _GEMM_TILING_CACHE.clear()


def _conv_hw_key(hw: HardwareSpec) -> tuple:
    return (hw.wbuf, hw.ibuf, hw.obuf, hw.bbuf,
            hw.b_w, hw.b_b, hw.b_i, hw.b_p, hw.J, hw.K)


def _conv_layer_key(layer: ConvLayer) -> tuple:
    return (layer.n, layer.ic, layer.ih, layer.iw, layer.oc, layer.oh,
            layer.ow, layer.kh, layer.kw, layer.s, layer.has_bias)


def _gemm_layer_key(layer: GemmLayer) -> tuple:
    return (layer.m, layer.n, layer.k, layer.has_bias)


def _simd_hw_key(hw: HardwareSpec) -> tuple:
    return (hw.vmem, hw.b_in, hw.K)


def _simd_layer_key(layer: SimdLayer) -> tuple:
    return (layer.h, layer.w, layer.n, layer.c, layer.parts)


def stable_key_repr(key) -> str:
    """Canonical, process-independent serialization of a nested cache key.

    The table/tiling cache keys are nested tuples of ints, bools, floats
    and strings (hardware invariants, layer shapes, phases), plus frozen
    dataclasses of the same (the SIMD layer parts).  The persistent
    table store (``core.store``) content-addresses its entries on this
    serialization, so it must be byte-stable across processes and Python
    versions: every leaf is tagged with its type (``True`` and ``1``
    must not collide) and rendered via ``repr`` (exact for ints and
    round-trip-exact for floats); dataclasses serialize as their class
    name plus fields in definition order.  Unsupported leaf types raise
    ``TypeError`` — an unserializable key must never silently alias."""
    parts: list = []
    _stable_key_parts(key, parts)
    return "".join(parts)


def _stable_key_parts(obj, out: list) -> None:
    if isinstance(obj, tuple):
        out.append("(")
        for item in obj:
            _stable_key_parts(item, out)
            out.append(",")
        out.append(")")
    elif isinstance(obj, bool):            # before int: bool is an int
        out.append(f"b:{obj!r}")
    elif isinstance(obj, int):
        out.append(f"i:{obj!r}")
    elif isinstance(obj, float):
        out.append(f"f:{obj!r}")
    elif isinstance(obj, str):
        out.append(f"s:{len(obj)}:{obj}")
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        out.append(f"d:{type(obj).__name__}(")
        for f in dataclasses.fields(obj):
            _stable_key_parts(getattr(obj, f.name), out)
            out.append(",")
        out.append(")")
    else:
        raise TypeError(
            f"cache keys must be nested tuples/dataclasses of "
            f"int/bool/float/str; got {type(obj).__name__}: {obj!r}")


def _align_down(v: int, a: int) -> int:
    return max(a, (v // a) * a) if v >= a else v


def _max_fit(lo: int, hi: int, fits) -> int:
    """Largest v in [lo, hi] with fits(v), assuming fits is monotone
    decreasing in v and fits(lo) holds (binary search)."""
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


@lru_cache(maxsize=None)
def _distinct_quotients(dim: int) -> Tuple[int, ...]:
    """All distinct values of ``ceil(dim/m)`` over m >= 1, ascending.

    There are only O(sqrt(dim)) of them: for m <= sqrt(dim) each m gives
    one quotient, and every quotient produced by a larger m is itself
    <= sqrt(dim)+1 (t = ceil(dim/t') for t' = ceil(dim/t) — the standard
    divisor-block identity filters the achievable small values)."""
    r = math.isqrt(dim)
    out = {ceil_div(dim, m) for m in range(1, r + 2)}
    out.update(t for t in range(1, r + 2)
               if ceil_div(dim, ceil_div(dim, t)) == t)
    return tuple(sorted(out))


def _fill_dim(cur: int, dim: int, fits) -> int:
    """Exact remainder fill for one tile extent: among the extents in
    [cur, largest-that-fits], pick the one minimizing the ceil-padded
    extent ``ceil(dim/T) * T`` (tile-grid traffic is proportional to it —
    growing 8 -> 13 over a dim of 14 would *double* the padded extent),
    tie-breaking toward the largest T (fewest tiles, least setup
    overhead).  Never shrinks below ``cur``, so it can only improve on
    the doubling pass it follows.

    Only the O(sqrt(dim)) distinct quotients ``t = ceil(dim/m)`` can win
    (for any other extent, the next quotient up has the same tile count
    and a no-worse padded extent is found at a quotient), so the scan
    enumerates exactly those instead of every tile count in
    [1, ceil(dim/cur)] — O(dim) when ``cur`` is 1."""
    if cur >= dim:
        return cur
    hi = _max_fit(cur, dim, fits)
    best_t, best_ext = cur, ceil_div(dim, cur) * cur
    for t in _distinct_quotients(dim):
        if t < cur or t > hi:
            continue
        ext = ceil_div(dim, t) * t
        if ext < best_ext or (ext == best_ext and t > best_t):
            best_t, best_ext = t, ext
    return best_t


# ---------------------------------------------------------------------------
# Vectorized helpers: the same primitives with a candidate axis
# ---------------------------------------------------------------------------

def _max_fit_vec(lo: np.ndarray, hi: np.ndarray, fits) -> np.ndarray:
    """Vector ``_max_fit``: per-lane largest v in [lo, hi] with fits(v),
    where ``fits`` maps an int64 vector to a boolean vector (monotone
    decreasing per lane, fits(lo) assumed)."""
    # saturation fast path: lanes whose whole range fits converge at once
    # (the common case — most tile extents reach the full dim), leaving
    # the log2(dim) bisection to the genuinely capacity-bound lanes
    lo = np.where(fits(hi), hi, lo)
    hi = hi.copy()
    while True:
        open_ = lo < hi
        if not open_.any():
            return lo
        mid = (lo + hi + 1) // 2
        ok = fits(mid) & open_
        lo = np.where(ok, mid, lo)
        hi = np.where(open_ & ~ok, mid - 1, hi)


def _fill_dim_batch(cur: np.ndarray, dim: int, fits=None,
                    hi: "np.ndarray | None" = None) -> np.ndarray:
    """Vector ``_fill_dim``: the padded-extent minimization as one masked
    distinct-quotient reduction over the candidate axis.  The
    largest-that-fits bound comes either from ``hi`` (callers whose
    capacity constraints invert in closed form — the conv path) or from a
    vector bisection of ``fits`` (an int64-extent-vector -> bool-vector
    predicate, monotone decreasing per lane — the SIMD path).  A lane
    whose ``hi`` lands below ``cur`` (its current extent no longer fits)
    keeps ``cur``, exactly like the scalar.  Lanes already at ``dim`` are
    returned unchanged."""
    act = cur < dim
    if not act.any():
        return cur
    if hi is None:
        hi = _max_fit_vec(cur, np.where(act, dim, cur), fits)
    qs = np.asarray(_distinct_quotients(dim), dtype=np.int64)
    # lexicographic (padded extent, -t) packed into one int64 key
    enc = 2 * dim + 2
    key_q = ((dim + qs - 1) // qs) * qs * enc + (dim - qs)
    valid = (qs[None, :] >= cur[:, None]) & (qs[None, :] <= hi[:, None])
    best = np.where(valid, key_q[None, :],
                    np.iinfo(np.int64).max).min(axis=1)
    best = np.minimum(best, ((dim + cur - 1) // cur) * cur * enc
                      + (dim - cur))
    return np.where(act, dim - best % enc, cur)


# ---------------------------------------------------------------------------
# Conv tiling
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvTiling:
    """Outer tile sizes T_phi and inner tile sizes t_phi (paper Fig. 4)."""
    T_oh: int; T_ow: int; T_n: int
    T_kh: int; T_kw: int; T_ic: int; T_oc: int
    t_ic: int; t_oc: int
    # inner tiles for the remaining dims are 1 by construction

    def ih_extent(self, s: int) -> int:
        return (self.T_oh - 1) * s + self.T_kh

    def iw_extent(self, s: int) -> int:
        return (self.T_ow - 1) * s + self.T_kw

    def weight_tile_elems(self) -> int:
        return self.T_kh * self.T_kw * self.T_ic * self.T_oc

    def ifmap_tile_elems(self, s: int) -> int:
        return self.ih_extent(s) * self.iw_extent(s) * self.T_n * self.T_ic

    def psum_tile_elems(self) -> int:
        return self.T_oh * self.T_ow * self.T_n * self.T_oc


def conv_tile_fits(hw: HardwareSpec, layer: ConvLayer, t: ConvTiling) -> bool:
    """Validity: every outer tile fits its (half, double-buffered) SRAM."""
    if t.weight_tile_elems() * hw.b_w // 8 > hw.wbuf // 2:
        return False
    if t.ifmap_tile_elems(layer.s) * hw.b_i // 8 > hw.ibuf // 2:
        return False
    if t.psum_tile_elems() * hw.b_p // 8 > hw.obuf // 2:
        return False
    if layer.has_bias and t.T_oc * hw.b_b // 8 > hw.bbuf // 2:
        return False
    for tv, dim in ((t.T_oh, layer.oh), (t.T_ow, layer.ow), (t.T_n, layer.n),
                    (t.T_kh, layer.kh), (t.T_kw, layer.kw),
                    (t.T_ic, layer.ic), (t.T_oc, layer.oc)):
        if not (1 <= tv <= dim):
            return False
    return True


def make_conv_tiling(hw: HardwareSpec, layer: ConvLayer) -> ConvTiling:
    """Memoized scalar front-end: a one-candidate slice of the batched
    derivation below (single code path with the DSE grid fill)."""
    key = (_conv_hw_key(hw), _conv_layer_key(layer))
    t = _CONV_TILING_CACHE.get(key)
    if t is None:
        t = _CONV_TILING_CACHE[key] = _derive_conv_tiling(hw, layer)
    return t


def _derive_conv_tiling(hw: HardwareSpec, layer: ConvLayer) -> ConvTiling:
    return derive_conv_tilings_batch(
        hw, [(hw.wbuf, hw.ibuf, hw.obuf)], layer)[0]


def derive_conv_tilings_batch(hw: HardwareSpec,
                              size_triples: Sequence[Tuple[int, int, int]],
                              layer: ConvLayer) -> List[ConvTiling]:
    """Derive the greedy conv tiling for *every* (wbuf, ibuf, obuf) byte
    triple at once: one numpy pass over the candidate axis, bit-identical
    per candidate to ``derive_conv_tiling_reference``.

    All other hardware invariants (bit widths, J/K, bbuf) come from
    ``hw``; the triples are byte sizes, exactly as stored on
    ``HardwareSpec``.  Every greedy phase of the scalar walk becomes a
    masked vector update — the loop counts are logarithmic in the layer
    dims, so the pass does O(log) vector operations regardless of how
    many candidates ride the axis."""
    fields = _derive_conv_tiling_arrays(hw, size_triples, layer)
    # .tolist() bulk-converts to Python ints (ConvTiling fields are plain
    # ints, exactly like the scalar path's)
    return [ConvTiling(*vals)
            for vals in zip(*(a.tolist() for a in fields))]


def _derive_conv_tiling_arrays(hw: HardwareSpec,
                               size_triples: Sequence[Tuple[int, int, int]],
                               layer: ConvLayer) -> Tuple[np.ndarray, ...]:
    """The batched greedy kernel, returning the struct-of-arrays form
    ``(T_oh, T_ow, T_n, T_kh, T_kw, T_ic, T_oc, t_ic, t_oc)`` (int64,
    one lane per triple).  ``dse.batch_build_conv_tables`` consumes this
    directly so whole table lattices never materialize per-candidate
    ``ConvTiling`` objects."""
    tri = np.asarray([(t[0], t[1], t[2]) for t in size_triples],
                     dtype=np.int64).reshape(-1, 3)
    n = len(tri)
    wcap = tri[:, 0] // 2 * 8 // hw.b_w      # weight elems per half-buffer
    icap = tri[:, 1] // 2 * 8 // hw.b_i
    ocap = tri[:, 2] // 2 * 8 // hw.b_p
    j0 = min(hw.J, layer.ic)
    k0 = min(hw.K, layer.oc)
    s = layer.s

    # 1) kernel window: keep full, shrink only if a single (J, K) weight
    #    slice with the window would not fit (training-phase huge kernels).
    T_kh = np.full(n, layer.kh, dtype=np.int64)
    T_kw = np.full(n, layer.kw, dtype=np.int64)
    while True:
        m = (T_kh * T_kw * j0 * k0 > wcap) & (T_kw > 1)
        if not m.any():
            break
        T_kw = np.where(m, T_kw // 2, T_kw)
    while True:
        m = (T_kh * T_kw * j0 * k0 > wcap) & (T_kh > 1)
        if not m.any():
            break
        T_kh = np.where(m, T_kh // 2, T_kh)

    # 2) maximize T_ic (J-aligned) with minimal T_oc, then grow T_oc:
    #    doubling first, then an exact remainder fill to the largest
    #    K-aligned value the capacity admits (full oc when it fits).  The
    #    fill is what makes *arbitrary* — non-power-of-two — buffer sizes
    #    meaningful: without it every capacity between two powers of two
    #    collapses onto the lower one's tiling.
    v = wcap // (T_kh * T_kw * k0)
    T_ic = np.where(v >= hw.J, np.maximum(hw.J, v // hw.J * hw.J), v)
    T_ic = np.maximum(1, np.minimum(T_ic, layer.ic))

    def grow_oc(T_oc: np.ndarray) -> np.ndarray:
        while True:
            m = ((T_oc * 2 <= layer.oc)
                 & (T_kh * T_kw * T_ic * T_oc * 2 <= wcap))
            if not m.any():
                break
            T_oc = np.where(m, T_oc * 2, T_oc)
        T_oc = np.minimum(T_oc, layer.oc)
        cap_oc = wcap // (T_kh * T_kw * T_ic)
        fill = np.minimum(layer.oc, np.maximum(k0, cap_oc // k0 * k0))
        return np.where(cap_oc >= layer.oc, layer.oc,
                        np.where(cap_oc >= k0,
                                 np.maximum(T_oc, fill), T_oc))

    T_oc = grow_oc(np.full(n, k0, dtype=np.int64))

    # ifmap cap may also bound T_ic (for 1x1-spatial minimum tiles) ...
    while True:
        m = (T_ic > 1) & (T_kh * T_kw * T_ic > icap)
        if not m.any():
            break
        T_ic = np.where(m, T_ic // 2, T_ic)
    # ... and when it does, the WBuf capacity the shrink freed is
    # re-offered to T_oc (idempotent where no shrink happened, so lanes
    # the guard never touched keep their exact first-pass tiling).
    T_oc = grow_oc(T_oc)

    # 3) spatial/batch tile growth under IBuf and OBuf.  The capacity
    #    constraints are integer products monotone in each extent, so the
    #    exact per-dim maximum ("hi") inverts in closed form — the growth
    #    check is one comparison and the remainder fill needs no
    #    bisection.  When the current tiling does not fit at all (tiny
    #    IBuf/OBuf), hi lands below the current extent, no growth
    #    happens, and the final validity check applies the fallback —
    #    exactly the scalar behavior.
    T_oh = np.ones(n, dtype=np.int64)
    T_ow = np.ones(n, dtype=np.int64)
    T_n = np.ones(n, dtype=np.int64)

    def hi_ow():
        ih = (T_oh - 1) * s + T_kh
        return np.minimum(
            layer.ow,
            np.minimum((icap // (ih * T_n * T_ic) - T_kw) // s + 1,
                       ocap // (T_oh * T_n * T_oc)))

    def hi_oh():
        iw = (T_ow - 1) * s + T_kw
        return np.minimum(
            layer.oh,
            np.minimum((icap // (iw * T_n * T_ic) - T_kh) // s + 1,
                       ocap // (T_ow * T_n * T_oc)))

    def hi_n():
        ih = (T_oh - 1) * s + T_kh
        iw = (T_ow - 1) * s + T_kw
        return np.minimum(
            layer.n,
            np.minimum(icap // (ih * iw * T_ic),
                       ocap // (T_oh * T_ow * T_oc)))

    while True:
        grew = np.zeros(n, dtype=bool)
        cand = np.minimum(T_ow * 2, layer.ow)
        m = (cand > T_ow) & (cand <= hi_ow())
        T_ow = np.where(m, cand, T_ow)
        grew |= m
        cand = np.minimum(T_oh * 2, layer.oh)
        m = (cand > T_oh) & (cand <= hi_oh())
        T_oh = np.where(m, cand, T_oh)
        grew |= m
        cand = np.minimum(T_n * 2, layer.n)
        m = (cand > T_n) & (cand <= hi_n())
        T_n = np.where(m, cand, T_n)
        grew |= m
        if not grew.any():
            break

    # 4) remainder fill: grow each spatial/batch dim to the padding-aware
    #    best extent that still fits (doubling alone strands up to half of
    #    each capacity, and all of any capacity between two powers of two).
    while True:
        grew = np.zeros(n, dtype=bool)
        v = _fill_dim_batch(T_ow, layer.ow, hi=hi_ow())
        grew |= v > T_ow
        T_ow = v
        v = _fill_dim_batch(T_oh, layer.oh, hi=hi_oh())
        grew |= v > T_oh
        T_oh = v
        v = _fill_dim_batch(T_n, layer.n, hi=hi_n())
        grew |= v > T_n
        T_n = v
        if not grew.any():
            break

    t_ic = np.minimum(hw.J, T_ic)
    t_oc = np.minimum(hw.K, T_oc)

    # Validity (the vector ``conv_tile_fits``) with the same last-resort
    # fallback as the scalar: unit tiles along everything but ic/oc lanes.
    ih = (T_oh - 1) * s + T_kh
    iw = (T_ow - 1) * s + T_kw
    ok = ((T_kh * T_kw * T_ic * T_oc * hw.b_w // 8 <= tri[:, 0] // 2)
          & (ih * iw * T_n * T_ic * hw.b_i // 8 <= tri[:, 1] // 2)
          & (T_oh * T_ow * T_n * T_oc * hw.b_p // 8 <= tri[:, 2] // 2))
    if layer.has_bias:
        ok &= T_oc * hw.b_b // 8 <= hw.bbuf // 2
    for tv, dim in ((T_oh, layer.oh), (T_ow, layer.ow), (T_n, layer.n),
                    (T_kh, layer.kh), (T_kw, layer.kw),
                    (T_ic, layer.ic), (T_oc, layer.oc)):
        ok &= (1 <= tv) & (tv <= dim)
    fb_ic = min(hw.J, layer.ic)
    fb_oc = min(hw.K, layer.oc)
    T_oh = np.where(ok, T_oh, 1)
    T_ow = np.where(ok, T_ow, 1)
    T_n = np.where(ok, T_n, 1)
    T_kh = np.where(ok, T_kh, 1)
    T_kw = np.where(ok, T_kw, 1)
    T_ic = np.where(ok, T_ic, fb_ic)
    T_oc = np.where(ok, T_oc, fb_oc)
    t_ic = np.where(ok, t_ic, fb_ic)
    t_oc = np.where(ok, t_oc, fb_oc)

    return (T_oh, T_ow, T_n, T_kh, T_kw, T_ic, T_oc, t_ic, t_oc)


def derive_conv_tiling_reference(hw: HardwareSpec,
                                 layer: ConvLayer) -> ConvTiling:
    """The original scalar greedy walk, retained as the independently
    written reference the batched kernel is pinned against (the tiling
    analogue of ``dse.search_reference``).  Production callers go through
    ``make_conv_tiling`` -> ``derive_conv_tilings_batch``."""
    wcap = hw.wbuf // 2 * 8 // hw.b_w          # weight elems per half-buffer
    icap = hw.ibuf // 2 * 8 // hw.b_i
    ocap = hw.obuf // 2 * 8 // hw.b_p

    # 1) kernel window: keep full, shrink only when forced.
    T_kh, T_kw = layer.kh, layer.kw
    j0 = min(hw.J, layer.ic)
    k0 = min(hw.K, layer.oc)
    while T_kh * T_kw * j0 * k0 > wcap and T_kw > 1:
        T_kw = max(1, T_kw // 2)
    while T_kh * T_kw * j0 * k0 > wcap and T_kh > 1:
        T_kh = max(1, T_kh // 2)

    # 2) maximize T_ic (J-aligned), then grow T_oc within WBuf.
    T_ic = min(layer.ic, _align_down(wcap // (T_kh * T_kw * k0), hw.J))
    T_ic = max(1, min(T_ic, layer.ic))

    def grow_oc(T_oc: int) -> int:
        while T_oc * 2 <= layer.oc and T_kh * T_kw * T_ic * T_oc * 2 <= wcap:
            T_oc *= 2
        T_oc = min(T_oc, layer.oc)
        cap_oc = wcap // (T_kh * T_kw * T_ic)
        if cap_oc >= layer.oc:
            return layer.oc
        if cap_oc >= k0:
            return max(T_oc, min(layer.oc, _align_down(cap_oc, k0)))
        return T_oc

    T_oc = grow_oc(k0)

    # ifmap cap may also bound T_ic (for 1x1-spatial minimum tiles); the
    # WBuf capacity a shrink frees is re-offered to T_oc (grow_oc is
    # idempotent, so an untriggered guard changes nothing).
    while T_ic > 1 and (T_kh * T_kw * T_ic) > icap:
        T_ic = max(1, T_ic // 2)
    T_oc = grow_oc(T_oc)

    # 3) spatial/batch tile growth under IBuf and OBuf.
    T_oh = T_ow = T_n = 1

    def fits(oh: int, ow: int, n: int) -> bool:
        ih = (oh - 1) * layer.s + T_kh
        iw = (ow - 1) * layer.s + T_kw
        return (ih * iw * n * T_ic <= icap) and (oh * ow * n * T_oc <= ocap)

    grew = True
    while grew:
        grew = False
        for dim in ("ow", "oh", "n"):
            oh, ow, n = T_oh, T_ow, T_n
            if dim == "ow" and T_ow < layer.ow and fits(oh, min(ow * 2, layer.ow), n):
                T_ow = min(T_ow * 2, layer.ow); grew = True
            elif dim == "oh" and T_oh < layer.oh and fits(min(oh * 2, layer.oh), ow, n):
                T_oh = min(T_oh * 2, layer.oh); grew = True
            elif dim == "n" and T_n < layer.n and fits(oh, ow, min(n * 2, layer.n)):
                T_n = min(T_n * 2, layer.n); grew = True

    # 4) padding-aware remainder fill on each spatial/batch dim.
    grew = True
    while grew:
        grew = False
        v = _fill_dim(T_ow, layer.ow, lambda x: fits(T_oh, x, T_n))
        if v > T_ow:
            T_ow = v; grew = True
        v = _fill_dim(T_oh, layer.oh, lambda x: fits(x, T_ow, T_n))
        if v > T_oh:
            T_oh = v; grew = True
        v = _fill_dim(T_n, layer.n, lambda x: fits(T_oh, T_ow, x))
        if v > T_n:
            T_n = v; grew = True

    t = ConvTiling(T_oh=T_oh, T_ow=T_ow, T_n=T_n, T_kh=T_kh, T_kw=T_kw,
                   T_ic=T_ic, T_oc=T_oc,
                   t_ic=min(hw.J, T_ic), t_oc=min(hw.K, T_oc))
    if not conv_tile_fits(hw, layer, t):
        # Last-resort fallback: unit tiles along everything but ic/oc lanes.
        t = ConvTiling(1, 1, 1, 1, 1, min(hw.J, layer.ic), min(hw.K, layer.oc),
                       t_ic=min(hw.J, layer.ic), t_oc=min(hw.K, layer.oc))
    return t


def conv_tilings_for_triples(hw: HardwareSpec,
                             size_triples: Sequence[Tuple[int, int, int]],
                             layer: ConvLayer) -> List[ConvTiling]:
    """Cache-aware batch accessor: derive only the triples not already
    memoized — in one vectorized pass — seed the cache, and return the
    tilings for all triples in order.  For callers that want the
    ``ConvTiling`` objects themselves (the table build goes through the
    lighter struct-of-arrays kernel via ``dse.batch_build_conv_tables``
    and never materializes them)."""
    base = _conv_hw_key(hw)
    lk = _conv_layer_key(layer)
    keys = [((int(t[0]), int(t[1]), int(t[2])) + base[3:], lk)
            for t in size_triples]
    miss = [i for i, k in enumerate(keys) if k not in _CONV_TILING_CACHE]
    if miss:
        derived = derive_conv_tilings_batch(
            hw, [size_triples[i] for i in miss], layer)
        for i, t in zip(miss, derived):
            _CONV_TILING_CACHE[keys[i]] = t
    return [_CONV_TILING_CACHE[k] for k in keys]


def prefill_conv_tilings(hw: HardwareSpec,
                         size_triples: Sequence[Tuple[int, int, int]],
                         layers: Sequence[ConvLayer]) -> None:
    """Batch-fill the conv tiling cache for every (size triple x unique
    layer shape) pair not already present (byte triples, like
    ``conv_tilings_for_triples``)."""
    seen = set()
    for layer in layers:
        lk = _conv_layer_key(layer)
        if lk in seen:
            continue
        seen.add(lk)
        conv_tilings_for_triples(hw, size_triples, layer)


# ---------------------------------------------------------------------------
# GEMM tiling
#
# M/N/K blocking of out[m, n] = in[m, k] @ w[k, n] against the same three
# double-buffered SRAMs: the (T_k, T_n) weight block lives in WBuf, the
# (T_m, T_k) input block in IBuf, the (T_m, T_n) psum block in OBuf.  The
# greedy is the exact specialization of the conv walk under the
# fc-equivalence (a GEMM m x n x k prices like ``fc(n=m, ic=k, oc=n)``:
# unit kernel window, unit spatial extents, batch = m) — the kernel-shrink
# phase vanishes, the T_ic/T_oc phases become T_k/T_n, and the three
# spatial growth dims collapse onto the single streamed dim m.  The
# fc-equivalence is pinned bit-identical in tests/test_gemm.py.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GemmTiling:
    """Outer blocks (T_m, T_k, T_n) + inner systolic tiles (t_k, t_n)."""
    T_m: int; T_k: int; T_n: int
    t_k: int; t_n: int

    def weight_tile_elems(self) -> int:
        return self.T_k * self.T_n

    def input_tile_elems(self) -> int:
        return self.T_m * self.T_k

    def psum_tile_elems(self) -> int:
        return self.T_m * self.T_n


def gemm_tile_fits(hw: HardwareSpec, layer: GemmLayer, t: GemmTiling) -> bool:
    """Validity: every outer block fits its (half, double-buffered) SRAM."""
    if t.weight_tile_elems() * hw.b_w // 8 > hw.wbuf // 2:
        return False
    if t.input_tile_elems() * hw.b_i // 8 > hw.ibuf // 2:
        return False
    if t.psum_tile_elems() * hw.b_p // 8 > hw.obuf // 2:
        return False
    if layer.has_bias and t.T_n * hw.b_b // 8 > hw.bbuf // 2:
        return False
    for tv, dim in ((t.T_m, layer.m), (t.T_k, layer.k), (t.T_n, layer.n)):
        if not (1 <= tv <= dim):
            return False
    return True


def make_gemm_tiling(hw: HardwareSpec, layer: GemmLayer) -> GemmTiling:
    """Memoized scalar front-end: a one-candidate slice of the batched
    derivation below (single code path with the DSE grid fill)."""
    key = (_conv_hw_key(hw), _gemm_layer_key(layer))
    t = _GEMM_TILING_CACHE.get(key)
    if t is None:
        t = _GEMM_TILING_CACHE[key] = derive_gemm_tilings_batch(
            hw, [(hw.wbuf, hw.ibuf, hw.obuf)], layer)[0]
    return t


def derive_gemm_tilings_batch(hw: HardwareSpec,
                              size_triples: Sequence[Tuple[int, int, int]],
                              layer: GemmLayer) -> List[GemmTiling]:
    """Derive the greedy GEMM blocking for every (wbuf, ibuf, obuf) byte
    triple at once — the GEMM analogue of ``derive_conv_tilings_batch``,
    bit-identical per candidate to ``derive_gemm_tiling_reference``."""
    fields = _derive_gemm_tiling_arrays(hw, size_triples, layer)
    return [GemmTiling(*vals)
            for vals in zip(*(a.tolist() for a in fields))]


def _derive_gemm_tiling_arrays(hw: HardwareSpec,
                               size_triples: Sequence[Tuple[int, int, int]],
                               layer: GemmLayer) -> Tuple[np.ndarray, ...]:
    """The batched greedy kernel in struct-of-arrays form
    ``(T_m, T_k, T_n, t_k, t_n)`` (int64, one lane per triple)."""
    tri = np.asarray([(t[0], t[1], t[2]) for t in size_triples],
                     dtype=np.int64).reshape(-1, 3)
    n = len(tri)
    wcap = tri[:, 0] // 2 * 8 // hw.b_w
    icap = tri[:, 1] // 2 * 8 // hw.b_i
    ocap = tri[:, 2] // 2 * 8 // hw.b_p
    k0 = min(hw.K, layer.n)

    # 1) maximize T_k (J-aligned) with minimal T_n, then grow T_n within
    #    WBuf — doubling plus the exact K-aligned remainder fill.
    v = wcap // k0
    T_k = np.where(v >= hw.J, np.maximum(hw.J, v // hw.J * hw.J), v)
    T_k = np.maximum(1, np.minimum(T_k, layer.k))

    def grow_n(T_n: np.ndarray) -> np.ndarray:
        while True:
            m = (T_n * 2 <= layer.n) & (T_k * T_n * 2 <= wcap)
            if not m.any():
                break
            T_n = np.where(m, T_n * 2, T_n)
        T_n = np.minimum(T_n, layer.n)
        cap_n = wcap // T_k
        fill = np.minimum(layer.n, np.maximum(k0, cap_n // k0 * k0))
        return np.where(cap_n >= layer.n, layer.n,
                        np.where(cap_n >= k0,
                                 np.maximum(T_n, fill), T_n))

    T_n = grow_n(np.full(n, k0, dtype=np.int64))

    # IBuf may bound T_k (a single m-row of the input block must fit);
    # freed WBuf capacity is re-offered to T_n, like the conv walk.
    while True:
        m = (T_k > 1) & (T_k > icap)
        if not m.any():
            break
        T_k = np.where(m, T_k // 2, T_k)
    T_n = grow_n(T_n)

    # 2) stream dim growth under IBuf and OBuf: doubling, then the exact
    #    padding-aware remainder fill (the capacity bound inverts in
    #    closed form, so no bisection is needed).
    T_m = np.ones(n, dtype=np.int64)

    def hi_m():
        return np.minimum(layer.m,
                          np.minimum(icap // T_k, ocap // T_n))

    while True:
        cand = np.minimum(T_m * 2, layer.m)
        m = (cand > T_m) & (cand <= hi_m())
        if not m.any():
            break
        T_m = np.where(m, cand, T_m)
    T_m = _fill_dim_batch(T_m, layer.m, hi=hi_m())

    t_k = np.minimum(hw.J, T_k)
    t_n = np.minimum(hw.K, T_n)

    # Validity (vector ``gemm_tile_fits``) with the unit-block fallback.
    ok = ((T_k * T_n * hw.b_w // 8 <= tri[:, 0] // 2)
          & (T_m * T_k * hw.b_i // 8 <= tri[:, 1] // 2)
          & (T_m * T_n * hw.b_p // 8 <= tri[:, 2] // 2))
    if layer.has_bias:
        ok &= T_n * hw.b_b // 8 <= hw.bbuf // 2
    for tv, dim in ((T_m, layer.m), (T_k, layer.k), (T_n, layer.n)):
        ok &= (1 <= tv) & (tv <= dim)
    fb_k = min(hw.J, layer.k)
    fb_n = min(hw.K, layer.n)
    T_m = np.where(ok, T_m, 1)
    T_k = np.where(ok, T_k, fb_k)
    T_n = np.where(ok, T_n, fb_n)
    t_k = np.where(ok, t_k, fb_k)
    t_n = np.where(ok, t_n, fb_n)

    return (T_m, T_k, T_n, t_k, t_n)


def derive_gemm_tiling_reference(hw: HardwareSpec,
                                 layer: GemmLayer) -> GemmTiling:
    """The scalar greedy walk, retained as the independently written
    reference the batched kernel is pinned against."""
    wcap = hw.wbuf // 2 * 8 // hw.b_w
    icap = hw.ibuf // 2 * 8 // hw.b_i
    ocap = hw.obuf // 2 * 8 // hw.b_p
    k0 = min(hw.K, layer.n)

    T_k = min(layer.k, _align_down(wcap // k0, hw.J))
    T_k = max(1, min(T_k, layer.k))

    def grow_n(T_n: int) -> int:
        while T_n * 2 <= layer.n and T_k * T_n * 2 <= wcap:
            T_n *= 2
        T_n = min(T_n, layer.n)
        cap_n = wcap // T_k
        if cap_n >= layer.n:
            return layer.n
        if cap_n >= k0:
            return max(T_n, min(layer.n, _align_down(cap_n, k0)))
        return T_n

    T_n = grow_n(k0)
    while T_k > 1 and T_k > icap:
        T_k = max(1, T_k // 2)
    T_n = grow_n(T_n)

    T_m = 1

    def fits(m: int) -> bool:
        return m * T_k <= icap and m * T_n <= ocap

    while T_m < layer.m and fits(min(T_m * 2, layer.m)):
        T_m = min(T_m * 2, layer.m)
    T_m = _fill_dim(T_m, layer.m, fits)

    t = GemmTiling(T_m=T_m, T_k=T_k, T_n=T_n,
                   t_k=min(hw.J, T_k), t_n=min(hw.K, T_n))
    if not gemm_tile_fits(hw, layer, t):
        fb_k, fb_n = min(hw.J, layer.k), min(hw.K, layer.n)
        t = GemmTiling(1, fb_k, fb_n, t_k=fb_k, t_n=fb_n)
    return t


def gemm_tilings_for_triples(hw: HardwareSpec,
                             size_triples: Sequence[Tuple[int, int, int]],
                             layer: GemmLayer) -> List[GemmTiling]:
    """Cache-aware batch accessor (the GEMM twin of
    ``conv_tilings_for_triples``)."""
    base = _conv_hw_key(hw)
    lk = _gemm_layer_key(layer)
    keys = [((int(t[0]), int(t[1]), int(t[2])) + base[3:], lk)
            for t in size_triples]
    miss = [i for i, k in enumerate(keys) if k not in _GEMM_TILING_CACHE]
    if miss:
        derived = derive_gemm_tilings_batch(
            hw, [size_triples[i] for i in miss], layer)
        for i, t in zip(miss, derived):
            _GEMM_TILING_CACHE[keys[i]] = t
    return [_GEMM_TILING_CACHE[k] for k in keys]


# ---------------------------------------------------------------------------
# SIMD tiling
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimdTiling:
    T_h: int; T_w: int; T_n: int; T_c: int
    t_c: int


def simd_tile_bytes(hw: HardwareSpec, layer: SimdLayer, t: "SimdTiling") -> int:
    """VMem bytes needed by the *largest* part's resident tiles."""
    worst = 0
    v4 = t.T_h * t.T_w * t.T_n * t.T_c
    for part in layer.parts:
        tot = 0
        for ref in part.tensors:
            if ref.rank == "4d":
                tot += int(math.ceil(v4 * ref.scale)) * hw.b_in // 8
            else:
                tot += t.T_c * hw.b_in // 8
        worst = max(worst, tot)
    return worst


def simd_tile_fits(hw: HardwareSpec, layer: SimdLayer, t: "SimdTiling") -> bool:
    if not (1 <= t.T_h <= layer.h and 1 <= t.T_w <= layer.w
            and 1 <= t.T_n <= layer.n and 1 <= t.T_c <= layer.c):
        return False
    return simd_tile_bytes(hw, layer, t) <= hw.vmem   # single-buffered: full VMem


def make_simd_tiling(hw: HardwareSpec, layer: SimdLayer) -> SimdTiling:
    """Memoized scalar front-end: a one-candidate slice of the batched
    derivation below (single code path with the DSE grid fill)."""
    key = (_simd_hw_key(hw), _simd_layer_key(layer))
    t = _SIMD_TILING_CACHE.get(key)
    if t is None:
        t = _SIMD_TILING_CACHE[key] = _derive_simd_tiling(hw, layer)
    return t


def _derive_simd_tiling(hw: HardwareSpec, layer: SimdLayer) -> SimdTiling:
    return derive_simd_tilings_batch(hw, [hw.vmem], layer)[0]


def derive_simd_tilings_batch(hw: HardwareSpec, vmems: Sequence[int],
                              layer: SimdLayer) -> List[SimdTiling]:
    """Derive the greedy SIMD tiling for every VMem byte size at once —
    the non-Conv analogue of ``derive_conv_tilings_batch``, bit-identical
    per candidate to ``derive_simd_tiling_reference``."""
    vm = np.asarray(list(vmems), dtype=np.int64)
    n = len(vm)
    parts = [([ref.scale for ref in part.tensors if ref.rank == "4d"],
              sum(1 for ref in part.tensors if ref.rank != "4d"))
             for part in layer.parts]

    def fits(T_h, T_w, T_n, T_c):
        v4 = (T_h * T_w * T_n * T_c).astype(np.float64)
        worst = np.zeros(n, dtype=np.int64)
        for scales, n_1d in parts:
            tot = np.zeros(n, dtype=np.int64)
            for sc in scales:
                tot = tot + np.ceil(v4 * sc).astype(np.int64) * hw.b_in // 8
            if n_1d:
                tot = tot + n_1d * (T_c * hw.b_in // 8)
            worst = np.maximum(worst, tot)
        return worst <= vm

    one = np.ones(n, dtype=np.int64)
    c0 = min(layer.c, max(hw.K, _align_down(layer.c, hw.K)))
    T_c = np.full(n, c0, dtype=np.int64)
    while True:
        m = ~fits(one, one, one, T_c) & (T_c > 1)
        if not m.any():
            break
        T_c = np.where(m, np.maximum(1, T_c // 2), T_c)

    # exact channel fill: the halving above lands on a power-of-two
    # fraction of the K-aligned start; non-power-of-two VMem sizes admit
    # a larger tile in between.
    T_c = _fill_dim_batch(T_c, layer.c, lambda x: fits(one, one, one, x))

    T_h = one.copy()
    T_w = one.copy()
    T_n = one.copy()
    while True:
        grew = np.zeros(n, dtype=bool)
        cand = np.minimum(T_w * 2, layer.w)
        m = (T_w < layer.w) & fits(T_h, cand, T_n, T_c)
        T_w = np.where(m, cand, T_w)
        grew |= m
        cand = np.minimum(T_h * 2, layer.h)
        m = (T_h < layer.h) & fits(cand, T_w, T_n, T_c)
        T_h = np.where(m, cand, T_h)
        grew |= m
        cand = np.minimum(T_n * 2, layer.n)
        m = (T_n < layer.n) & fits(T_h, T_w, cand, T_c)
        T_n = np.where(m, cand, T_n)
        grew |= m
        if not grew.any():
            break

    # remainder fill on the spatial/batch dims, mirroring the conv path.
    while True:
        grew = np.zeros(n, dtype=bool)
        v = _fill_dim_batch(T_w, layer.w, lambda x: fits(T_h, x, T_n, T_c))
        grew |= v > T_w
        T_w = v
        v = _fill_dim_batch(T_h, layer.h, lambda x: fits(x, T_w, T_n, T_c))
        grew |= v > T_h
        T_h = v
        v = _fill_dim_batch(T_n, layer.n, lambda x: fits(T_h, T_w, x, T_c))
        grew |= v > T_n
        T_n = v
        if not grew.any():
            break

    return [SimdTiling(T_h=h, T_w=w, T_n=nn, T_c=c, t_c=min(hw.K, c))
            for h, w, nn, c in zip(T_h.tolist(), T_w.tolist(),
                                   T_n.tolist(), T_c.tolist())]


def derive_simd_tiling_reference(hw: HardwareSpec,
                                 layer: SimdLayer) -> SimdTiling:
    """The original scalar greedy walk (reference twin of
    ``derive_conv_tiling_reference``)."""
    T_c = min(layer.c, max(hw.K, _align_down(layer.c, hw.K)))
    t = SimdTiling(1, 1, 1, T_c, t_c=min(hw.K, T_c))
    while not simd_tile_fits(hw, layer, t) and t.T_c > 1:
        t = SimdTiling(1, 1, 1, max(1, t.T_c // 2), t_c=min(hw.K, max(1, t.T_c // 2)))

    def with_dims(h: int, w: int, n: int, c: int) -> SimdTiling:
        return SimdTiling(T_h=h, T_w=w, T_n=n, T_c=c, t_c=min(hw.K, c))

    # exact channel fill: the halving loop above lands on a power-of-two
    # fraction of the K-aligned start; any capacity between two such
    # fractions (non-power-of-two VMem sizes) admits a larger tile.
    if t.T_c < layer.c:
        c = _fill_dim(t.T_c, layer.c,
                      lambda x: simd_tile_fits(hw, layer, with_dims(
                          t.T_h, t.T_w, t.T_n, x)))
        t = with_dims(t.T_h, t.T_w, t.T_n, c)

    grew = True
    while grew:
        grew = False
        for dim in ("w", "h", "n"):
            cand = SimdTiling(
                T_h=min(t.T_h * 2, layer.h) if dim == "h" else t.T_h,
                T_w=min(t.T_w * 2, layer.w) if dim == "w" else t.T_w,
                T_n=min(t.T_n * 2, layer.n) if dim == "n" else t.T_n,
                T_c=t.T_c, t_c=t.t_c)
            if cand != t and simd_tile_fits(hw, layer, cand):
                t = cand; grew = True

    # remainder fill on the spatial/batch dims, mirroring the conv path.
    grew = True
    while grew:
        grew = False
        for dim in ("w", "h", "n"):
            cur = getattr(t, f"T_{dim}")
            limit = getattr(layer, dim)
            if cur >= limit:
                continue
            v = _fill_dim(cur, limit,
                          lambda x: simd_tile_fits(hw, layer, with_dims(
                              x if dim == "h" else t.T_h,
                              x if dim == "w" else t.T_w,
                              x if dim == "n" else t.T_n, t.T_c)))
            if v > cur:
                t = with_dims(v if dim == "h" else t.T_h,
                              v if dim == "w" else t.T_w,
                              v if dim == "n" else t.T_n, t.T_c)
                grew = True
    return t


def simd_tilings_for_vmems(hw: HardwareSpec, vmems: Sequence[int],
                           layer: SimdLayer) -> List[SimdTiling]:
    """Cache-aware batch accessor over VMem byte sizes (the SIMD twin of
    ``conv_tilings_for_triples``)."""
    base = _simd_hw_key(hw)
    lk = _simd_layer_key(layer)
    keys = [((int(v),) + base[1:], lk) for v in vmems]
    miss = [i for i, k in enumerate(keys) if k not in _SIMD_TILING_CACHE]
    if miss:
        derived = derive_simd_tilings_batch(
            hw, [vmems[i] for i in miss], layer)
        for i, t in zip(miss, derived):
            _SIMD_TILING_CACHE[keys[i]] = t
    return [_SIMD_TILING_CACHE[k] for k in keys]


def prefill_simd_tilings(hw: HardwareSpec, vmems: Sequence[int],
                         layers: Sequence[SimdLayer]) -> None:
    """Batch-fill the SIMD tiling cache for every (vmem x unique layer
    shape) pair not already present (byte sizes)."""
    seen = set()
    for layer in layers:
        lk = _simd_layer_key(layer)
        if lk in seen:
            continue
        seen.add(lk)
        simd_tilings_for_vmems(hw, vmems, layer)
