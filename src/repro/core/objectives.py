"""First-class DSE objectives — the metric axis of the search API.

The paper's deliverable is end-to-end statistics (cycles, access counts,
energy, power — Secs. IV-VI), but a search has to *reduce* them to one
figure of merit per candidate.  An ``Objective`` is that reduction,
expressed over *batches* of candidates so both search front-ends keep
their vectorized evaluation: the exhaustive grid scores its whole
[sizes x bandwidths] cost matrix in one call, the refine front-end scores
each proposed neighborhood.

``MetricBatch`` is the data contract between an engine and an objective:
``cycles`` is always present (int64, any shape); the energy-derived
metrics (``energy``, ``edp``, ``power``, ``runtime_s``) are computed
lazily from the per-candidate busy-cycle / SRAM-bit / DRAM-bit tensors
the cost tables carry (see ``ConvTable``/``SimdTable`` in ``core.dse``)
and cached, so a pure-cycles search never pays for them.

Scores are *minimized*; ``float('inf')`` marks an infeasible candidate
(e.g. over a power cap).  Ship objectives:

  * ``cycles``                 — end-to-end latency (the legacy metric)
  * ``energy``                 — total energy E_total (Eq. 29)
  * ``edp``                    — energy-delay product E_total * runtime
  * ``cycles_under_power_cap`` — latency among candidates with
                                 P_avg <= cap_w (Eq. 32); needs a cap, so
                                 instantiate ``CyclesUnderPowerCap(cap_w=...)``

Custom objectives: subclass ``Objective`` (or any object with ``name``,
``needs_energy`` and ``score``) and either pass the instance directly to
``Study.search`` or ``register_objective`` a zero-arg factory for a
string name.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Union

import numpy as np

from .energy import array_namespace


class MetricBatch:
    """Per-candidate metrics for one batch (or grid) of design points.

    ``cycles`` is eager; the energy report — the dict ``compute_energy``
    returns, vectorized per candidate — is produced lazily by the
    engine-supplied thunk and cached across metric accesses.
    """

    def __init__(self, cycles: np.ndarray,
                 energy_fn: Optional[Callable[[], Dict[str, np.ndarray]]]
                 = None):
        self.cycles = cycles
        self._energy_fn = energy_fn
        self._report: Optional[Dict[str, np.ndarray]] = None

    def energy_report(self) -> Dict[str, np.ndarray]:
        if self._report is None:
            if self._energy_fn is None:
                raise ValueError(
                    "this engine supplied no energy tensors; the objective "
                    "requires them (needs_energy=True)")
            self._report = self._energy_fn()
        return self._report

    @property
    def energy(self) -> np.ndarray:
        """E_total, Joules (Eq. 29)."""
        return self.energy_report()["E_total"]

    @property
    def runtime_s(self) -> np.ndarray:
        return self.energy_report()["runtime_s"]

    @property
    def power(self) -> np.ndarray:
        """P_avg, Watts (Eq. 32)."""
        return self.energy_report()["P_avg"]

    @property
    def edp(self) -> np.ndarray:
        """Energy-delay product, Joule-seconds."""
        return self.energy * self.runtime_s


class Objective:
    """A batched reduction of per-candidate metrics to a minimized score.

    ``score`` must be shape-preserving (elementwise over the batch) and
    may return ``inf`` for infeasible candidates.  ``needs_energy`` lets
    engines skip assembling energy tensors for pure-cycle searches."""

    name: str = "objective"
    needs_energy: bool = False

    def score(self, m: MetricBatch) -> np.ndarray:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class Cycles(Objective):
    """End-to-end cycles — the legacy (and default) metric.  Scores are
    the int64 cycle counts themselves, so results are bit-identical to
    the pre-objective API."""

    name = "cycles"
    needs_energy = False

    def score(self, m: MetricBatch) -> np.ndarray:
        return m.cycles


class Energy(Objective):
    """Total energy E_total (Eq. 29), Joules."""

    name = "energy"
    needs_energy = True

    def score(self, m: MetricBatch) -> np.ndarray:
        return m.energy


class EDP(Objective):
    """Energy-delay product E_total * runtime, Joule-seconds."""

    name = "edp"
    needs_energy = True

    def score(self, m: MetricBatch) -> np.ndarray:
        return m.edp


@dataclass(frozen=True)
class CyclesUnderPowerCap(Objective):
    """Min-cycles subject to P_avg <= cap_w: candidates over the cap
    score ``inf`` (infeasible), the rest score their cycles."""

    cap_w: float = float("inf")

    name = "cycles_under_power_cap"
    needs_energy = True

    def score(self, m: MetricBatch) -> np.ndarray:
        # xp dispatch keeps jnp metric batches (the device DSE backend)
        # on device; the numpy path is byte-for-byte the legacy one
        xp = array_namespace(m.cycles)
        return xp.where(xp.asarray(m.power) <= self.cap_w,
                        xp.asarray(m.cycles, dtype=float), np.inf)

    def __repr__(self) -> str:
        return f"CyclesUnderPowerCap(cap_w={self.cap_w})"


OBJECTIVES: Dict[str, Callable[[], Objective]] = {
    "cycles": Cycles,
    "energy": Energy,
    "edp": EDP,
}


def register_objective(name: str, factory: Callable[[], Objective]) -> None:
    """Register a zero-arg objective factory under a string name."""
    OBJECTIVES[name] = factory


def resolve_objective(obj: Union[None, str, Objective]) -> Objective:
    """None -> cycles; a registered name -> its instance; an Objective
    passes through."""
    if obj is None:
        return Cycles()
    if isinstance(obj, str):
        if obj == "cycles_under_power_cap":
            raise ValueError(
                "cycles_under_power_cap needs a cap: pass "
                "CyclesUnderPowerCap(cap_w=...) instead of the string name")
        try:
            return OBJECTIVES[obj]()
        except KeyError:
            raise ValueError(f"unknown objective {obj!r}; registered: "
                             f"{sorted(OBJECTIVES)}") from None
    if isinstance(obj, Objective):
        return obj
    if all(hasattr(obj, a) for a in ("score", "name", "needs_energy")):
        return obj                     # duck-typed custom objective
    raise TypeError(
        f"objective must be a registered name or an object with "
        f"name/needs_energy/score, got {obj!r}")
