"""Budget-constrained local-search DSE front-end (``method="refine"``).

The exhaustive grid engine (``core.dse``) answers the paper's Table VIII
question — how much does the right SRAM/bandwidth split buy — by sweeping
every power-of-two allocation inside the budget band.  The true optimum,
however, lives *between* lattice points (a 96 kB IBuf is a real design,
and since the tiling generator's exact remainder fill it also gets a
genuinely different tiling), and the 8-parameter grid grows as
``sizes^4 x bws^4``.  This module searches that finer space with a tiny
fraction of the grid's candidate evaluations:

  * **Seeded multi-start coordinate descent.**  Deterministic heuristic
    starts (balanced / conv-heavy / VMem-heavy splits of the budget) plus
    seeded random lattice starts; every run with the same
    ``RefineConfig.seed`` produces the same trajectory.
  * **Batched neighborhoods.**  A descent step proposes the *whole*
    neighborhood of the incumbent at once — single-parameter moves plus
    budget-preserving pairwise transfers — and costs it through the same
    ``ConvTable``/``SimdTable`` batched evaluators as the grid: one
    broadcasted ``np.maximum`` reduction per unique size triple / VMem
    value, never a per-candidate Python loop.
  * **Successive lattice refinement.**  Level 0 walks the caller's
    power-of-two lattice (restricted there, the costs are bit-identical
    to the grid's).  Each later level halves the move stride —
    32 kB, 16, 8, ... down to ``RefineConfig.min_step`` — so the search
    ends on arbitrary integer splits of the budgets.
  * **Table reuse.**  Tables come from the process-lifetime
    ``get_conv_table``/``get_simd_table`` cache, so refinement levels
    revisiting a size triple, repeated seeds, and a grid sweep of the
    same shapes all share builds (``table_cache_stats`` shows the hits).

Every costed candidate is archived as a ``DSEPoint`` (the off-lattice
materialization), the per-phase attribution of *any* point — on-lattice
or off — is re-derived through ``phase_cycles_batch``-style column sums
that partition the total exactly, and the returned ``DSEResult`` supports
the same frontier/economic/phase API as the grid's.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .dse import (DSEPoint, DSEResult, _GridEngine, batch_build_conv_tables,
                  batch_build_gemm_tables, get_conv_table, get_gemm_table,
                  get_simd_table, prefetch_conv_tables, _tuples,
                  register_search_method)
from .energy import DEFAULT_ENERGY, EnergyModel, compute_energy_batch
from .hardware import KB, HardwareSpec
from .objectives import Cycles, MetricBatch, Objective, resolve_objective
from .tiling import prefill_simd_tilings

Tup = Tuple[int, int, int, int]
Cand = Tuple[Tup, Tup]                     # (sizes_kb, bws)


@dataclass(frozen=True)
class RefineConfig:
    """Knobs of the local search.  Defaults are tuned so the Table VIII
    fixtures (+-15% budget bands) finish an order of magnitude under the
    grid's candidate count while never landing above the grid optimum.
    On much wider tolerance bands the default evaluation cap can starve
    the descent before it converges — grant more (e.g. ``max_evals``
    around the grid's candidate count; convergence typically uses only a
    few percent of it)."""
    seed: int = 0
    n_starts: int = 8          # heuristic starts first, then seeded random
    max_evals: Optional[int] = None   # hard cap; None: ~grid_cands / 12
    min_step: int = 1          # finest off-lattice stride (kB / bits-cycle)
    lattice_only: bool = False  # stop after level 0 (grid-equivalence mode)
    max_steps: int = 200       # per-start accepted-move cap (safety)


@dataclass(frozen=True)
class RefineTrace:
    """What the optimizer did: the deterministic trajectory (one entry
    per accepted move: start index, refinement stride, incumbent) plus
    the evaluation accounting the >=10x-fewer-candidates claim rests on."""
    seed: int
    n_starts: int
    n_evals: int               # unique candidates costed
    n_size_triples: int        # unique ConvTables driven
    n_vmems: int               # unique SimdTables driven
    grid_candidates: int       # what the exhaustive sweep would have cost
    trajectory: Tuple[Tuple[int, int, DSEPoint], ...]

    @property
    def eval_saving(self) -> float:
        return self.grid_candidates / max(1, self.n_evals)


# ---------------------------------------------------------------------------
# Batched candidate evaluation over the shared tables
# ---------------------------------------------------------------------------

class _RefineEvaluator:
    """Costs batches of arbitrary (sizes, bws) candidates through the
    union-of-shapes tables, memoizing the two separable projections —
    conv cost at (size triple, bw triple), SIMD cost at (vmem, bw_v) —
    per network, so a revisited projection is a dict lookup and a
    revisited size triple is a table-cache hit.

    Alongside cycles it memoizes the bandwidth-independent *energy*
    components each projection contributes — busy cycles, SRAM bits per
    buffer, DRAM bits, straight off the tables' energy tensors — so the
    descent can score candidates in any ``Objective`` (``scores``) and
    any archived point can be priced after the fact (``energy_at``)."""

    def __init__(self, hw_base: HardwareSpec,
                 nets: Mapping[str, Sequence[object]],
                 objective: Optional[Objective] = None,
                 em: EnergyModel = DEFAULT_ENERGY,
                 workers: int = 0):
        self.hw = hw_base
        self.obj = resolve_objective(objective)
        self.em = em
        self.workers = workers
        self.eng = _GridEngine(hw_base, nets)
        self._conv: Dict[str, Dict[tuple, int]] = {n: {} for n in nets}
        self._simd: Dict[str, Dict[tuple, int]] = {n: {} for n in nets}
        # s3 -> (busy, wbuf, ibuf, obuf, bbuf, dram); vm -> (busy, vmem, dram)
        self._conv_e: Dict[str, Dict[tuple, tuple]] = {n: {} for n in nets}
        self._simd_e: Dict[str, Dict[int, tuple]] = {n: {} for n in nets}
        self._seen: Dict[str, set] = {n: set() for n in nets}
        self.archive: Dict[str, List[DSEPoint]] = {n: [] for n in nets}
        self.archive_scores: Dict[str, List[float]] = {n: [] for n in nets}
        self._s3_seen: Dict[str, set] = {n: set() for n in nets}
        self._vm_seen: Dict[str, set] = {n: set() for n in nets}

    def n_evals(self, name: str) -> int:
        return len(self._seen[name])

    def n_size_triples(self, name: str) -> int:
        return len(self._s3_seen[name])

    def n_vmems(self, name: str) -> int:
        return len(self._vm_seen[name])

    def filter_budget(self, name: str, cands: Sequence[Cand],
                      room: int) -> List[Cand]:
        """Already-counted candidates plus the first ``room`` new ones —
        the hard ``max_evals`` enforcement (deterministic: keeps the
        canonical candidate order)."""
        seen = self._seen[name]
        out: List[Cand] = []
        new = 0
        for c in cands:
            if c in seen:
                out.append(c)
            elif new < room:
                out.append(c)
                new += 1
        return out

    def _conv_fill(self, name: str, need: Dict[tuple, List[tuple]]) -> None:
        """Fill the array-side projection memo — conv *and* GEMM layers
        share the (size triple, bw triple) coordinates, so both fold
        into the same cycle memo and 6-tuple energy components."""
        memo = self._conv[name]
        e_memo = self._conv_e[name]
        cols = self.eng.conv_cols[name]
        gcols = self.eng.gemm_cols[name]
        hws = [self.hw.replace(wbuf=s3[0] * KB, ibuf=s3[1] * KB,
                               obuf=s3[2] * KB) for s3 in need]
        if self.workers > 1:
            prefetch_conv_tables(hws, self.eng._conv_union, self.workers)
        # whole neighborhoods of uncached size triples are batch-built in
        # one vectorized pass per layer (the serial fast path); both
        # builders are clean no-ops on an empty shape union
        batch_build_conv_tables(hws, self.eng._conv_union)
        batch_build_gemm_tables(hws, self.eng._gemm_union)
        for s3, b3s in need.items():
            self._s3_seen[name].add(s3)
            hw = self.hw.replace(wbuf=s3[0] * KB, ibuf=s3[1] * KB,
                                 obuf=s3[2] * KB)
            bw_w = [b[0] for b in b3s]
            bw_i = [b[1] for b in b3s]
            bw_o = [b[2] for b in b3s]
            vals = np.zeros(len(b3s), dtype=np.int64)
            e = [0, 0, 0, 0, 0, 0]
            for table, tcols in (
                    ((get_conv_table(hw, self.eng._conv_union)
                      if cols else None), cols),
                    ((get_gemm_table(hw, self.eng._gemm_union)
                      if gcols else None), gcols)):
                if not tcols:
                    continue
                per_layer = table.layer_cycles_batch(bw_w, bw_i, bw_o)
                vals += per_layer[:, tcols].sum(axis=1).astype(np.int64)
                if s3 not in e_memo:
                    e[0] += int(table.busy[tcols].sum())
                    e[1] += int(table.sram["wbuf"][tcols].sum())
                    e[2] += int(table.sram["ibuf"][tcols].sum())
                    e[3] += int(table.sram["obuf"][tcols].sum())
                    e[4] += int(table.sram["bbuf"][tcols].sum())
                    e[5] += int(table.dram[tcols].sum())
            e_memo.setdefault(s3, tuple(e))
            for b3, v in zip(b3s, vals):
                memo[(s3, b3)] = int(v)

    def _simd_fill(self, name: str, need: Dict[int, List[int]]) -> None:
        memo = self._simd[name]
        e_memo = self._simd_e[name]
        ids = self.eng.simd_ids[name]
        prefill_simd_tilings(self.hw, [vm * KB for vm in need],
                             self.eng._simd_union)
        for vm, wvs in need.items():
            self._vm_seen[name].add(vm)
            table = get_simd_table(self.hw.replace(vmem=vm * KB),
                                   self.eng._simd_union)
            if ids:
                rows = [r for i in ids for r in range(*table.layer_rows[i])]
                compute = sum(table.layer_compute[i] for i in ids)
                stall = table.row_stall_batch(wvs)
                vals = (compute + stall[:, rows].sum(axis=1)) \
                    .astype(np.int64)
                if vm not in e_memo:
                    e_memo[vm] = (int(table.busy[ids].sum()),
                                  int(table.sram_vmem[ids].sum()),
                                  int(table.dram[ids].sum()))
            else:
                vals = np.zeros(len(wvs), dtype=np.int64)
                e_memo.setdefault(vm, (0, 0, 0))
            for w, v in zip(wvs, vals):
                memo[(vm, w)] = int(v)

    def _energy_batch(self, name: str, cands: Sequence[Cand],
                      cycles: np.ndarray) -> Dict[str, np.ndarray]:
        """Vectorized Sec. VI energy report for already-memoized
        candidates, assembled from the per-projection energy components."""
        ce, se = self._conv_e[name], self._simd_e[name]
        try:
            conv = np.array([ce[sz[:3]] for sz, _ in cands], dtype=np.int64)
            simd = np.array([se[sz[3]] for sz, _ in cands], dtype=np.int64)
        except KeyError:
            missing = [sz for sz, _ in cands
                       if sz[:3] not in ce or sz[3] not in se]
            raise ValueError(
                f"point(s) with sizes {missing} were never evaluated by "
                f"this refine run; energy is only available for archived "
                f"candidates") from None
        sizes = np.array([sz for sz, _ in cands], dtype=np.int64)
        return compute_energy_batch(
            self.hw, em=self.em,
            c_sa=conv[:, 0], c_simd=simd[:, 0], l_total=cycles,
            sram_bits={"wbuf": conv[:, 1], "ibuf": conv[:, 2],
                       "obuf": conv[:, 3], "bbuf": conv[:, 4],
                       "vmem": simd[:, 1]},
            sram_sizes={"wbuf": sizes[:, 0] * KB, "ibuf": sizes[:, 1] * KB,
                        "obuf": sizes[:, 2] * KB, "bbuf": self.hw.bbuf,
                        "vmem": sizes[:, 3] * KB},
            dram_bits=conv[:, 5] + simd[:, 2])

    def energy_at(self, name: str, point: DSEPoint) -> Dict[str, float]:
        """Energy report of one evaluated point (components are memoized
        by construction for every archived candidate)."""
        cand = (point.sizes_kb, point.bws)
        rep = self._energy_batch(name, [cand],
                                 np.array([point.cycles], dtype=np.int64))
        return {k: float(v[0]) for k, v in rep.items()}

    def energy_many(self, name: str,
                    points: Sequence[DSEPoint]) -> np.ndarray:
        """E_total for many evaluated points in one vectorized call (the
        Pareto path over the whole archive)."""
        cands = [(p.sizes_kb, p.bws) for p in points]
        cycles = np.array([p.cycles for p in points], dtype=np.int64)
        return self._energy_batch(name, cands, cycles)["E_total"]

    def evaluate(self, name: str, cands: Sequence[Cand]) -> np.ndarray:
        """Objective scores for each candidate (int64 cycles under the
        default cycles objective); one batched reduction per unique size
        triple / VMem value not already memoized.  Every newly seen
        candidate is archived (with its true cycle count) along with its
        score."""
        conv_memo, simd_memo = self._conv[name], self._simd[name]
        need_c: Dict[tuple, List[tuple]] = {}
        need_s: Dict[int, List[int]] = {}
        for sz, bw in cands:
            s3, b3 = sz[:3], bw[:3]
            if (s3, b3) not in conv_memo:
                lst = need_c.setdefault(s3, [])
                if b3 not in lst:
                    lst.append(b3)
            vm, wv = sz[3], bw[3]
            if (vm, wv) not in simd_memo:
                lst = need_s.setdefault(vm, [])
                if wv not in lst:
                    lst.append(wv)
        if need_c:
            self._conv_fill(name, need_c)
        if need_s:
            self._simd_fill(name, need_s)
        cycles = np.empty(len(cands), dtype=np.int64)
        for i, (sz, bw) in enumerate(cands):
            cycles[i] = conv_memo[(sz[:3], bw[:3])] \
                + simd_memo[(sz[3], bw[3])]
        if type(self.obj) is Cycles:   # exact type: custom "cycles"-named
            scores = cycles            # objectives still score() below
        else:
            mb = MetricBatch(cycles,
                             lambda: self._energy_batch(name, cands, cycles))
            scores = np.asarray(self.obj.score(mb), dtype=float)
        seen = self._seen[name]
        arch, arch_scores = self.archive[name], self.archive_scores[name]
        for i, (sz, bw) in enumerate(cands):
            if (sz, bw) not in seen:
                seen.add((sz, bw))
                arch.append(DSEPoint(sz, bw, int(cycles[i])))
                arch_scores.append(scores[i].item())
        return scores

    def cycles_of(self, name: str, cand: Cand) -> int:
        """True cycle count of an already-memoized candidate."""
        sz, bw = cand
        return (self._conv[name][(sz[:3], bw[:3])]
                + self._simd[name][(sz[3], bw[3])])

    def phase_cycles(self, name: str, point: DSEPoint) -> Dict[str, int]:
        """Phase-resolved cycles of any (sizes, bws) point — the same
        column-partition sums as the grid's per-phase matrices, driven at
        one configuration, so they partition the point's total exactly."""
        sz, bw = point.sizes_kb, point.bws
        out: Dict[str, int] = {}
        hw = self.hw.replace(wbuf=sz[0] * KB, ibuf=sz[1] * KB,
                             obuf=sz[2] * KB)
        pcols = self.eng.conv_phase_cols[name]
        if pcols:
            table = get_conv_table(hw, self.eng._conv_union)
            per_layer = table.layer_cycles_batch([bw[0]], [bw[1]], [bw[2]])
            for ph, cols in pcols.items():
                out[ph] = int(per_layer[:, cols].sum(axis=1)
                              .astype(np.int64)[0])
        gpcols = self.eng.gemm_phase_cols[name]
        if gpcols:
            table = get_gemm_table(hw, self.eng._gemm_union)
            per_layer = table.layer_cycles_batch([bw[0]], [bw[1]], [bw[2]])
            for ph, cols in gpcols.items():
                out[ph] = int(per_layer[:, cols].sum(axis=1)
                              .astype(np.int64)[0])
        pids = self.eng.simd_phase_ids[name]
        if pids:
            table = get_simd_table(self.hw.replace(vmem=sz[3] * KB),
                                   self.eng._simd_union)
            stall = table.row_stall_batch([bw[3]])
            for ph, ids in pids.items():
                rows = [r for i in ids for r in range(*table.layer_rows[i])]
                compute = sum(table.layer_compute[i] for i in ids)
                out[ph] = int((compute + stall[:, rows].sum(axis=1))
                              .astype(np.int64)[0])
        return out


# ---------------------------------------------------------------------------
# Feasible-tuple construction
# ---------------------------------------------------------------------------

def _ladder_move(tup: Tup, i: int, values: Sequence[int], up: bool
                 ) -> Optional[Tup]:
    """Move coordinate i one notch along the sorted value ladder."""
    vals = values
    pos = np.searchsorted(vals, tup[i])
    if up:
        if pos + 1 >= len(vals) or vals[pos] != tup[i]:
            return None
        nv = vals[pos + 1]
    else:
        if pos == 0 or vals[pos] != tup[i]:
            return None
        nv = vals[pos - 1]
    out = list(tup)
    out[i] = int(nv)
    return tuple(out)


def _repair(tup: Tup, values: Sequence[int], lo: float, hi: float
            ) -> Optional[Tup]:
    """Notch coordinates along the ladder until the sum lands in
    [lo, hi]; deterministic (largest coord down / smallest coord up,
    lowest index on ties).  None if the band is unreachable."""
    cur = tup
    for _ in range(64):
        s = sum(cur)
        if lo <= s <= hi:
            return cur
        if s > hi:
            order = sorted(range(4), key=lambda i: (-cur[i], i))
            moved = None
            for i in order:
                moved = _ladder_move(cur, i, values, up=False)
                if moved is not None:
                    break
        else:
            order = sorted(range(4), key=lambda i: (cur[i], i))
            moved = None
            for i in order:
                moved = _ladder_move(cur, i, values, up=True)
                if moved is not None:
                    break
        if moved is None:
            return None
        cur = moved
    return None


def _nearest(values: Sequence[int], target: float) -> int:
    return int(min(values, key=lambda v: (abs(v - target), v)))


def _starts(rng: np.random.Generator, values: Sequence[int], budget: int,
            lo: float, hi: float, n: int) -> List[Tup]:
    """Deterministic heuristic splits of the budget, then seeded random
    lattice tuples, all repaired into the band."""
    profiles = [
        (0.25, 0.25, 0.25, 0.25),      # balanced
        (0.30, 0.30, 0.30, 0.10),      # conv-side heavy
        (0.15, 0.15, 0.15, 0.55),      # vmem / last-coordinate heavy
    ]
    out: List[Tup] = []
    for prof in profiles:
        t = tuple(_nearest(values, f * budget) for f in prof)
        r = _repair(t, values, lo, hi)
        if r is not None and r not in out:
            out.append(r)
    guard = 0
    while len(out) < n and guard < 200:
        guard += 1
        t = tuple(int(values[k]) for k in rng.integers(0, len(values), 4))
        r = _repair(t, values, lo, hi)
        if r is not None and r not in out:
            out.append(r)
    return out[:n]


# ---------------------------------------------------------------------------
# Neighborhoods
# ---------------------------------------------------------------------------

def _lattice_neighbors(tup: Tup, values: Sequence[int], lo: float, hi: float
                       ) -> List[Tup]:
    """Level 0: every single-coordinate replacement by any other lattice
    value, pairwise transfers of up to three notches each way (multi-notch
    transfers cross valleys whose one-notch intermediates are uphill), and
    pairwise value swaps (sum-preserving by construction)."""
    out = set()
    for i in range(4):
        for v in values:
            if v == tup[i]:
                continue
            cand = list(tup)
            cand[i] = int(v)
            if lo <= sum(cand) <= hi:
                out.add(tuple(cand))
    for i in range(4):
        upi = tup
        for _ in range(3):
            upi = _ladder_move(upi, i, values, up=True)
            if upi is None:
                break
            for j in range(4):
                if j == i:
                    continue
                dnj = upi
                for _ in range(3):
                    dnj = _ladder_move(dnj, j, values, up=False)
                    if dnj is None:
                        break
                    if lo <= sum(dnj) <= hi:
                        out.add(dnj)
    for i in range(4):
        for j in range(i + 1, 4):
            if tup[i] != tup[j]:
                cand = list(tup)
                cand[i], cand[j] = cand[j], cand[i]
                out.add(tuple(cand))
    out.discard(tup)
    return sorted(out)


def _grow_repair_lattice(tup: Tup, i: int, notches: int,
                         values: Sequence[int], lo: float, hi: float
                         ) -> Optional[Tup]:
    """Grow coordinate i by ``notches`` ladder steps, then pay for it by
    notching the *smallest* other coordinates down until the sum is back
    in [lo, hi].  Smallest-first repair deliberately complements
    ``_repair``'s largest-first policy: it concentrates the budget on
    the grown coordinate instead of leveling the split."""
    cur: Optional[Tup] = tup
    for _ in range(notches):
        cur = _ladder_move(cur, i, values, up=True)
        if cur is None:
            return None
    for _ in range(64):
        s = sum(cur)
        if s <= hi:
            return cur if lo <= s else None
        moved = None
        for j in sorted((j for j in range(4) if j != i),
                        key=lambda j: (cur[j], j)):
            moved = _ladder_move(cur, j, values, up=False)
            if moved is not None:
                break
        if moved is None:
            return None
        cur = moved
    return None


def _grow_repair_step(tup: Tup, i: int, grow: int, step: int,
                      vmin: int, vmax: int, lo: float, hi: float
                      ) -> Optional[Tup]:
    """Arithmetic ``_grow_repair_lattice``: add ``grow`` to coordinate i
    (clamped to vmax), repair smallest-first in ``step`` decrements."""
    if tup[i] + grow > vmax:
        return None
    cur = list(tup)
    cur[i] += grow
    for _ in range(64):
        s = sum(cur)
        if s <= hi:
            return tuple(cur) if lo <= s else None
        js = [j for j in range(4) if j != i and cur[j] - step >= vmin]
        if not js:
            return None
        j = min(js, key=lambda j: (cur[j], j))
        cur[j] -= step
    return None


def _joint_moves(sizes_tup: Tup, bws_tup: Tup,
                 s_grow, b_grow) -> List[Cand]:
    """Paired size+bandwidth moves: grow buffer i *and* its feed
    bandwidth together, each paid for by the smallest other coordinates.
    Coordinate descent over sizes-only / bws-only neighborhoods misses
    optima where a buffer and its bandwidth must move as one (a bigger
    IBuf only pays once the input stream is also fed faster — each
    single-axis move is uphill, the pair is downhill; observed on the
    16x16 training fixture).  ``s_grow(i, n)`` / ``b_grow(i, n)`` map a
    coordinate and a grow amount to a repaired tuple or None."""
    out: List[Cand] = []
    for i in range(4):
        ss = [s for n in (1, 2, 3)
              for s in [s_grow(i, n)] if s is not None]
        bs = [b for n in (1, 2, 3)
              for b in [b_grow(i, n)] if b is not None]
        for s in ss:
            if s == sizes_tup:
                continue
            for b in bs:
                if b != bws_tup:
                    out.append((s, b))
    return out


def _step_neighbors(tup: Tup, step: int, vmin: int, vmax: int,
                    lo: float, hi: float) -> List[Tup]:
    """Refinement levels: single-coordinate +-{1,2,4}*step moves plus
    pairwise +-step transfers, clamped to [vmin, vmax] and the band."""
    out = set()
    for i in range(4):
        for k in (1, 2, 4):
            for d in (k * step, -k * step):
                nv = tup[i] + d
                if not vmin <= nv <= vmax:
                    continue
                cand = list(tup)
                cand[i] = nv
                if lo <= sum(cand) <= hi:
                    out.add(tuple(cand))
    for i in range(4):
        for j in range(4):
            if i == j:
                continue
            ni, nj = tup[i] + step, tup[j] - step
            if not (vmin <= ni <= vmax and vmin <= nj <= vmax):
                continue
            cand = list(tup)
            cand[i], cand[j] = ni, nj
            if lo <= sum(cand) <= hi:
                out.add(tuple(cand))
    return sorted(out)


# ---------------------------------------------------------------------------
# The optimizer
# ---------------------------------------------------------------------------

def _min_gap(values: Sequence[int]) -> int:
    vs = sorted(set(values))
    return min(b - a for a, b in zip(vs, vs[1:])) if len(vs) > 1 else 1


def refine_search_many(hw_base: HardwareSpec,
                       nets: Mapping[str, Sequence[object]],
                       size_budget_kb: int, bw_budget: int, *,
                       sizes: Sequence[int], bws: Sequence[int],
                       tol: float, lower_bound: bool,
                       refine: Optional[RefineConfig] = None,
                       objective: Optional[Objective] = None,
                       em: EnergyModel = DEFAULT_ENERGY,
                       workers: int = 0,
                       backend: Optional[str] = None) -> Dict[str, DSEResult]:
    """The ``method="refine"`` front-end (see module docstring).

    ``backend`` is accepted for front-end signature parity (a ``Study``
    forwards its grid-evaluation backend to every front-end declaring
    it) and ignored: the local search prices small scalar neighborhoods
    where host numpy is already the fast path — the on-device backends
    (``repro.core.gridax``) pay off on whole-lattice reductions.

    Networks are optimized independently but share the union cost tables
    and the process-lifetime table cache, exactly like the grid engine —
    so a refine run after (or before) a grid sweep of the same shapes
    rebuilds nothing at the lattice level.  The descent accepts moves on
    the ``objective``'s score (cycles by default; energy/EDP/power-capped
    searches run the identical search dynamics over their own
    landscape)."""
    cfg = refine if refine is not None else RefineConfig()
    sizes = sorted(int(s) for s in sizes)
    bws = sorted(int(b) for b in bws)
    lo_s = size_budget_kb * (1 - tol) if lower_bound else 0
    lo_b = bw_budget * (1 - tol) if lower_bound else 0
    hi_s = size_budget_kb * (1 + tol)
    hi_b = bw_budget * (1 + tol)
    n_grid = (len(_tuples(sizes, 4, lo_s, hi_s))
              * len(_tuples(bws, 4, lo_b, hi_b)))
    if n_grid == 0:
        raise ValueError("empty DSE space; widen grids or budgets")
    # The default budget scales with the grid so the Table VIII fixtures
    # stay an order of magnitude under exhaustive, with a floor that lets
    # every start finish on small grids (where no saving is claimed).
    max_evals = cfg.max_evals if cfg.max_evals is not None \
        else max(600, n_grid // 12)

    ev = _RefineEvaluator(hw_base, nets, objective=objective, em=em,
                          workers=workers)
    out: Dict[str, DSEResult] = {}
    for name in nets:
        out[name] = _refine_one(ev, name, cfg, sizes, bws,
                                size_budget_kb, bw_budget,
                                (lo_s, hi_s), (lo_b, hi_b),
                                max_evals, n_grid)
    return out


def _refine_one(ev: _RefineEvaluator, name: str, cfg: RefineConfig,
                sizes: Sequence[int], bws: Sequence[int],
                size_budget_kb: int, bw_budget: int,
                s_band: Tuple[float, float], b_band: Tuple[float, float],
                max_evals: int, n_grid: int) -> DSEResult:
    rng = np.random.default_rng(cfg.seed)
    s_starts = _starts(rng, sizes, size_budget_kb,
                       s_band[0], s_band[1], cfg.n_starts)
    b_starts = _starts(rng, bws, bw_budget,
                       b_band[0], b_band[1], cfg.n_starts)
    starts: List[Cand] = [
        (s_starts[k % len(s_starts)], b_starts[k % len(b_starts)])
        for k in range(max(len(s_starts), len(b_starts)))
    ] if s_starts and b_starts else []
    if not starts:
        raise ValueError("no feasible starting point in the budget band")

    steps: List[int] = []
    if not cfg.lattice_only:
        st = _min_gap(sizes + list(bws)) // 2
        while st >= max(1, cfg.min_step):
            steps.append(st)
            st //= 2
    vmin_s, vmax_s = min(sizes), max(sizes)
    vmin_b, vmax_b = min(bws), max(bws)

    trajectory: List[Tuple[int, int, DSEPoint]] = []

    for si, start in enumerate(starts):
        if ev.n_evals(name) >= max_evals:
            break
        cur = start
        cur_score = ev.evaluate(name, [cur])[0].item()
        trajectory.append(
            (si, 0, DSEPoint(cur[0], cur[1], ev.cycles_of(name, cur))))
        level = 0                     # 0 = lattice, k>=1 = steps[k-1]
        moves = 0
        while moves < cfg.max_steps:
            if level == 0:
                s_nb = _lattice_neighbors(cur[0], sizes, *s_band)
                b_nb = _lattice_neighbors(cur[1], bws, *b_band)
                joint = _joint_moves(
                    cur[0], cur[1],
                    lambda i, n: _grow_repair_lattice(cur[0], i, n,
                                                      sizes, *s_band),
                    lambda i, n: _grow_repair_lattice(cur[1], i, n,
                                                      bws, *b_band))
                stride = 0
            else:
                stp = steps[level - 1]
                s_nb = _step_neighbors(cur[0], stp, vmin_s, vmax_s, *s_band)
                b_nb = _step_neighbors(cur[1], stp, vmin_b, vmax_b, *b_band)
                joint = _joint_moves(
                    cur[0], cur[1],
                    lambda i, n: _grow_repair_step(cur[0], i, n * stp, stp,
                                                   vmin_s, vmax_s, *s_band),
                    lambda i, n: _grow_repair_step(cur[1], i, n * stp, stp,
                                                   vmin_b, vmax_b, *b_band))
                stride = stp
            cands = sorted({(s, cur[1]) for s in s_nb}
                           | {(cur[0], b) for b in b_nb}
                           | set(joint))
            room = max_evals - ev.n_evals(name)
            if cands and room > 0:
                cands = ev.filter_budget(name, cands, room)
                scores = ev.evaluate(name, cands)
                i = int(scores.argmin())         # first occurrence: the
                cand, score = cands[i], scores[i].item()  # order-earliest min
            else:
                cand, score = None, None
            # accept a strictly better score, or an equal score at a point
            # earlier in (sizes, bws) tuple order — the legacy grid
            # iteration order for ascending lattices; the monotone
            # decrease also guarantees termination
            if cand is not None and (score, cand) < (cur_score, cur):
                cur, cur_score = cand, score
                moves += 1
                trajectory.append(
                    (si, stride,
                     DSEPoint(cur[0], cur[1], ev.cycles_of(name, cur))))
                level = 0             # improvement: restart from coarse
            else:
                level += 1            # stalled: refine the stride
                if level > len(steps):
                    break

    arch = ev.archive[name]
    arch_scores = ev.archive_scores[name]
    is_cycles = type(ev.obj) is Cycles
    scored = [(s, p) for s, p in zip(arch_scores, arch)
              if s != float("inf")]
    if not scored:
        raise ValueError(f"objective {ev.obj.name!r} marks every evaluated "
                         f"candidate infeasible for network {name!r}")
    best_point = min(scored, key=lambda sp: (sp[0], sp[1].sizes_kb,
                                             sp[1].bws))[1]
    worst_point = max(scored, key=lambda sp: (sp[0], sp[1].sizes_kb,
                                              sp[1].bws))[1]
    trace = RefineTrace(seed=cfg.seed, n_starts=len(starts),
                        n_evals=ev.n_evals(name),
                        n_size_triples=ev.n_size_triples(name),
                        n_vmems=ev.n_vmems(name),
                        grid_candidates=n_grid,
                        trajectory=tuple(trajectory))
    return DSEResult(best=best_point, worst=worst_point,
                     refine=trace, archive=list(arch),
                     objective=ev.obj.name,
                     archive_scores=None if is_cycles else list(arch_scores),
                     _phase_at=lambda p, _n=name: ev.phase_cycles(_n, p),
                     _energy_at=lambda p, _n=name: ev.energy_at(_n, p),
                     _energy_many=lambda ps, _n=name: ev.energy_many(_n, ps))


register_search_method("refine", refine_search_many)
