"""Objective-first DSE front door: ``Workload`` / ``Objective`` / ``Study``.

The paper's deliverable is *end-to-end* statistics — cycles, access
counts, energy, power (Secs. IV-VI) — and its design-space study
(Sec. VII-B) asks allocation questions against them.  This module makes
each axis of such a study a first-class value:

  * ``Workload`` — what runs: a network (by registry name or as a layer
    list), inference or training (Table I expansion), at a batch size.
    Replaces the ad-hoc ``training=True`` kwarg + bare layer sequences.
  * ``Objective`` — what is minimized: a batched reduction over the cost
    tables (``repro.core.objectives``).  Ship: ``cycles``, ``energy``,
    ``edp``, ``CyclesUnderPowerCap(cap_w=...)``.
  * ``Study`` — where the search runs: owns the hardware base, the
    candidate space (lattices, budget tolerance), the energy model, the
    worker pool for parallel table builds, and the front-end registry
    (``method="grid"`` exhaustive / ``method="refine"`` local search).

One study amortizes everything shareable: all its searches draw from the
process-lifetime ``ConvTable``/``SimdTable`` caches, and because the
tables carry the energy tensors alongside cycles, a cycles sweep
followed by an energy (or EDP, or power-capped) sweep over the same
budgets rebuilds *nothing* (``Study.cache_stats``).

    study = Study(HI3, workers=4)
    wl = Workload("resnet50")                       # inference, batch 1
    res = study.search(wl, 2048, 2048, objective="edp")
    res.best, res.energy_report(), res.pareto()     # 2-D cycles/energy

The legacy ``repro.core.dse.search``/``search_many`` survive as thin
deprecation shims over a default ``Study``, bit-identical under the
default cycles objective.
"""
from __future__ import annotations

import contextlib
import inspect
import random
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from . import faultinject
from .backward import expand_training_graph
from .dse import (BWS, SEARCH_METHODS, SIZES_KB, DSEPoint, DSEResult, Layer,
                  clear_table_caches, resolve_backend, table_cache_stats)
from .energy import DEFAULT_ENERGY, EnergyModel
from .hardware import KB, HardwareSpec
from .layers import ConvLayer, GemmLayer, SimdLayer
from .objectives import Objective, resolve_objective
from .store import TableStore, env_int, store_context

WORKERS_ENV = "REPRO_DSE_WORKERS"
SELFCHECK_ENV = "REPRO_DSE_SELFCHECK"


def default_workers() -> int:
    """Worker-process default for parallel table builds: the
    ``REPRO_DSE_WORKERS`` environment variable, else 0 (serial).  A
    garbage value warns (``RuntimeWarning`` naming it) and falls back —
    never a silent serial run."""
    return max(0, env_int(WORKERS_ENV, 0))


def default_selfcheck() -> int:
    """Self-check sample count default: the ``REPRO_DSE_SELFCHECK``
    environment variable (candidates cross-validated per search), else 0
    (off).  Garbage values warn and fall back like ``default_workers``."""
    return max(0, env_int(SELFCHECK_ENV, 0))


class IntegrityError(RuntimeError):
    """A batched DSE result diverged from the independent scalar walk.

    Raised by the opt-in self-check mode (``REPRO_DSE_SELFCHECK=n`` /
    ``Study(selfcheck=n)``): the batched cost tables and the scalar
    reference tiling+simulator path are pinned bit-identical, so any
    divergence means a corrupted cached table, a poisoned store entry
    that validated, or a real batched-vs-scalar regression.  Structured
    fields: ``workload`` (the search key), ``point`` (the diverging
    ``DSEPoint``), ``expected`` (scalar reference cycles), ``actual``
    (batched cycles)."""

    def __init__(self, workload: str, point: DSEPoint,
                 expected: int, actual: int):
        self.workload = workload
        self.point = point
        self.expected = expected
        self.actual = actual
        super().__init__(
            f"DSE self-check failed for workload {workload!r} at "
            f"sizes_kb={point.sizes_kb} bws={point.bws}: batched path "
            f"reports {actual} cycles, scalar reference walk reports "
            f"{expected}")


def _reference_point_cycles(hw_base: HardwareSpec,
                            layers: Sequence[Layer],
                            point: DSEPoint) -> int:
    """Independent scalar evaluation of one candidate: reference tiling
    derivation + per-layer simulator, bypassing every cache and table so
    a poisoned ``ConvTable``/``SimdTable`` cannot vouch for itself."""
    from .conv_model import simulate_conv
    from .gemm_model import simulate_gemm
    from .simd_model import simulate_simd
    from .tiling import (derive_conv_tiling_reference,
                         derive_gemm_tiling_reference,
                         derive_simd_tiling_reference)
    wb, ib, ob, vm = point.sizes_kb
    bw_w, bw_i, bw_o, bw_v = point.bws
    hw = hw_base.replace(wbuf=wb * KB, ibuf=ib * KB, obuf=ob * KB,
                         vmem=vm * KB, bw_w=bw_w, bw_i=bw_i,
                         bw_o=bw_o, bw_v=bw_v)
    total = 0
    for layer in layers:
        if isinstance(layer, ConvLayer):
            t = derive_conv_tiling_reference(hw, layer)
            total += simulate_conv(hw, layer, t).total_cycles
        elif isinstance(layer, GemmLayer):
            t = derive_gemm_tiling_reference(hw, layer)
            total += simulate_gemm(hw, layer, t).total_cycles
        else:
            t = derive_simd_tiling_reference(hw, layer)
            total += simulate_simd(hw, layer, t).total_cycles
    return total


@dataclass(frozen=True)
class Workload:
    """What runs on the accelerator: a network, a phase, a batch size.

    ``net`` is either a name in ``repro.core.networks.NETWORKS``, an LLM
    config name (``repro.models.frontends.llm_config_names`` — lowered
    to a GEMM + SIMD graph), or an explicit layer sequence (stored as a
    tuple).  ``training=True`` selects the Table I training expansion
    (and, for named CNNs, the BN-bearing graph); ``batch`` defaults to
    the paper's setup for CNNs — 1 for inference, 32 for training
    (Sec. VII-A) — and to 1 for LLM configs (their token count is
    ``batch * seq``); it only applies to named networks (an explicit
    layer list already fixes its batch).  ``seq`` sets the LLM sequence
    length (default ``LLM_SEQ_DEFAULT``) and is invalid elsewhere."""
    net: Union[str, Tuple[Layer, ...]]
    training: bool = False
    batch: Optional[int] = None
    name: Optional[str] = None
    seq: Optional[int] = None

    def __post_init__(self):
        if not isinstance(self.net, (str, tuple)):
            object.__setattr__(self, "net", tuple(self.net))
        if not isinstance(self.net, str):
            if self.batch is not None:
                raise ValueError("batch applies to named networks only; an "
                                 "explicit layer list already fixes its "
                                 "batch")
            if self.seq is not None:
                raise ValueError("seq applies to named LLM configs only; "
                                 "an explicit layer list already fixes "
                                 "its shapes")

    @property
    def label(self) -> str:
        if self.name:
            return self.name
        base = self.net if isinstance(self.net, str) else "net"
        return f"{base}:train" if self.training else base

    def layers(self) -> List[Layer]:
        """The concrete layer list, training-expanded when asked.  Named
        CNNs follow ``simulate``'s conventions: BN layers appear only in
        training graphs (inference graphs are BN-folded).  Names not in
        the CNN registry resolve as LLM configs and lower to a GEMM +
        SIMD graph (``repro.models.frontends.lower_llm``)."""
        if isinstance(self.net, str):
            from .networks import NETWORKS
            if self.net in NETWORKS:
                if self.seq is not None:
                    raise ValueError(
                        f"seq applies to LLM configs only; {self.net!r} "
                        f"is a CNN registry network")
                batch = self.batch if self.batch is not None \
                    else (32 if self.training else 1)
                net = NETWORKS[self.net](batch, bn=self.training)
            else:
                from ..models.frontends import (llm_config_names,
                                                lower_llm,
                                                resolve_llm_config)
                cfg = resolve_llm_config(self.net)
                if cfg is None:
                    raise ValueError(
                        f"unknown network {self.net!r}; registered CNN "
                        f"networks: {sorted(NETWORKS)}; LLM configs: "
                        f"{llm_config_names()}")
                net = lower_llm(cfg, batch=self.batch or 1, seq=self.seq)
        else:
            net = list(self.net)
        return expand_training_graph(net) if self.training else net


def as_workload(w: Union[Workload, str, Sequence[Layer]]) -> Workload:
    """Coerce a workload spec: a ``Workload`` passes through, a string
    names a registry network (inference), a layer sequence wraps as an
    inference workload."""
    if isinstance(w, Workload):
        return w
    if isinstance(w, str):
        return Workload(net=w)
    if isinstance(w, Sequence) and all(
            isinstance(l, (ConvLayer, GemmLayer, SimdLayer)) for l in w):
        return Workload(net=tuple(w))
    raise TypeError(f"cannot interpret {w!r} as a Workload")


@dataclass(frozen=True)
class SweepRequest:
    """One self-contained DSE query: workload + budgets + metric + method.

    ``search_many`` prices several *workloads* under ONE budget pair and
    objective; a ``SweepRequest`` additionally carries its own budgets,
    objective, and front-end, so heterogeneous queries — different
    networks, budgets, objectives, inference and training — become plain
    values that can be queued, grouped, and deduplicated.  This is the
    unit the serving subsystem (``repro.serve``) moves around; the
    synchronous batch entry is ``Study.search_requests``.

    ``objective`` is a registered name or an ``Objective`` instance.
    Requests group (and dedup) on string names by value and on instances
    by *identity*: two ``CyclesUnderPowerCap(cap_w=...)`` objects with
    different caps share a class-level ``name``, so identity is the only
    safe sharing key — pass the same instance to queries that should
    coalesce."""
    workload: Workload
    size_budget_kb: int
    bw_budget: int
    objective: Union[str, Objective, None] = "cycles"
    method: str = "grid"

    def __post_init__(self):
        object.__setattr__(self, "workload", as_workload(self.workload))

    def _objective_token(self):
        obj = self.objective
        if obj is None:
            return "cycles"
        return obj if isinstance(obj, str) else id(obj)

    @property
    def group_key(self) -> tuple:
        """Requests with equal group keys are priced by ONE
        ``search_many`` call (same budgets/objective/method — only the
        workloads differ)."""
        return (int(self.size_budget_kb), int(self.bw_budget),
                self._objective_token(), self.method)

    @property
    def dedup_key(self) -> tuple:
        """Full query identity: equal keys mean bit-identical answers,
        so in-flight duplicates can share one result."""
        return (self.workload, *self.group_key)


class Study:
    """One design-space study: hardware base + candidate space + caches.

    Every ``search``/``search_many`` call runs over this study's lattice
    (``sizes`` x ``bws``, four coordinates each, filtered to the +-``tol``
    budget band) with its energy model and worker pool; front-ends come
    from its method registry (``"grid"`` and ``"refine"`` built in,
    ``register_method`` for custom ones).

    The default ``workers=0`` serial path is the fast path: uncached
    per-size-triple ``ConvTable``s are batch-built through the vectorized
    greedy tiling derivation — one numpy pass per layer shape covers the
    study's whole candidate lattice (``dse.batch_build_conv_tables``).
    ``workers > 1`` instead fans scalar builds out across forked
    processes, the *many-core* option for very heavy shape unions where
    fork+pickle overhead amortizes; results stay bit-identical either
    way, defaulting to ``$REPRO_DSE_WORKERS``.

    ``store`` pins this study's persistent table store (a ``TableStore``,
    a directory path, or ``None`` to force the store off even when
    ``$REPRO_TABLE_STORE`` is set); left at the default, resolution
    follows the process-wide rules in ``repro.core.store``.
    ``selfcheck=n`` (default ``$REPRO_DSE_SELFCHECK``, else off)
    cross-validates n sampled candidates of every search against the
    scalar reference walk and raises ``IntegrityError`` on divergence.

    ``backend`` picks where the exhaustive front-end's grid reductions
    run — ``"numpy"`` (host, the default), ``"jax"`` (on-device
    jit/vmap), or ``"jax-fused"`` (jit/vmap with the fused Pallas
    best/worst kernel); ``None`` follows ``$REPRO_DSE_BACKEND``.  All
    backends are pinned bit-identical (``repro.core.gridax``); front-ends
    that don't take a ``backend`` parameter (e.g. ``"refine"``'s scalar
    neighborhoods, or third-party registrations) are called without it.
    """

    _INHERIT = object()          # store default: follow env/global rules

    def __init__(self, hw: HardwareSpec, *,
                 sizes: Sequence[int] = SIZES_KB,
                 bws: Sequence[int] = BWS,
                 tol: float = 0.15, lower_bound: bool = True,
                 energy_model: EnergyModel = DEFAULT_ENERGY,
                 workers: Optional[int] = None,
                 store: Union[TableStore, str, Path, None] = _INHERIT,
                 selfcheck: Optional[int] = None,
                 methods: Optional[Dict[str, object]] = None,
                 backend: Optional[str] = None):
        self.hw = hw
        self.sizes = tuple(sizes)
        self.bws = tuple(bws)
        self.tol = tol
        self.lower_bound = lower_bound
        self.energy_model = energy_model
        self.workers = default_workers() if workers is None else int(workers)
        self.store = store
        self.selfcheck = default_selfcheck() if selfcheck is None \
            else max(0, int(selfcheck))
        self._methods = methods
        self.backend = resolve_backend(backend)

    # ---- front-end registry ----------------------------------------------

    def register_method(self, name: str, fn) -> None:
        """Register a search front-end on this study only (the global
        registry in ``repro.core.dse`` is untouched)."""
        if self._methods is None:
            self._methods = dict(SEARCH_METHODS)
        self._methods[name] = fn

    def _resolve_method(self, method: str):
        registry = self._methods if self._methods is not None \
            else SEARCH_METHODS
        fn = registry.get(method)
        if fn is None and method == "refine":
            from . import optimize                    # registers itself
            del optimize
            fn = SEARCH_METHODS.get(method)
            if self._methods is not None:
                self._methods.setdefault(method, fn)
        if fn is None:
            raise ValueError(f"unknown search method {method!r}; "
                             f"registered: {sorted(registry)}")
        return fn

    # ---- searching --------------------------------------------------------

    def search_many(self,
                    workloads: Mapping[str, Union[Workload, str,
                                                  Sequence[Layer]]],
                    size_budget_kb: int, bw_budget: int, *,
                    objective: Union[str, Objective, None] = "cycles",
                    method: str = "grid",
                    refine=None) -> Dict[str, DSEResult]:
        """Search several workloads at once, sharing the union-of-shapes
        cost tables (a Table IX style sweep builds each table once).
        Returns ``{key: DSEResult}`` scored in ``objective``."""
        obj = resolve_objective(objective)
        nets = {key: as_workload(w).layers()
                for key, w in workloads.items()}
        fn = self._resolve_method(method)
        kwargs = dict(sizes=self.sizes, bws=self.bws, tol=self.tol,
                      lower_bound=self.lower_bound, refine=refine,
                      objective=obj, em=self.energy_model,
                      workers=self.workers)
        # forward the grid-evaluation backend only to front-ends that
        # declare it (keeps pre-existing registrations working unchanged)
        params = inspect.signature(fn).parameters
        if "backend" in params or any(
                p.kind is inspect.Parameter.VAR_KEYWORD
                for p in params.values()):
            kwargs["backend"] = self.backend
        ctx = contextlib.nullcontext() if self.store is Study._INHERIT \
            else store_context(self.store)
        with ctx:
            out = fn(self.hw, nets, size_budget_kb, bw_budget, **kwargs)
        if self.selfcheck > 0:
            for key, res in out.items():
                self._self_check(key, nets[key], res,
                                 size_budget_kb, bw_budget)
        return out

    def _self_check(self, key: str, layers: Sequence[Layer],
                    res: DSEResult, size_budget_kb: int,
                    bw_budget: int) -> None:
        """Cross-validate ``selfcheck`` sampled candidates (plus the
        winner) of one result against the scalar reference walk.  The
        sample is deterministic in (workload, budgets), so a divergence
        reproduces run over run."""
        if res.grid is not None:
            count = res.grid.n_candidates
            candidate = res.grid.point
        elif res.archive:
            count = len(res.archive)
            candidate = res.archive.__getitem__
        else:
            return
        rng = random.Random(zlib.crc32(
            f"{key}|{size_budget_kb}|{bw_budget}|{count}".encode()))
        idx = rng.sample(range(count), min(self.selfcheck, count))
        for point in [candidate(i) for i in idx] + [res.best]:
            expected = _reference_point_cycles(self.hw, layers, point)
            f = faultinject.fire("selfcheck_perturb")
            if f is not None:
                expected += int(f.arg or 1)
            if expected != point.cycles:
                raise IntegrityError(key, point, expected, point.cycles)

    def search(self, workload: Union[Workload, str, Sequence[Layer]],
               size_budget_kb: int, bw_budget: int, *,
               objective: Union[str, Objective, None] = "cycles",
               method: str = "grid", refine=None) -> DSEResult:
        """Search one workload; see ``search_many``.

        ``objective`` may be a registered name (``"cycles"``,
        ``"energy"``, ``"edp"``) or an ``Objective`` instance (e.g.
        ``CyclesUnderPowerCap(cap_w=30.0)``); ``method`` one of this
        study's front-ends (``"grid"``/``"refine"``)."""
        wl = as_workload(workload)
        key = wl.label
        return self.search_many({key: wl}, size_budget_kb, bw_budget,
                                objective=objective, method=method,
                                refine=refine)[key]

    def search_requests(self, requests: Sequence[SweepRequest]
                        ) -> List[DSEResult]:
        """Batch-of-workloads entry: price heterogeneous ``SweepRequest``s
        and fan the results back out in request order.

        Requests are grouped on ``SweepRequest.group_key`` (same budgets,
        objective, method) and each group runs as ONE ``search_many``
        call over its workloads, so the group shares union-of-layer-shape
        table builds; across groups, the process-lifetime table caches
        still dedup every size-triple window the budgets overlap on.
        Each result is bit-identical to a standalone ``search`` of the
        same request — the per-network costs of a shared ``search_many``
        are column gathers over the union tables with unchanged summation
        order (pinned in tests/test_service.py).

        This is the synchronous coalescing primitive; ``repro.serve``
        wraps it with a queue, admission control, deduplication, fault
        isolation, and metrics."""
        requests = [r if isinstance(r, SweepRequest) else SweepRequest(*r)
                    for r in requests]
        groups: Dict[tuple, List[int]] = {}
        for i, req in enumerate(requests):
            groups.setdefault(req.group_key, []).append(i)
        out: List[Optional[DSEResult]] = [None] * len(requests)
        for idx in groups.values():
            head = requests[idx[0]]
            res = self.search_many(
                {f"q{i}": requests[i].workload for i in idx},
                head.size_budget_kb, head.bw_budget,
                objective=head.objective, method=head.method)
            for i in idx:
                out[i] = res[f"q{i}"]
        return out

    # ---- cache ownership --------------------------------------------------

    @staticmethod
    def cache_stats() -> Dict[str, object]:
        """Counters of the shared table caches (``table_cache_stats``)."""
        return table_cache_stats()

    @staticmethod
    def clear_caches() -> None:
        """Drop the shared table caches (benchmark fairness)."""
        clear_table_caches()
