"""Energy / power computation — paper Sec. VI.

E_total = E_SA + E_SIMD + E_S + E_D                         (Eq. 29)
E_SA    = (C_SA * P_SA_dyn + L_total * P_SA_leak) * T_clk    (Eq. 30)
E_S     = sum_buff A_S_buff * e_buff ;  E_D = A_D * e_D      (Eq. 31)
P_avg   = E_total / (L_total * T_clk)                        (Eq. 32)

Constants: the paper uses proprietary post-SP&R data (commercial 12nm flow)
and a commercial memory compiler; those are not published. We substitute
openly documented values, recorded here so every number is reproducible:
  * e_D = 3.9 pJ/bit  -- HBM2 access energy (O'Connor et al., MICRO'17 [21])
  * SRAM read/write energy: CACTI-style capacity fit at ~14/12nm,
    e_sram(S) = 0.035 * (S_kB / 32)^0.25 pJ/bit  (anchors near ~0.03-0.08
    pJ/bit for 32kB-2MB banks reported for 14nm compilers)
  * MAC dynamic power: 16b ~0.35 mW @1GHz, 8b ~0.12 mW (DNN-accel surveys);
    SIMD 32b ALU+ctrl ~0.6 mW; leakage = 8% of array dynamic.
  * T_clk = 1 ns (1 GHz, the GeneSys 12nm design point).
Absolute energy therefore carries these constants' uncertainty; the paper's
*claims* we validate are fractions (non-Conv share) and ratios (DSE gains),
which are insensitive to uniform constant scaling.
"""
from __future__ import annotations

import sys
from dataclasses import dataclass
from typing import Dict, Mapping, Union

import numpy as np

from .hardware import HardwareSpec


def array_namespace(x) -> object:
    """``jax.numpy`` if ``x`` is a jax array, else ``numpy``.  Keeps the
    batched energy/objective math on whichever backend produced the
    cycles grid (the device DSE backend feeds jnp grids) without
    importing jax on the numpy path — if ``x`` is a jax array, jax is
    necessarily already in ``sys.modules``."""
    jax = sys.modules.get("jax")
    if jax is not None and isinstance(x, jax.Array):
        import jax.numpy as jnp
        return jnp
    return np

PJ = 1e-12

ArrayLike = Union[int, float, np.ndarray]


@dataclass(frozen=True)
class EnergyModel:
    t_clk_s: float = 1e-9
    e_dram_pj_per_bit: float = 3.9
    mac_dyn_w_16b: float = 0.35e-3
    mac_dyn_w_8b: float = 0.12e-3
    alu_dyn_w: float = 0.6e-3
    leak_frac: float = 0.08

    def e_sram_pj_per_bit(self, size_bytes: ArrayLike) -> ArrayLike:
        """Per-bit SRAM access energy; accepts a scalar size in bytes or an
        ndarray of sizes (one per design-space candidate)."""
        if np.ndim(size_bytes) == 0:
            kb = max(1.0, size_bytes / 1024.0)
            return 0.035 * (kb / 32.0) ** 0.25
        kb = np.maximum(1.0, np.asarray(size_bytes, dtype=float) / 1024.0)
        return 0.035 * (kb / 32.0) ** 0.25

    def p_sa_dyn(self, hw: HardwareSpec) -> float:
        per_mac = self.mac_dyn_w_16b if hw.b_w >= 16 else self.mac_dyn_w_8b
        return hw.J * hw.K * per_mac

    def p_simd_dyn(self, hw: HardwareSpec) -> float:
        return hw.K * self.alu_dyn_w

    def p_sa_leak(self, hw: HardwareSpec) -> float:
        return self.leak_frac * self.p_sa_dyn(hw)

    def p_simd_leak(self, hw: HardwareSpec) -> float:
        return self.leak_frac * self.p_simd_dyn(hw)


DEFAULT_ENERGY = EnergyModel()


def compute_energy(hw: HardwareSpec,
                   c_sa: int, c_simd: int, l_total: int,
                   sram_bits: Dict[str, int], dram_bits: int,
                   em: EnergyModel = DEFAULT_ENERGY) -> Dict[str, float]:
    """Returns a breakdown in Joules + average power in Watts."""
    e_sa = (c_sa * em.p_sa_dyn(hw) + l_total * em.p_sa_leak(hw)) * em.t_clk_s
    e_simd = (c_simd * em.p_simd_dyn(hw)
              + l_total * em.p_simd_leak(hw)) * em.t_clk_s

    buf_size = {"wbuf": hw.wbuf, "ibuf": hw.ibuf, "obuf": hw.obuf,
                "bbuf": hw.bbuf, "vmem": hw.vmem, "imem": hw.imem}
    e_s = sum(bits * em.e_sram_pj_per_bit(buf_size.get(buf, hw.vmem)) * PJ
              for buf, bits in sram_bits.items())
    e_d = dram_bits * em.e_dram_pj_per_bit * PJ

    e_total = e_sa + e_simd + e_s + e_d
    runtime_s = l_total * em.t_clk_s
    return {
        "E_SA": e_sa, "E_SIMD": e_simd, "E_S": e_s, "E_D": e_d,
        "E_total": e_total,
        "runtime_s": runtime_s,
        "P_avg": (e_total / runtime_s) if runtime_s > 0 else 0.0,
    }


# Canonical buffer order of the batched SRAM-energy sum.  It matches the
# insertion order of ``NetworkReport.sram_bits_by_buffer()`` on conv-first
# networks (all paper workloads), so the sequential accumulation below adds
# the same terms in the same order as the scalar ``compute_energy`` —
# float-identical, not merely close.
SRAM_BUFFER_ORDER = ("wbuf", "ibuf", "obuf", "bbuf", "vmem")


def compute_energy_batch(hw: HardwareSpec, *,
                         c_sa: ArrayLike, c_simd: ArrayLike,
                         l_total: ArrayLike,
                         sram_bits: Mapping[str, ArrayLike],
                         sram_sizes: Mapping[str, ArrayLike],
                         dram_bits: ArrayLike,
                         em: EnergyModel = DEFAULT_ENERGY
                         ) -> Dict[str, np.ndarray]:
    """Vectorized ``compute_energy``: every input may be an ndarray of
    per-candidate values (broadcast against each other), and — unlike the
    scalar path, where one ``hw`` fixes every buffer size — ``sram_sizes``
    carries a per-candidate size array for each buffer, so one call prices
    an entire design-space grid.  Term structure and accumulation order
    mirror the scalar function exactly (Eqs. 29-32).

    ``l_total`` may be a jax array (the device DSE backend): every term
    is elementwise, so the report stays on device with the same IEEE
    operations — bit-identical to the numpy path."""
    xp = array_namespace(l_total)
    e_sa = (c_sa * em.p_sa_dyn(hw) + l_total * em.p_sa_leak(hw)) * em.t_clk_s
    e_simd = (c_simd * em.p_simd_dyn(hw)
              + l_total * em.p_simd_leak(hw)) * em.t_clk_s

    e_s = 0.0
    for buf in SRAM_BUFFER_ORDER:
        if buf in sram_bits:
            e_s = e_s + (sram_bits[buf]
                         * em.e_sram_pj_per_bit(sram_sizes[buf]) * PJ)
    for buf in sram_bits:            # non-canonical buffers, if any
        if buf not in SRAM_BUFFER_ORDER:
            e_s = e_s + (sram_bits[buf]
                         * em.e_sram_pj_per_bit(sram_sizes[buf]) * PJ)
    e_d = dram_bits * em.e_dram_pj_per_bit * PJ

    e_total = e_sa + e_simd + e_s + e_d
    runtime_s = xp.asarray(l_total, dtype=float) * em.t_clk_s
    with np.errstate(divide="ignore", invalid="ignore"):
        p_avg = xp.where(runtime_s > 0, e_total / runtime_s, 0.0)
    return {
        "E_SA": xp.asarray(e_sa, dtype=float),
        "E_SIMD": xp.asarray(e_simd, dtype=float),
        "E_S": xp.asarray(e_s + xp.zeros_like(runtime_s), dtype=float),
        "E_D": xp.asarray(e_d + xp.zeros_like(runtime_s), dtype=float),
        "E_total": xp.asarray(e_total, dtype=float),
        "runtime_s": runtime_s,
        "P_avg": p_avg,
    }
