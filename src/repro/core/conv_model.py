"""Systolic-array (Conv/FC) performance model — paper Sections IV-C, IV-D.

Implements, with ceiling-corrected multipliers (paper footnote 1):
  * DRAM access counts  A_Dw (Eq. 4), A_Di (Eq. 7), A_Dp (Eqs. 9-10),
    A_Db (Eq. 11)                                     [bits]
  * SRAM access counts  (Table III)                   [bits]
  * compute cycles      (Eqs. 15-16, PSO_SA = (J-1)+(K-1))
  * DRAM stall cycles   under double buffering via the exhaustive 4-valid-
    case tile-segment analysis (Table IV, Fig. 6, Eqs. 17-18).

Also provides the two degraded baselines of Fig. 5 ("No-Stall" and
"Simplified") for the accuracy comparison benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Sequence

import numpy as np

from .hardware import HardwareSpec
from .layers import ConvLayer
from .tiling import ConvTiling, ceil_div, make_conv_tiling


@dataclass
class PerfStats:
    """Per-layer performance statistics (the SimDIT output interface)."""
    engine: str = "sa"                       # 'sa' | 'simd'
    compute_cycles: int = 0
    stall_cycles: int = 0
    dram_bits: Dict[str, int] = field(default_factory=dict)   # by stream
    sram_bits: Dict[str, int] = field(default_factory=dict)   # by buffer
    ops: Dict[str, int] = field(default_factory=dict)         # arithmetic op counts

    @property
    def total_cycles(self) -> int:
        return self.compute_cycles + self.stall_cycles

    @property
    def dram_total_bits(self) -> int:
        return sum(self.dram_bits.values())

    @property
    def sram_total_bits(self) -> int:
        return sum(self.sram_bits.values())

    def merged(self, other: "PerfStats") -> "PerfStats":
        out = PerfStats(engine=self.engine,
                        compute_cycles=self.compute_cycles + other.compute_cycles,
                        stall_cycles=self.stall_cycles + other.stall_cycles)
        for src, dst in ((self.dram_bits, out.dram_bits),
                         (other.dram_bits, out.dram_bits)):
            for k, v in src.items():
                dst[k] = dst.get(k, 0) + v
        for src, dst in ((self.sram_bits, out.sram_bits),
                         (other.sram_bits, out.sram_bits)):
            for k, v in src.items():
                dst[k] = dst.get(k, 0) + v
        for src in (self.ops, other.ops):
            for k, v in src.items():
                out.ops[k] = out.ops.get(k, 0) + v
        return out


@dataclass(frozen=True)
class ConvMultipliers:
    """Outer (m_*) and inner (r_*) loop multipliers (Eqs. 1, 12)."""
    m_oh: int; m_ow: int; m_n: int; m_kh: int; m_kw: int; m_ic: int; m_oc: int
    r_oh: int; r_ow: int; r_n: int; r_kh: int; r_kw: int; r_ic: int; r_oc: int

    @property
    def m_outer(self) -> int:                      # Eq. 14
        return (self.m_oh * self.m_ow * self.m_n * self.m_kh * self.m_kw
                * self.m_ic * self.m_oc)

    @property
    def m_w_tile(self) -> int:                     # Eq. 3
        return self.m_kh * self.m_kw * self.m_ic * self.m_oc

    @property
    def m_spatial(self) -> int:                    # m_oh * m_ow * m_n
        return self.m_oh * self.m_ow * self.m_n

    @property
    def m_accum(self) -> int:                      # m_kh * m_kw * m_ic
        return self.m_kh * self.m_kw * self.m_ic

    @property
    def m_inner(self) -> int:                      # Eq. 13
        return (self.r_oh * self.r_ow * self.r_n * self.r_kh * self.r_kw
                * self.r_ic * self.r_oc)


def conv_multipliers(layer: ConvLayer, t: ConvTiling) -> ConvMultipliers:
    return ConvMultipliers(
        m_oh=ceil_div(layer.oh, t.T_oh), m_ow=ceil_div(layer.ow, t.T_ow),
        m_n=ceil_div(layer.n, t.T_n), m_kh=ceil_div(layer.kh, t.T_kh),
        m_kw=ceil_div(layer.kw, t.T_kw), m_ic=ceil_div(layer.ic, t.T_ic),
        m_oc=ceil_div(layer.oc, t.T_oc),
        r_oh=t.T_oh, r_ow=t.T_ow, r_n=t.T_n, r_kh=t.T_kh, r_kw=t.T_kw,
        r_ic=ceil_div(t.T_ic, t.t_ic), r_oc=ceil_div(t.T_oc, t.t_oc))


# ---------------------------------------------------------------------------
# DRAM accesses (Sec. IV-C)
# ---------------------------------------------------------------------------

def conv_dram_bits(hw: HardwareSpec, layer: ConvLayer, t: ConvTiling,
                   m: ConvMultipliers) -> Dict[str, int]:
    v_w = t.weight_tile_elems()                               # Eq. 2
    a_dw = v_w * m.m_w_tile * hw.b_w                          # Eq. 4

    v_i = t.ifmap_tile_elems(layer.s)                         # Eq. 5
    a_di = v_i * m.m_outer * hw.b_i                           # Eqs. 6-7

    v_p = t.psum_tile_elems()                                 # Eq. 8
    m_p = m.m_spatial * m.m_oc * (2 * m.m_accum - 1)          # Eq. 9
    a_dp = v_p * m_p * hw.b_p                                 # Eq. 10

    a_db = t.T_oc * m.m_oc * hw.b_b if layer.has_bias else 0  # Eq. 11
    return {"weight": a_dw, "ifmap": a_di, "psum": a_dp, "bias": a_db}


# ---------------------------------------------------------------------------
# SRAM accesses (Table III)
# ---------------------------------------------------------------------------

def conv_sram_bits(hw: HardwareSpec, layer: ConvLayer, t: ConvTiling,
                   m: ConvMultipliers) -> Dict[str, int]:
    iters = m.m_inner * m.m_outer
    v_w_i = t.T_kh * t.T_kw * t.t_ic * t.t_oc // (t.T_kh * t.T_kw)  # inner tile
    # Inner tiles have t_phi = 1 on every dim except ic/oc (Fig. 4):
    v_w_inner = t.t_ic * t.t_oc
    v_i_inner = t.t_ic
    v_p_inner = t.t_oc
    ofmap_elems = layer.ofmap_elems

    a_sw = v_w_inner * iters * hw.b_w
    a_si = v_i_inner * iters * hw.b_i
    a_sp = (v_p_inner * 2 * iters - ofmap_elems) * hw.b_p
    a_sb = ofmap_elems * hw.b_b if layer.has_bias else 0
    return {"wbuf": a_sw, "ibuf": a_si, "obuf": a_sp, "bbuf": a_sb}


# ---------------------------------------------------------------------------
# Cycle counts (Sec. IV-D)
# ---------------------------------------------------------------------------

def conv_tile_compute_cycles(hw: HardwareSpec, t: ConvTiling) -> int:
    """Eq. 15."""
    return (t.T_oh * t.T_ow * t.T_n * t.T_kh * t.T_kw
            * ceil_div(t.T_ic, hw.J) * ceil_div(t.T_oc, hw.K))


def conv_compute_cycles(hw: HardwareSpec, layer: ConvLayer, t: ConvTiling,
                        m: ConvMultipliers) -> int:
    """Eq. 16 (includes per-tile pipeline setup overhead)."""
    return (conv_tile_compute_cycles(hw, t) + hw.pso_sa) * m.m_outer


@dataclass(frozen=True)
class ConvSegmentQuantities:
    """Bandwidth-independent per-tile quantities of the Table IV / Eq. 18
    tile-segment stall model: per-tile compute cycles, the four valid-case
    occurrence counts, and the per-stream DRAM bit volumes.  They depend
    only on the tiling (i.e. buffer *sizes*), so a bandwidth sweep over a
    fixed size configuration reuses one instance (the property the
    tensorized DSE in ``core.dse`` exploits)."""
    c_tile: int                               # compute cycles/tile incl. PSO
    o1: int; o2: int; o4: int; o5: int        # case occurrence counts
    w_bits: int                               # weight tile
    wb_bits: int                              # weight + bias tile
    i_bits: int                               # ifmap tile
    ps_bits: int                              # psum store only
    pls_bits: int                             # psum load + store (2x)


def conv_segment_quantities(hw: HardwareSpec, layer: ConvLayer,
                            t: ConvTiling, m: ConvMultipliers
                            ) -> ConvSegmentQuantities:
    """Occurrence counts (Sec. IV-D, Case-4 derivation generalized) and
    per-stream tile volumes shared by ``conv_stall_cycles`` and the DSE
    cost tables."""
    o5 = m.m_oc
    o4 = m.m_w_tile - m.m_oc                                    # Eq. 17
    o1 = m.m_oc * (m.m_spatial - 1)
    o2 = (m.m_outer - m.m_spatial * m.m_oc) - o4
    assert o1 >= 0 and o2 >= 0 and o4 >= 0
    assert o1 + o2 + o4 + o5 == m.m_outer

    w_bits = t.weight_tile_elems() * hw.b_w
    b_bits = t.T_oc * hw.b_b if layer.has_bias else 0
    p_bits = t.psum_tile_elems() * hw.b_p
    return ConvSegmentQuantities(
        c_tile=conv_tile_compute_cycles(hw, t) + hw.pso_sa,
        o1=o1, o2=o2, o4=o4, o5=o5,
        w_bits=w_bits, wb_bits=w_bits + b_bits,
        i_bits=t.ifmap_tile_elems(layer.s) * hw.b_i,
        ps_bits=p_bits, pls_bits=2 * p_bits)


def conv_quantities_batch(hw: HardwareSpec, layer: ConvLayer,
                          tilings: Sequence[ConvTiling]
                          ) -> Dict[str, np.ndarray]:
    """Vectorized per-candidate cost-table quantities for ONE layer across
    many tilings (one per buffer-size candidate): the
    ``ConvSegmentQuantities`` fields plus the busy/DRAM/SRAM energy
    tensors a ``ConvTable`` column carries.  Bit-identical per candidate
    to the scalar ``conv_segment_quantities`` / ``conv_dram_bits`` /
    ``conv_sram_bits`` / ``conv_tile_compute_cycles`` composition (same
    integer arithmetic, evaluated on the candidate axis), which is what
    lets ``dse.batch_build_conv_tables`` assemble whole table lattices
    without a per-(size, layer) Python walk.

    ``tilings`` is either a sequence of ``ConvTiling``s or the
    struct-of-arrays 9-tuple ``tiling._derive_conv_tiling_arrays``
    returns (the zero-materialization fast path)."""
    if isinstance(tilings, tuple) and len(tilings) == 9 \
            and isinstance(tilings[0], np.ndarray):
        T_oh, T_ow, T_n, T_kh, T_kw, T_ic, T_oc, t_ic, t_oc = tilings
    else:
        f = np.array([[t.T_oh, t.T_ow, t.T_n, t.T_kh, t.T_kw, t.T_ic,
                       t.T_oc, t.t_ic, t.t_oc] for t in tilings],
                     dtype=np.int64).T
        T_oh, T_ow, T_n, T_kh, T_kw, T_ic, T_oc, t_ic, t_oc = f

    def cd(a, b):
        return -(-a // b)

    m_oh = cd(layer.oh, T_oh); m_ow = cd(layer.ow, T_ow)
    m_n = cd(layer.n, T_n); m_kh = cd(layer.kh, T_kh)
    m_kw = cd(layer.kw, T_kw); m_ic = cd(layer.ic, T_ic)
    m_oc = cd(layer.oc, T_oc)
    r_ic = cd(T_ic, t_ic); r_oc = cd(T_oc, t_oc)
    m_w_tile = m_kh * m_kw * m_ic * m_oc
    m_spatial = m_oh * m_ow * m_n
    m_accum = m_kh * m_kw * m_ic
    m_outer = m_spatial * m_w_tile
    m_inner = T_oh * T_ow * T_n * T_kh * T_kw * r_ic * r_oc

    c_tile = (T_oh * T_ow * T_n * T_kh * T_kw
              * cd(T_ic, hw.J) * cd(T_oc, hw.K)) + hw.pso_sa
    o5 = m_oc
    o4 = m_w_tile - m_oc                                        # Eq. 17
    o1 = m_oc * (m_spatial - 1)
    o2 = (m_outer - m_spatial * m_oc) - o4
    assert (o1 >= 0).all() and (o2 >= 0).all() and (o4 >= 0).all()
    assert (o1 + o2 + o4 + o5 == m_outer).all()

    w_elems = T_kh * T_kw * T_ic * T_oc                         # Eq. 2
    ih = (T_oh - 1) * layer.s + T_kh
    iw = (T_ow - 1) * layer.s + T_kw
    i_elems = ih * iw * T_n * T_ic                              # Eq. 5
    p_elems = T_oh * T_ow * T_n * T_oc                          # Eq. 8
    w_bits = w_elems * hw.b_w
    b_bits = T_oc * hw.b_b if layer.has_bias else 0
    ps_bits = p_elems * hw.b_p

    m_p = m_spatial * m_oc * (2 * m_accum - 1)                  # Eq. 9
    dram = (w_elems * m_w_tile * hw.b_w                         # Eq. 4
            + i_elems * m_outer * hw.b_i                        # Eqs. 6-7
            + p_elems * m_p * hw.b_p                            # Eq. 10
            + (T_oc * m_oc * hw.b_b if layer.has_bias else 0))  # Eq. 11

    iters = m_inner * m_outer                                   # Table III
    ofmap_elems = layer.ofmap_elems
    sram = {"wbuf": t_ic * t_oc * iters * hw.b_w,
            "ibuf": t_ic * iters * hw.b_i,
            "obuf": (t_oc * 2 * iters - ofmap_elems) * hw.b_p,
            "bbuf": (np.full(len(T_oc), ofmap_elems * hw.b_b, dtype=np.int64)
                     if layer.has_bias
                     else np.zeros(len(T_oc), dtype=np.int64))}
    return {"c_tile": c_tile, "o1": o1, "o2": o2, "o4": o4, "o5": o5,
            "w_bits": w_bits, "wb_bits": w_bits + b_bits,
            "i_bits": i_elems * hw.b_i,
            "ps_bits": ps_bits, "pls_bits": 2 * ps_bits,
            "busy": c_tile * m_outer, "dram": dram, "sram": sram}


def conv_stall_cycles(hw: HardwareSpec, layer: ConvLayer, t: ConvTiling,
                      m: ConvMultipliers) -> int:
    """Tile-segment DRAM stall model (Table IV; Fig. 6; Eqs. 17-18).

    Valid cases (weight+bias load / weight load / psum load):
      Case-1: 0/0/0 -- weight reused, first accumulation step already done
      Case-2: 0/0/1 -- weight reused, psum accumulation continues
      Case-4: 0/1/1 -- new weight tile mid-accumulation
      Case-5: 1/0/0 -- new weight+bias tile at an oc-loop boundary
    Every case also performs the always-on ifmap load and psum/ofmap store.
    Per-tile segment time = max over the parallel DRAM interfaces and the
    compute (Fig. 6(b)); psum load & store share the OBuf interface and are
    serialized (the 2x term of Eq. 18).
    """
    q = conv_segment_quantities(hw, layer, t, m)
    t_w = ceil_div(q.w_bits, hw.bw_w)
    t_wb = ceil_div(q.wb_bits, hw.bw_w)
    t_i = ceil_div(q.i_bits, hw.bw_i)
    t_ps = ceil_div(q.ps_bits, hw.bw_o)        # store only
    t_pls = ceil_div(q.pls_bits, hw.bw_o)      # load + store, shared interface

    seg1 = max(q.c_tile, t_i, t_ps)
    seg2 = max(q.c_tile, t_i, t_pls)
    seg4 = max(q.c_tile, t_w, t_i, t_pls)                       # Eq. 18
    seg5 = max(q.c_tile, t_wb, t_i, t_ps)

    total_time = (q.o1 * seg1 + q.o2 * seg2
                  + q.o4 * seg4 + q.o5 * seg5)
    compute = q.c_tile * m.m_outer
    return max(0, total_time - compute)


# ---------------------------------------------------------------------------
# Top-level per-layer entry points
# ---------------------------------------------------------------------------

def simulate_conv(hw: HardwareSpec, layer: ConvLayer,
                  t: ConvTiling | None = None,
                  stall_model: str = "simdit") -> PerfStats:
    """Full SimDIT Conv model. ``stall_model`` in {simdit, no_stall,
    simplified} — the latter two reproduce the Fig. 5 baselines."""
    if t is None:
        t = make_conv_tiling(hw, layer)
    m = conv_multipliers(layer, t)
    dram = conv_dram_bits(hw, layer, t, m)
    sram = conv_sram_bits(hw, layer, t, m)
    compute = conv_compute_cycles(hw, layer, t, m)

    if stall_model == "no_stall":
        stall = 0
    elif stall_model == "simplified":
        # max of isolated totals across the four parallel components
        t_wb = ceil_div(dram["weight"] + dram["bias"], hw.bw_w)
        t_i = ceil_div(dram["ifmap"], hw.bw_i)
        t_p = ceil_div(dram["psum"], hw.bw_o)
        stall = max(0, max(compute, t_wb, t_i, t_p) - compute)
    else:
        stall = conv_stall_cycles(hw, layer, t, m)

    macs = layer.macs
    ops = {"mac": macs}
    if layer.has_bias:
        ops["add"] = layer.ofmap_elems
    return PerfStats(engine="sa", compute_cycles=compute, stall_cycles=stall,
                     dram_bits=dram, sram_bits=sram, ops=ops)
