"""Hardware specification for the SimDIT accelerator model (paper Table II).

Two engines (paper Sec. III):
  * a J x K systolic PE array for Conv/FC (``weight-stationary``), fed by
    four double-buffered SRAMs (WBuf, BBuf, IBuf, OBuf), and
  * a 1 x K SIMD ALU array for every non-Conv op, fed by a single-buffered
    vector memory (VMem) plus an instruction memory (IMem).

Units convention used throughout ``repro.core``:
  * buffer sizes     : bytes
  * bit widths       : bits
  * DRAM bandwidths  : bits / cycle (per off-chip interface, as in the paper)
  * access counts    : bits (the paper's ``A_* = V * M * b`` form); element
                       counts are reported separately where useful.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict

KB = 1024


@dataclass(frozen=True)
class HardwareSpec:
    """Parameterizable accelerator substrate (paper Table II)."""

    name: str = "custom"
    # Systolic array
    J: int = 64                      # PE rows   (ic mapped along rows)
    K: int = 64                      # PE cols   (oc mapped along cols; also #ALUs)
    wbuf: int = 1024 * KB            # weight buffer, bytes
    bbuf: int = 32 * KB              # bias buffer, bytes
    ibuf: int = 512 * KB             # ifmap buffer, bytes
    obuf: int = 1024 * KB            # ofmap/psum buffer, bytes
    # SIMD array
    vmem: int = 1024 * KB            # vector memory, bytes
    imem: int = 64 * KB              # instruction memory, bytes
    # Bit widths (systolic)
    b_w: int = 16                    # weight
    b_b: int = 32                    # bias
    b_i: int = 16                    # ifmap
    b_p: int = 32                    # psum / ofmap
    # Bit widths (SIMD)
    b_in: int = 32
    b_out: int = 32
    # Per-interface DRAM bandwidth, bits/cycle
    bw_w: int = 512                  # shared WBuf + BBuf interface
    bw_i: int = 512                  # IBuf interface
    bw_o: int = 512                  # OBuf interface
    bw_v: int = 512                  # VMem interface
    # ALU issue cycles per arithmetic op type. The SIMD array is pipelined
    # (Sec. IV-E: "pipeline stages ... similar to a general MIPS processor"),
    # so simple ops sustain 1/cycle; iterative ops (div, sqrt) cost more.
    # hash=False keeps the frozen spec hashable (dicts aren't); two specs
    # differing only in ``lat`` hash-collide but still compare unequal.
    lat: Dict[str, int] = field(hash=False, default_factory=lambda: dict(
        add=1, sub=1, mul=1, div=2, max=1, cmp=1, exp=2, sqrt=2, rsqrt=2, copy=1))

    # ---- derived helpers -------------------------------------------------
    @property
    def pso_sa(self) -> int:
        """Systolic pipeline setup overhead per outer tile: (J-1)+(K-1)."""
        return (self.J - 1) + (self.K - 1)

    @property
    def pso_simd(self) -> int:
        """SIMD pipeline setup overhead: 6-stage MIPS pipe + K-ALU skew."""
        return (6 - 1) + (self.K - 1)

    def lam(self, op: str) -> int:
        return self.lat[op]

    def replace(self, **kw) -> "HardwareSpec":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Paper presets (Sec. VII-A).
#
# Training hardware HT1-3: 16-bit ifmap/weight, 32-bit psum, 32-bit SIMD.
# Inference hardware HI1-3:  8-bit ifmap/weight, 32-bit psum, 32-bit SIMD.
# "Bandwidth per off-chip interface = X bits/cycle" applies to each of the
# four interfaces.
# ---------------------------------------------------------------------------

def _train_bits() -> dict:
    return dict(b_w=16, b_i=16, b_p=32, b_b=32, b_in=32, b_out=32)


def _infer_bits() -> dict:
    return dict(b_w=8, b_i=8, b_p=32, b_b=32, b_in=32, b_out=32)


HT1 = HardwareSpec(name="HT1", J=16, K=16,
                   wbuf=256 * KB, ibuf=128 * KB, obuf=256 * KB, vmem=256 * KB,
                   bbuf=16 * KB, bw_w=128, bw_i=128, bw_o=128, bw_v=128,
                   **_train_bits())
HT2 = HardwareSpec(name="HT2", J=32, K=32,
                   wbuf=512 * KB, ibuf=256 * KB, obuf=512 * KB, vmem=512 * KB,
                   bbuf=32 * KB, bw_w=256, bw_i=256, bw_o=256, bw_v=256,
                   **_train_bits())
HT3 = HardwareSpec(name="HT3", J=64, K=64,
                   wbuf=1024 * KB, ibuf=512 * KB, obuf=1024 * KB, vmem=1024 * KB,
                   bbuf=64 * KB, bw_w=512, bw_i=512, bw_o=512, bw_v=512,
                   **_train_bits())

HI1 = HardwareSpec(name="HI1", J=16, K=16,
                   wbuf=32 * KB, ibuf=32 * KB, obuf=128 * KB, vmem=128 * KB,
                   bbuf=16 * KB, bw_w=128, bw_i=128, bw_o=128, bw_v=128,
                   **_infer_bits())
HI2 = HardwareSpec(name="HI2", J=32, K=32,
                   wbuf=256 * KB, ibuf=128 * KB, obuf=512 * KB, vmem=512 * KB,
                   bbuf=32 * KB, bw_w=256, bw_i=256, bw_o=256, bw_v=256,
                   **_infer_bits())
HI3 = HardwareSpec(name="HI3", J=64, K=64,
                   wbuf=512 * KB, ibuf=256 * KB, obuf=1024 * KB, vmem=1024 * KB,
                   bbuf=64 * KB, bw_w=512, bw_i=512, bw_o=512, bw_v=512,
                   **_infer_bits())

TRAIN_PRESETS = {16: HT1, 32: HT2, 64: HT3}
INFER_PRESETS = {16: HI1, 32: HI2, 64: HI3}
