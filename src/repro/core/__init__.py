"""SimDIT core: the paper's analytical performance model, faithfully
reimplemented (Secs. III-VI), plus the TPU-adapted instantiation used by the
framework's roofline/DSE machinery (``tpu_model``)."""
from .hardware import (HI1, HI2, HI3, HT1, HT2, HT3, INFER_PRESETS,
                       TRAIN_PRESETS, HardwareSpec)
from .layers import ConvLayer, SimdLayer, fc, phase_key
from .simulator import NetworkReport, simulate, simulate_network
from .backward import dx_conv, dw_conv, expand_training_graph
from .objectives import (EDP, Cycles, CyclesUnderPowerCap, Energy,
                         Objective, register_objective, resolve_objective)
from .store import TableStore, store_context
from .study import IntegrityError, Study, Workload

__all__ = [
    "HardwareSpec", "HT1", "HT2", "HT3", "HI1", "HI2", "HI3",
    "TRAIN_PRESETS", "INFER_PRESETS",
    "ConvLayer", "SimdLayer", "fc", "phase_key",
    "NetworkReport", "simulate", "simulate_network",
    "dx_conv", "dw_conv", "expand_training_graph",
    "Study", "Workload", "Objective", "Cycles", "Energy", "EDP",
    "CyclesUnderPowerCap", "register_objective", "resolve_objective",
    "TableStore", "store_context", "IntegrityError",
]
