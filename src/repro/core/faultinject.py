"""Deterministic fault injection for the DSE durability layer.

The robustness contract of the persistent table store (``core.store``)
and the fault-tolerant parallel table builds (``core.dse``) is only
worth anything if every recovery path is actually exercised, so this
module provides *deterministic, countable* fault hooks in the spirit of
the step watchdog in ``repro.distributed.fault``: production code asks
``fire(point)`` at a named fault point and this module answers "inject
now" a configured number of times, then never again.

Faults are armed either in-process (tests)::

    faultinject.arm("conv_worker_crash", times=1)

or through the ``REPRO_FAULTS`` environment variable (CI / subprocess
harnesses), a comma-separated list of ``point[:times[:arg]]`` items::

    REPRO_FAULTS="conv_worker_crash:2,store_corrupt:1,conv_worker_hang:1:30"

Known fault points (the arg is point-specific):

=====================  =====================================================
``conv_worker_exc``    a parallel ConvTable build task raises in the worker
``conv_worker_crash``  a worker hard-exits mid-task (``os._exit``) — the
                       pool surfaces ``BrokenProcessPool``
``conv_worker_hang``   a worker sleeps ``arg`` seconds (default 3600),
                       tripping the per-attempt build timeout
``store_corrupt``      the table-store file just written gets a flipped
                       byte (checksum failure on next load)
``store_truncate``     the file just written is truncated to half
``store_lock_hold``    the store's advisory lock is held ``arg`` seconds
                       (default 1.0) while inside the critical section,
                       exercising lock-contention timeouts in other
                       writers
``selfcheck_perturb``  the study self-check's reference cycles are
                       perturbed by ``arg`` (default 1) — proves the
                       integrity comparison actually trips on drift
``service_batch_exc``  a ``repro.serve`` grouped dispatch raises before
                       pricing — the service must degrade to per-request
                       serial evaluation, not drop the batch
``service_request_hang``  a ``repro.serve`` pricing call sleeps ``arg``
                       seconds (default 3600), tripping the service
                       watchdog; in degraded serial mode only the hung
                       request times out
=====================  =====================================================

Counts are consumed in the process that *queries* the fault point.  The
parallel-build faults are deliberately consumed on the submission side
(in the parent) and shipped to the worker as task directives, so
``times=1`` means exactly one poisoned task — not one per forked worker.

Everything here is inert unless armed: ``fire`` on an unarmed point is a
dict lookup returning ``None``.
"""
from __future__ import annotations

import os
import threading
import warnings
from dataclasses import dataclass, replace
from typing import Dict, Optional

ENV_VAR = "REPRO_FAULTS"

# The canonical fault-point registry.  Injection sites (``fire``), tests
# (``arm`` / REPRO_FAULTS specs), and the docstring table above must all
# use these names; ``repro.analysis`` cross-checks the three sets, and
# ``arm`` warns on a name not listed here.
FAULT_POINTS: Dict[str, str] = {
    "conv_worker_exc": "parallel ConvTable build task raises in the worker",
    "conv_worker_crash": "worker hard-exits mid-task (BrokenProcessPool)",
    "conv_worker_hang": "worker sleeps arg seconds, tripping build timeout",
    "store_corrupt": "table-store file gets a flipped byte after write",
    "store_truncate": "table-store file truncated to half after write",
    "store_lock_hold": "store advisory lock held arg seconds in-section",
    "selfcheck_perturb": "self-check reference cycles perturbed by arg",
    "service_batch_exc": "serve grouped dispatch raises before pricing",
    "service_request_hang": "serve pricing call sleeps arg seconds",
}


@dataclass
class Fault:
    """One armed fault: remaining firing count plus an optional argument
    (seconds for hangs/lock holds)."""
    point: str
    times: int
    arg: Optional[float] = None


# Armed faults are mutated from every thread that prices (the serving
# dispatcher, build workers' parent, tests): all registry state below is
# guarded by one lock.  ``fire`` must be a single atomic
# check-decrement-count — two racing callers must consume two distinct
# firings, never the same one twice.
_FAULT_LOCK = threading.Lock()
_FAULTS: Dict[str, Fault] = {}       # guarded-by: _FAULT_LOCK
_FIRED: Dict[str, int] = {}          # guarded-by: _FAULT_LOCK


def arm(point: str, times: int = 1, arg: Optional[float] = None) -> None:
    """Arm ``point`` to fire on its next ``times`` queries.  Unknown
    points warn (a typo here silently disables a recovery test) but
    still arm."""
    if point not in FAULT_POINTS:
        warnings.warn(
            f"arming unknown fault point {point!r} — not in "
            f"FAULT_POINTS; is it a typo?", RuntimeWarning, stacklevel=2)
    with _FAULT_LOCK:
        _FAULTS[point] = Fault(point, int(times), arg)


def disarm(point: str) -> None:
    with _FAULT_LOCK:
        _FAULTS.pop(point, None)


def reset() -> None:
    """Disarm everything and zero the fired counters (test teardown)."""
    with _FAULT_LOCK:
        _FAULTS.clear()
        _FIRED.clear()


def armed(point: str) -> bool:
    with _FAULT_LOCK:
        f = _FAULTS.get(point)
        return f is not None and f.times != 0


def fired(point: str) -> int:
    """How many times ``point`` has actually fired in this process."""
    with _FAULT_LOCK:
        return _FIRED.get(point, 0)


def fire(point: str) -> Optional[Fault]:
    """Consume one firing of ``point``: returns a snapshot of the armed
    ``Fault`` (for its ``arg``) when the fault should be injected now,
    else ``None``.  ``times < 0`` arms a fault that fires on every
    query.  Atomic: concurrent callers each consume a distinct firing."""
    with _FAULT_LOCK:
        f = _FAULTS.get(point)
        if f is None or f.times == 0:
            return None
        if f.times > 0:
            f.times -= 1
        _FIRED[point] = _FIRED.get(point, 0) + 1
        return replace(f)


def load_env(env: Optional[str] = None) -> None:
    """Arm faults from a ``REPRO_FAULTS``-style spec string (default: the
    environment variable).  Malformed items are skipped with a
    ``RuntimeWarning`` naming the bad item — a typo'd fault spec must
    never silently disable a CI fault suite."""
    spec = os.environ.get(ENV_VAR, "") if env is None else env
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        parts = item.split(":")
        try:
            point = parts[0]
            if not point:
                raise ValueError("empty fault point")
            times = int(parts[1]) if len(parts) > 1 else 1
            arg = float(parts[2]) if len(parts) > 2 else None
            if len(parts) > 3:
                raise ValueError("too many fields")
        except ValueError as exc:
            warnings.warn(
                f"ignoring malformed {ENV_VAR} item {item!r} ({exc}); "
                f"expected point[:times[:arg]]", RuntimeWarning,
                stacklevel=2)
            continue
        arm(point, times, arg)


load_env()
