"""Layer/operation specifications for the SimDIT model (paper Table I).

Two families:
  * ``ConvLayer``  -- executed on the systolic array (Conv + FC, both the
    forward op and the two backward ops after the Table V transforms).
  * ``SimdLayer``  -- executed on the SIMD array.  Every non-Conv op is
    expressed through one generic tile template (paper Sec. IV-B): an
    iteration space (h, w, n, c), a set of 4D/1D input/output tensors, and
    per-element arithmetic op lists.  ``BN_back`` is the two-part schedule
    of Algorithm 1: it is represented as two chained generic parts.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Systolic-array layers
# ---------------------------------------------------------------------------

CONV_PHASES = ("fwd", "bwd_dx", "bwd_dw")
GEMM_PHASES = ("fwd", "bwd_dx", "bwd_dw")
SIMD_PHASES = ("fwd", "bwd")


@dataclass(frozen=True)
class ConvLayer:
    """Conv/FC layer (paper Fig. 3 notation).

    FC layers are convs with kh=kw=ih=iw=oh=ow=1, ic=fan_in, oc=fan_out.
    ``phase`` tags forward vs the two backward ops (after Table V mapping
    both backward ops are *plain convolutions* and reuse the same model).
    """
    name: str
    n: int          # batch
    ic: int
    ih: int
    iw: int
    oc: int
    oh: int
    ow: int
    kh: int
    kw: int
    s: int = 1
    has_bias: bool = True
    phase: str = "fwd"          # fwd | bwd_dx | bwd_dw
    kind: str = "conv"          # conv | fc

    @property
    def macs(self) -> int:
        return self.n * self.oh * self.ow * self.oc * self.kh * self.kw * self.ic

    @property
    def weight_elems(self) -> int:
        return self.kh * self.kw * self.ic * self.oc

    @property
    def ofmap_elems(self) -> int:
        return self.n * self.oh * self.ow * self.oc

    @property
    def ifmap_elems(self) -> int:
        return self.n * self.ih * self.iw * self.ic

    @property
    def is_backward(self) -> bool:
        return self.phase != "fwd"


def fc(name: str, n: int, fan_in: int, fan_out: int, has_bias: bool = True,
       phase: str = "fwd") -> ConvLayer:
    return ConvLayer(name=name, n=n, ic=fan_in, ih=1, iw=1, oc=fan_out,
                     oh=1, ow=1, kh=1, kw=1, s=1, has_bias=has_bias,
                     phase=phase, kind="fc")


@dataclass(frozen=True)
class GemmLayer:
    """Plain GEMM out[m, n] = in[m, k] @ w[k, n] (+ bias[n]) on the
    systolic array — attention/MLP projections map onto the weight-
    stationary array without im2col: k along the J rows (the reduction
    dim, like ``ic``), n along the K columns (like ``oc``), m streamed
    (like the batch-spatial dim).  A GEMM m x n x k is cost-equivalent to
    ``fc(n=m, ic=k, oc=n)``; keeping it a first-class type preserves the
    M/N/K vocabulary, the per-head/per-expert ``count`` multiplicity, and
    the ``param`` distinction the training expansion needs.

    ``count`` repeats the identical GEMM (e.g. batch x heads attention
    score GEMMs): every cost quantity scales linearly, the tiling does
    not depend on it.  ``param=False`` marks activation-activation GEMMs
    (attention scores, A·V) whose "weight" operand is itself an
    activation: the training expansion still emits both operand
    gradients but skips the parameter update."""
    name: str
    m: int          # rows of the output (streamed dim)
    n: int          # cols of the output (mapped on the K array columns)
    k: int          # reduction dim (mapped on the J array rows)
    has_bias: bool = False
    phase: str = "fwd"          # fwd | bwd_dx | bwd_dw
    kind: str = "gemm"
    count: int = 1
    param: bool = True

    @property
    def macs(self) -> int:
        return self.m * self.n * self.k * self.count

    @property
    def weight_elems(self) -> int:
        return self.k * self.n

    @property
    def out_elems(self) -> int:
        return self.m * self.n

    @property
    def in_elems(self) -> int:
        return self.m * self.k

    @property
    def is_backward(self) -> bool:
        return self.phase != "fwd"


def gemm(name: str, m: int, n: int, k: int, has_bias: bool = False,
         phase: str = "fwd", count: int = 1, param: bool = True) -> GemmLayer:
    return GemmLayer(name=name, m=m, n=n, k=k, has_bias=has_bias,
                     phase=phase, count=count, param=param)


# ---------------------------------------------------------------------------
# SIMD-array layers: the generic tile template
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TensorRef:
    """A tensor participating in a SIMD op.

    ``rank`` is '4d' (iterates over h,w,n inside each c tile) or '1d'
    (loaded/stored once per c tile, outside the h/w/n loops -- exactly the
    placement of the 1D tensors in Algorithm 1).
    ``io`` in {'in','out'}.
    ``scale`` multiplies the default tile volume -- used e.g. for pool
    input tiles whose spatial extent is (T-1)*s + r per output tile dim.
    """
    rank: str
    io: str
    scale: float = 1.0


@dataclass(frozen=True)
class SimdPart:
    """One generic part: iteration space + tensors + per-element op lists."""
    tensors: Tuple[TensorRef, ...]
    ops4d: Tuple[str, ...] = ()     # arithmetic ops per 4D element
    ops1d: Tuple[str, ...] = ()     # arithmetic ops per 1D (per-channel) element


@dataclass(frozen=True)
class SimdLayer:
    """A non-Conv layer = 1..2 generic parts over an (h,w,n,c) space."""
    name: str
    op: str
    h: int
    w: int
    n: int
    c: int
    parts: Tuple[SimdPart, ...]
    phase: str = "fwd"
    pool_r: int = 0      # pool window / stride metadata (pool ops only)
    pool_s: int = 0

    @property
    def elems(self) -> int:
        return self.h * self.w * self.n * self.c

    @property
    def is_backward(self) -> bool:
        return self.phase != "fwd"


def phase_key(layer) -> str:
    """Namespaced engine:phase tag of a layer ('conv:fwd', 'gemm:bwd_dw',
    'simd:bwd', ...) — the key space shared by the simulator's per-phase
    aggregates and the DSE phase-resolved cost attribution."""
    if isinstance(layer, ConvLayer):
        family = "conv"
    elif isinstance(layer, GemmLayer):
        family = "gemm"
    else:
        family = "simd"
    return f"{family}:{layer.phase}"


# -- constructors for each modeled op (paper Table I) -----------------------

def tensor_add(name: str, h: int, w: int, n: int, c: int,
               phase: str = "fwd") -> SimdLayer:
    """out = in1 + in2 (paper Sec. IV-E). 1 add / element."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("add",))
    return SimdLayer(name, "tensor_add", h, w, n, c, (part,), phase)


def relu(name: str, h: int, w: int, n: int, c: int,
         phase: str = "fwd") -> SimdLayer:
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "out")),
        ops4d=("max",))
    return SimdLayer(name, "relu", h, w, n, c, (part,), phase)


def relu_back(name: str, h: int, w: int, n: int, c: int) -> SimdLayer:
    """dX = dY * (X > 0): reads dY and X, 1 cmp + 1 mul per element."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("cmp", "mul"))
    return SimdLayer(name, "relu_back", h, w, n, c, (part,), "bwd")


def pool(name: str, oh: int, ow: int, n: int, c: int, r: int, s: int,
         mode: str = "max", phase: str = "fwd") -> SimdLayer:
    """Max/avg pool with an r x r window, stride s.

    Iteration space = output tensor. The input tile for a (Th,Tw) output
    tile spans ((Th-1)s + r) x ((Tw-1)s + r); we fold that into a constant
    volume ``scale`` using the layer-level ratio (exact at full-tensor
    granularity, conservative within tiles).
    Per output element: (r*r - 1) max ops, or (r*r - 1) adds + 1 mul (avg,
    multiply by 1/r^2).
    """
    ih = (oh - 1) * s + r
    iw = (ow - 1) * s + r
    scale = (ih * iw) / float(oh * ow)
    if mode == "max":
        ops: Tuple[str, ...] = ("max",) * (r * r - 1)
    else:
        ops = ("add",) * (r * r - 1) + ("mul",)
    part = SimdPart(
        tensors=(TensorRef("4d", "in", scale=scale), TensorRef("4d", "out")),
        ops4d=ops)
    return SimdLayer(name, f"pool_{mode}", oh, ow, n, c, (part,), phase,
                     pool_r=r, pool_s=s)


def global_avg_pool(name: str, ih: int, iw: int, n: int, c: int,
                    phase: str = "fwd") -> SimdLayer:
    """Global average pool: output is 1x1; iterate over the input space and
    accumulate per channel (1 add / input element), then 1 mul per channel."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("1d", "out")),
        ops4d=("add",),
        ops1d=("mul",))
    return SimdLayer(name, "gap", ih, iw, n, c, (part,), phase)


def pool_back(name: str, oh: int, ow: int, n: int, c: int, r: int, s: int,
              mode: str = "max") -> SimdLayer:
    """Backward of pool.

    max: route dY to the argmax -- reads dY and the saved argmax index map,
         writes dX (input-sized): 1 cmp + 1 mul per *input* element.
    avg: dX = broadcast(dY) / r^2 : 1 mul per input element.
    Iteration space = input tensor (the written gradient)."""
    ih = (oh - 1) * s + r
    iw = (ow - 1) * s + r
    scale_out = (oh * ow) / float(ih * iw)
    if mode == "max":
        tensors = (TensorRef("4d", "in", scale=scale_out),   # dY
                   TensorRef("4d", "in", scale=scale_out),   # argmax map
                   TensorRef("4d", "out"))                   # dX
        ops: Tuple[str, ...] = ("cmp", "mul")
    else:
        tensors = (TensorRef("4d", "in", scale=scale_out), TensorRef("4d", "out"))
        ops = ("mul",)
    part = SimdPart(tensors=tensors, ops4d=ops)
    return SimdLayer(name, f"pool_{mode}_back", ih, iw, n, c, (part,), "bwd")


def gap_back(name: str, ih: int, iw: int, n: int, c: int) -> SimdLayer:
    """Backward of global-avg-pool: dX = dY / (ih*iw), broadcast."""
    part = SimdPart(
        tensors=(TensorRef("1d", "in"), TensorRef("4d", "out")),
        ops4d=("mul",))
    return SimdLayer(name, "gap_back", ih, iw, n, c, (part,), "bwd")


def batch_norm(name: str, h: int, w: int, n: int, c: int,
               phase: str = "fwd") -> SimdLayer:
    """BN forward (training): two passes over X.

    Part 1 (statistics): read X, accumulate sum and sum-of-squares per
      channel (1 add + 1 mul + 1 add per element); per channel finalize
      mean/var/psi: mul, sub(mul for E[x]^2), add(eps), rsqrt  -> stored as
      mu, psi for the backward pass (paper Fig. 10).
    Part 2 (normalize): per channel fold a = gamma*psi, b = beta - a*mu
      (mul, mul, sub — the same per-channel hoisting the paper applies to
      the Eq. 28 prefactor), then per element y = a*x + b: mul, add.
    """
    p1 = SimdPart(
        tensors=(TensorRef("4d", "in"),
                 TensorRef("1d", "out"), TensorRef("1d", "out")),
        ops4d=("add", "mul", "add"),
        ops1d=("mul", "mul", "sub", "rsqrt"))
    p2 = SimdPart(
        tensors=(TensorRef("4d", "in"),
                 TensorRef("1d", "in"), TensorRef("1d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("mul", "add"),
        ops1d=("mul", "mul", "sub"))
    return SimdLayer(name, "bn", h, w, n, c, (p1, p2), phase)


def bn_back(name: str, h: int, w: int, n: int, c: int) -> SimdLayer:
    """BN backward -- Algorithm 1 / Appendix A, two parts.

    Part-1 (lines 1-12,24): in: X, dY (4D), mu, psi (1D);
      out: Xhat (4D), dgamma, dbeta (1D).
      ops/4D elem: sub, mul (Xhat) + mul, add (dgamma psum) + add (dbeta) = 5.
    Part-2 (lines 13-23): in: Xhat, dY (4D), gamma (1D; dgamma & dbeta are
      *reused from VMem* inside the same c-tile -- no DRAM traffic, exactly
      the Line-24 placement of Algorithm 1); out: dX (4D).
      ops/1D elem: mul + div (the term outside the parenthesis of Eq. 28);
      ops/4D elem: 3 mul + 2 sub (Eq. 28 inside, matching Eq. 38).
    """
    p1 = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("1d", "in"), TensorRef("1d", "in"),
                 TensorRef("4d", "out"),
                 TensorRef("1d", "out"), TensorRef("1d", "out")),
        ops4d=("sub", "mul", "mul", "add", "add"))
    p2 = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("1d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("mul", "mul", "mul", "sub", "sub"),
        ops1d=("mul", "div"))
    return SimdLayer(name, "bn_back", h, w, n, c, (p1, p2), "bwd")


def param_update(name: str, numel: int, ndim: int, k_align: int = 1) -> SimdLayer:
    """SGD parameter update p <- p - lr * g  (mul + sub per element).

    1D/2D/4D parameter tensors (paper Table I) all flatten onto the SIMD
    lanes; we lay the elements over the c dimension in K-aligned rows.
    """
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("mul", "sub"))
    c = max(1, min(numel, 4096))
    rows = (numel + c - 1) // c
    return SimdLayer(name, f"update_{ndim}d", rows, 1, 1, c, (part,), "bwd")


def bias_grad(name: str, oh: int, ow: int, n: int, oc: int) -> SimdLayer:
    """dL/db = sum over (n, oh, ow) of dY: 1 add per element, 1D output."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("1d", "out")),
        ops4d=("add",))
    return SimdLayer(name, "bias_grad", oh, ow, n, oc, (part,), "bwd")


# -- transformer / LLM non-GEMM ops (same generic tile template) -------------
#
# These route softmax/layernorm/rotary/activation through the SIMD model
# exactly like the paper's non-conv ops.  Iteration spaces put the
# normalized/rotated feature dimension on ``c`` (the SIMD lanes) and the
# token count on the h/n dims, so per-feature 1D tensors (gamma, beta)
# land in the per-c-tile placement the template already models.

def rmsnorm(name: str, tokens: int, d: int, phase: str = "fwd") -> SimdLayer:
    """y = gamma * x / rms(x): a stats pass (sum of squares per token,
    finalized with a reciprocal sqrt) and a scale pass (2 mul/element)."""
    p1 = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("1d", "out")),
        ops4d=("mul", "add"),
        ops1d=("mul", "rsqrt"))
    p2 = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("1d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("mul", "mul"))
    return SimdLayer(name, "rmsnorm", tokens, 1, 1, d, (p1, p2), phase)


def layer_norm(name: str, tokens: int, d: int, phase: str = "fwd") -> SimdLayer:
    """Full LayerNorm: BN-style two-pass schedule (mean/var stats, then
    y = a*x + b with a = gamma*psi, b = beta - a*mu folded per feature)."""
    p1 = SimdPart(
        tensors=(TensorRef("4d", "in"),
                 TensorRef("1d", "out"), TensorRef("1d", "out")),
        ops4d=("add", "mul", "add"),
        ops1d=("mul", "mul", "sub", "rsqrt"))
    p2 = SimdPart(
        tensors=(TensorRef("4d", "in"),
                 TensorRef("1d", "in"), TensorRef("1d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("mul", "add"),
        ops1d=("mul", "mul", "sub"))
    return SimdLayer(name, "layernorm", tokens, 1, 1, d, (p1, p2), phase)


def softmax(name: str, rows: int, cols: int, phase: str = "fwd") -> SimdLayer:
    """Row-wise softmax over ``cols`` entries (attention scores, router
    logits): online max, shifted exp with running sum, then the rescale —
    5 ops per element (max, sub, exp, add, mul)."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "out")),
        ops4d=("max", "sub", "exp", "add", "mul"))
    return SimdLayer(name, "softmax", rows, 1, 1, cols, (part,), phase)


def rotary(name: str, tokens: int, d: int, phase: str = "fwd") -> SimdLayer:
    """Rotary position embedding: y = x*cos +- rot(x)*sin — reads the
    activations plus the (sin, cos) tables, 2 mul + 1 add per element."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("4d", "in"), TensorRef("4d", "out")),
        ops4d=("mul", "mul", "add"))
    return SimdLayer(name, "rotary", tokens, 1, 1, d, (part,), phase)


def conv1d(name: str, tokens: int, d: int, width: int,
           phase: str = "fwd") -> SimdLayer:
    """Depthwise causal short convolution over the sequence (the
    mamba2 / RG-LRU ``conv_width``-tap conv): ``width`` MACs per output
    element, reading the activation window and the per-channel taps."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("mul", "add") * width)
    return SimdLayer(name, "conv1d", tokens, 1, 1, d, (part,), phase)


def elementwise_scan(name: str, tokens: int, d: int, kind: str = "ssm",
                     phase: str = "fwd") -> SimdLayer:
    """Elementwise recurrence update (SSD state blend / RG-LRU gate
    recurrence): per element, the gate nonlinearity plus the decay
    multiply-accumulate into the carried state."""
    part = SimdPart(
        tensors=(TensorRef("4d", "in"), TensorRef("4d", "in"),
                 TensorRef("4d", "out")),
        ops4d=("exp", "mul", "mul", "add", "mul", "add"))
    return SimdLayer(name, f"scan_{kind}", tokens, 1, 1, d, (part,), phase)


def activation(name: str, tokens: int, d: int, act: str = "silu",
               gated: bool = False, phase: str = "fwd") -> SimdLayer:
    """Pointwise activation (silu/gelu both cost a sigmoid-like kernel:
    exp, add, div, then the gating mul).  ``gated=True`` adds the second
    (up-projection) operand and its elementwise product — the fused
    act(gate) * up of gated MLPs."""
    tensors = [TensorRef("4d", "in")]
    ops: Tuple[str, ...] = ("exp", "add", "div", "mul")
    if gated:
        tensors.append(TensorRef("4d", "in"))
        ops = ops + ("mul",)
    tensors.append(TensorRef("4d", "out"))
    part = SimdPart(tensors=tuple(tensors), ops4d=ops)
    return SimdLayer(name, f"act_{act}", tokens, 1, 1, d, (part,), phase)
