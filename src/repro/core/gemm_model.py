"""Systolic-array GEMM performance model.

A GEMM out[m, n] = in[m, k] @ w[k, n] (+ bias[n]) maps onto the
weight-stationary array without im2col: k along the J rows, n along the
K columns, m streamed through.  Under that mapping a GEMM is the exact
specialization of the paper's Conv/FC model (Secs. IV-C, IV-D) at a
unit kernel window and unit spatial extents — ``fc(n=m, ic=k, oc=n)``
prices identically, which tests/test_gemm.py pins bit-exactly — so
every formula below is the conv formula with the vanished dims removed:

  * utilization comes from array-dim alignment: per-block compute is
    ``T_m * ceil(T_k/J) * ceil(T_n/K)`` cycles (+ PSO), so misaligned
    k/n dims idle rows/columns exactly like misaligned ic/oc,
  * DRAM access counts follow Eqs. 4/7/9-11 with the M/N/K multipliers,
  * SRAM access counts follow Table III,
  * DRAM stalls use the same Table IV tile-segment analysis (the
    occurrence-count partition specializes to the M/N/K loop nest).

``GemmLayer.count`` repeats the identical GEMM (per-head / per-expert
instances): the scalar helpers model ONE instance and ``simulate_gemm``
scales the totals; the batched table path folds the factor into the
occurrence counts and energy tensors directly (stalls are linear in the
occurrence counts, so both routes agree exactly).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np

from .conv_model import PerfStats
from .hardware import HardwareSpec
from .layers import GemmLayer
from .tiling import GemmTiling, ceil_div, make_gemm_tiling


@dataclass(frozen=True)
class GemmMultipliers:
    """Outer (m_*) and inner (r_*) loop multipliers of the M/N/K nest."""
    m_m: int; m_k: int; m_n: int
    r_k: int; r_n: int

    @property
    def m_outer(self) -> int:
        return self.m_m * self.m_k * self.m_n

    @property
    def m_w_tile(self) -> int:                 # weight-block reload count
        return self.m_k * self.m_n

    @property
    def m_accum(self) -> int:                  # psum accumulation depth
        return self.m_k


def gemm_multipliers(layer: GemmLayer, t: GemmTiling) -> GemmMultipliers:
    return GemmMultipliers(
        m_m=ceil_div(layer.m, t.T_m), m_k=ceil_div(layer.k, t.T_k),
        m_n=ceil_div(layer.n, t.T_n),
        r_k=ceil_div(t.T_k, t.t_k), r_n=ceil_div(t.T_n, t.t_n))


# ---------------------------------------------------------------------------
# DRAM / SRAM accesses (one GEMM instance)
# ---------------------------------------------------------------------------

def gemm_dram_bits(hw: HardwareSpec, layer: GemmLayer, t: GemmTiling,
                   m: GemmMultipliers) -> Dict[str, int]:
    a_dw = t.weight_tile_elems() * m.m_w_tile * hw.b_w
    a_di = t.input_tile_elems() * m.m_outer * hw.b_i
    m_p = m.m_m * m.m_n * (2 * m.m_accum - 1)
    a_dp = t.psum_tile_elems() * m_p * hw.b_p
    a_db = t.T_n * m.m_n * hw.b_b if layer.has_bias else 0
    return {"weight": a_dw, "ifmap": a_di, "psum": a_dp, "bias": a_db}


def gemm_sram_bits(hw: HardwareSpec, layer: GemmLayer, t: GemmTiling,
                   m: GemmMultipliers) -> Dict[str, int]:
    m_inner = t.T_m * m.r_k * m.r_n
    iters = m_inner * m.m_outer
    out_elems = layer.m * layer.n
    a_sw = t.t_k * t.t_n * iters * hw.b_w
    a_si = t.t_k * iters * hw.b_i
    a_sp = (t.t_n * 2 * iters - out_elems) * hw.b_p
    a_sb = out_elems * hw.b_b if layer.has_bias else 0
    return {"wbuf": a_sw, "ibuf": a_si, "obuf": a_sp, "bbuf": a_sb}


# ---------------------------------------------------------------------------
# Cycle counts
# ---------------------------------------------------------------------------

def gemm_tile_compute_cycles(hw: HardwareSpec, t: GemmTiling) -> int:
    """Per-block compute: the array-dim-alignment utilization model."""
    return t.T_m * ceil_div(t.T_k, hw.J) * ceil_div(t.T_n, hw.K)


def gemm_compute_cycles(hw: HardwareSpec, layer: GemmLayer, t: GemmTiling,
                        m: GemmMultipliers) -> int:
    return (gemm_tile_compute_cycles(hw, t) + hw.pso_sa) * m.m_outer


@dataclass(frozen=True)
class GemmSegmentQuantities:
    """Bandwidth-independent per-block stall-model quantities (one GEMM
    instance) — the GEMM twin of ``ConvSegmentQuantities``."""
    c_tile: int
    o1: int; o2: int; o4: int; o5: int
    w_bits: int
    wb_bits: int
    i_bits: int
    ps_bits: int
    pls_bits: int


def gemm_segment_quantities(hw: HardwareSpec, layer: GemmLayer,
                            t: GemmTiling, m: GemmMultipliers
                            ) -> GemmSegmentQuantities:
    o5 = m.m_n
    o4 = m.m_w_tile - m.m_n
    o1 = m.m_n * (m.m_m - 1)
    o2 = (m.m_outer - m.m_m * m.m_n) - o4
    assert o1 >= 0 and o2 >= 0 and o4 >= 0
    assert o1 + o2 + o4 + o5 == m.m_outer

    w_bits = t.weight_tile_elems() * hw.b_w
    b_bits = t.T_n * hw.b_b if layer.has_bias else 0
    p_bits = t.psum_tile_elems() * hw.b_p
    return GemmSegmentQuantities(
        c_tile=gemm_tile_compute_cycles(hw, t) + hw.pso_sa,
        o1=o1, o2=o2, o4=o4, o5=o5,
        w_bits=w_bits, wb_bits=w_bits + b_bits,
        i_bits=t.input_tile_elems() * hw.b_i,
        ps_bits=p_bits, pls_bits=2 * p_bits)


def gemm_quantities_batch(hw: HardwareSpec, layer: GemmLayer,
                          tilings: Sequence[GemmTiling]
                          ) -> Dict[str, np.ndarray]:
    """Vectorized cost-table quantities for ONE GEMM layer across many
    tilings, same keys as ``conv_quantities_batch``.  ``layer.count`` is
    folded into the occurrence counts, busy cycles, and DRAM/SRAM energy
    tensors (all linear), leaving the per-block volumes untouched.

    ``tilings`` is either a sequence of ``GemmTiling``s or the
    struct-of-arrays 5-tuple ``tiling._derive_gemm_tiling_arrays``
    returns (the zero-materialization fast path)."""
    if isinstance(tilings, tuple) and len(tilings) == 5 \
            and isinstance(tilings[0], np.ndarray):
        T_m, T_k, T_n, t_k, t_n = tilings
    else:
        f = np.array([[t.T_m, t.T_k, t.T_n, t.t_k, t.t_n] for t in tilings],
                     dtype=np.int64).T
        T_m, T_k, T_n, t_k, t_n = f

    def cd(a, b):
        return -(-a // b)

    cnt = layer.count
    m_m = cd(layer.m, T_m); m_k = cd(layer.k, T_k); m_n = cd(layer.n, T_n)
    r_k = cd(T_k, t_k); r_n = cd(T_n, t_n)
    m_w_tile = m_k * m_n
    m_outer = m_m * m_w_tile
    m_inner = T_m * r_k * r_n

    c_tile = T_m * cd(T_k, hw.J) * cd(T_n, hw.K) + hw.pso_sa
    o5 = m_n
    o4 = m_w_tile - m_n
    o1 = m_n * (m_m - 1)
    o2 = (m_outer - m_m * m_n) - o4
    assert (o1 >= 0).all() and (o2 >= 0).all() and (o4 >= 0).all()
    assert (o1 + o2 + o4 + o5 == m_outer).all()

    w_elems = T_k * T_n
    i_elems = T_m * T_k
    p_elems = T_m * T_n
    w_bits = w_elems * hw.b_w
    b_bits = T_n * hw.b_b if layer.has_bias else 0
    ps_bits = p_elems * hw.b_p

    m_p = m_m * m_n * (2 * m_k - 1)
    dram = (w_elems * m_w_tile * hw.b_w
            + i_elems * m_outer * hw.b_i
            + p_elems * m_p * hw.b_p
            + (T_n * m_n * hw.b_b if layer.has_bias else 0)) * cnt

    iters = m_inner * m_outer
    out_elems = layer.m * layer.n
    sram = {"wbuf": t_k * t_n * iters * hw.b_w * cnt,
            "ibuf": t_k * iters * hw.b_i * cnt,
            "obuf": (t_n * 2 * iters - out_elems) * hw.b_p * cnt,
            "bbuf": (np.full(len(T_n), out_elems * hw.b_b * cnt,
                             dtype=np.int64)
                     if layer.has_bias
                     else np.zeros(len(T_n), dtype=np.int64))}
    return {"c_tile": c_tile, "o1": o1 * cnt, "o2": o2 * cnt,
            "o4": o4 * cnt, "o5": o5 * cnt,
            "w_bits": w_bits, "wb_bits": w_bits + b_bits,
            "i_bits": i_elems * hw.b_i,
            "ps_bits": ps_bits, "pls_bits": 2 * ps_bits,
            "busy": c_tile * m_outer * cnt, "dram": dram, "sram": sram}


def gemm_stall_cycles(hw: HardwareSpec, layer: GemmLayer, t: GemmTiling,
                      m: GemmMultipliers) -> int:
    """Table IV tile-segment DRAM stall model, one GEMM instance."""
    q = gemm_segment_quantities(hw, layer, t, m)
    t_w = ceil_div(q.w_bits, hw.bw_w)
    t_wb = ceil_div(q.wb_bits, hw.bw_w)
    t_i = ceil_div(q.i_bits, hw.bw_i)
    t_ps = ceil_div(q.ps_bits, hw.bw_o)
    t_pls = ceil_div(q.pls_bits, hw.bw_o)

    seg1 = max(q.c_tile, t_i, t_ps)
    seg2 = max(q.c_tile, t_i, t_pls)
    seg4 = max(q.c_tile, t_w, t_i, t_pls)
    seg5 = max(q.c_tile, t_wb, t_i, t_ps)

    total_time = (q.o1 * seg1 + q.o2 * seg2
                  + q.o4 * seg4 + q.o5 * seg5)
    compute = q.c_tile * m.m_outer
    return max(0, total_time - compute)


# ---------------------------------------------------------------------------
# Top-level per-layer entry point
# ---------------------------------------------------------------------------

def simulate_gemm(hw: HardwareSpec, layer: GemmLayer,
                  t: GemmTiling | None = None,
                  stall_model: str = "simdit") -> PerfStats:
    """Full GEMM model (count-scaled totals).  ``stall_model`` mirrors
    ``simulate_conv``'s {simdit, no_stall, simplified}."""
    if t is None:
        t = make_gemm_tiling(hw, layer)
    m = gemm_multipliers(layer, t)
    dram = gemm_dram_bits(hw, layer, t, m)
    sram = gemm_sram_bits(hw, layer, t, m)
    compute = gemm_compute_cycles(hw, layer, t, m)

    if stall_model == "no_stall":
        stall = 0
    elif stall_model == "simplified":
        t_wb = ceil_div(dram["weight"] + dram["bias"], hw.bw_w)
        t_i = ceil_div(dram["ifmap"], hw.bw_i)
        t_p = ceil_div(dram["psum"], hw.bw_o)
        stall = max(0, max(compute, t_wb, t_i, t_p) - compute)
    else:
        stall = gemm_stall_cycles(hw, layer, t, m)

    cnt = layer.count
    ops = {"mac": layer.macs}                 # macs is already count-scaled
    if layer.has_bias:
        ops["add"] = layer.out_elems * cnt
    return PerfStats(engine="sa",
                     compute_cycles=compute * cnt, stall_cycles=stall * cnt,
                     dram_bits={k: v * cnt for k, v in dram.items()},
                     sram_bits={k: v * cnt for k, v in sram.items()},
                     ops=ops)
