"""SIMD-array (non-Conv) performance model — paper Secs. IV-E, V-C, App. A.

One generic engine evaluates every non-Conv layer expressed as
``SimdPart``s over an (h, w, n, c) iteration space:

  DRAM   : each 4D tensor tile is loaded/stored once per (h,w,n,c) outer
           iteration; each 1D tensor once per c iteration       (Eqs. 19-20, 34)
  SRAM   : 3 VMem accesses (2 reads + 1 write) per arithmetic op (Eqs. 35-36)
  compute: K ALUs in parallel, ceil(T_c/K) lane groups, latency
           sum(lambda_op); + PSO_SIMD per tile                  (Eqs. 21-22, 37-39)
  stalls : single-buffered VMem -> sequential load/store around each tile
           computation                                          (Eqs. 23, 40)
"""
from __future__ import annotations

import math
from typing import Dict

from .conv_model import PerfStats
from .hardware import HardwareSpec
from .layers import SimdLayer, SimdPart
from .tiling import SimdTiling, ceil_div, make_simd_tiling


def simd_part_tile_bits(hw: HardwareSpec, part: SimdPart,
                        t: SimdTiling) -> tuple[int, int]:
    """Per-tile DRAM traffic of one part: (bits per 4D (h,w,n,c) tile,
    bits per 1D per-c-tile load/store).  Bandwidth-independent — shared by
    the per-layer stall model and the DSE cost tables."""
    v4 = t.T_h * t.T_w * t.T_n * t.T_c
    bits_4d_per_tile = 0
    for ref in part.tensors:
        if ref.rank == "4d":
            vol = int(math.ceil(v4 * ref.scale))
            bits_4d_per_tile += vol * (hw.b_in if ref.io == "in" else hw.b_out)
    bits_1d_per_ctile = sum(
        t.T_c * (hw.b_in if ref.io == "in" else hw.b_out)
        for ref in part.tensors if ref.rank == "1d")
    return bits_4d_per_tile, bits_1d_per_ctile


def _part_stats(hw: HardwareSpec, layer: SimdLayer, part: SimdPart,
                t: SimdTiling) -> PerfStats:
    m_h = ceil_div(layer.h, t.T_h)
    m_w = ceil_div(layer.w, t.T_w)
    m_n = ceil_div(layer.n, t.T_n)
    m_c = ceil_div(layer.c, t.T_c)
    m_hwn = m_h * m_w * m_n

    v4 = t.T_h * t.T_w * t.T_n * t.T_c
    v1 = t.T_c

    # ---- DRAM ------------------------------------------------------------
    bits_4d_per_tile, bits_1d_per_ctile = simd_part_tile_bits(hw, part, t)
    dram_bits = (bits_4d_per_tile * m_hwn + bits_1d_per_ctile) * m_c

    # ---- op counts ---------------------------------------------------------
    ops: Dict[str, int] = {}
    n4 = v4 * m_hwn * m_c          # ceiling-padded element count
    n1 = v1 * m_c
    for op in part.ops4d:
        ops[op] = ops.get(op, 0) + n4
    for op in part.ops1d:
        ops[op] = ops.get(op, 0) + n1
    op_count = len(part.ops4d) * n4 + len(part.ops1d) * n1

    # ---- SRAM: 3 accesses (2r + 1w) per arithmetic op (Eq. 36) ------------
    sram_bits = op_count * 3 * hw.b_in

    # ---- compute cycles ----------------------------------------------------
    lam4 = sum(hw.lam(op) for op in part.ops4d)
    lam1 = sum(hw.lam(op) for op in part.ops1d)
    lanes = ceil_div(t.T_c, hw.K)
    c_tile4 = t.T_h * t.T_w * t.T_n * lanes * lam4           # Eq. 21 / Eq. 38
    c_tile1 = lanes * lam1                                   # Eq. 37
    compute = 0
    if lam4:
        compute += (c_tile4 + hw.pso_simd) * m_hwn * m_c     # Eq. 22 / Eq. 39
    if lam1:
        compute += c_tile1 * m_c

    # ---- stalls (single buffered; Eq. 23 / Eq. 40) -------------------------
    stall = (ceil_div(bits_4d_per_tile, hw.bw_v) * m_hwn
             + (ceil_div(bits_1d_per_ctile, hw.bw_v) if bits_1d_per_ctile else 0)
             ) * m_c

    return PerfStats(engine="simd", compute_cycles=compute, stall_cycles=stall,
                     dram_bits={"vmem": dram_bits},
                     sram_bits={"vmem": sram_bits}, ops=ops)


def simulate_simd(hw: HardwareSpec, layer: SimdLayer,
                  t: SimdTiling | None = None,
                  stall_model: str = "simdit") -> PerfStats:
    if t is None:
        t = make_simd_tiling(hw, layer)
    out = PerfStats(engine="simd")
    for part in layer.parts:
        out = out.merged(_part_stats(hw, layer, part, t))
    out.engine = "simd"
    if stall_model == "no_stall":
        out.stall_cycles = 0
    elif stall_model == "simplified":
        t_v = ceil_div(out.dram_total_bits, hw.bw_v)
        out.stall_cycles = max(0, max(out.compute_cycles, t_v) - out.compute_cycles)
    return out
