"""Crash-safe persistent table store: the L2 under the in-memory caches.

The process-lifetime ``ConvTable``/``SimdTable`` caches in ``core.dse``
die with the process, so every CLI run and CI job repays the full table
build cost.  This module promotes them to a *content-addressed on-disk
store* shared across workers and sessions — the durability half of the
ROADMAP's "DSE-as-a-service" item:

  * **Content addressing.**  An entry's filename is
    ``<kind>-<sha256(schema | kind | stable_key_repr(key))>.tbl`` where
    ``key`` is the exact in-memory cache key (hardware invariants +
    size triple + layer-shape/phase tuple) serialized canonically by
    ``tiling.stable_key_repr``.  Bumping ``SCHEMA_VERSION`` re-addresses
    everything, so stale-format files are simply never looked up.
  * **Atomic writes.**  Entries are written to a tempfile in the store
    directory, flushed + fsynced, then ``os.replace``d into place —
    readers never observe a half-written file, and concurrent writers of
    the same key are last-writer-wins with either result valid.
  * **Checksummed loads, quarantine on corruption.**  Every file embeds a
    magic, the schema version, and a SHA-256 digest of its payload.  Any
    validation failure — truncation, bit flips, unpicklable payload, key
    mismatch — moves the file into ``<root>/quarantine/`` and reports a
    miss: corruption costs a rebuild, never a crash.
  * **Advisory locking.**  Mutating passes (writes, eviction) take an
    ``fcntl`` lock on ``<root>/.lock`` with a bounded wait; on timeout
    they proceed anyway (atomic renames keep the store consistent) and
    count a ``store_lock_timeouts``.
  * **Size-capped LRU eviction.**  After each write the store evicts
    least-recently-used entries (mtime, refreshed on load) until under
    ``cap_bytes`` (``REPRO_TABLE_STORE_CAP_MB``, default 2048).

The store is **disabled by default**: it activates only when the
``REPRO_TABLE_STORE`` environment variable names a directory or a
``Study(store=...)`` / ``store_context(...)`` installs one, so every
existing bit-identity pin runs untouched.  Counters
(``store_hits``/``store_misses``/``store_corrupt``/``store_evicted``/
``store_lock_timeouts``) surface through ``dse.table_cache_stats()``.

Fault points (``core.faultinject``): ``store_corrupt`` /
``store_truncate`` damage the file just written, ``store_lock_hold``
holds the advisory lock inside the critical section.
"""
from __future__ import annotations

import contextlib
import hashlib
import io
import os
import pickle
import time
import warnings
from pathlib import Path
from typing import Dict, Iterator, Optional, Union

from . import faultinject
from .tiling import stable_key_repr

try:
    import fcntl
except ImportError:                      # non-POSIX: locking degrades to none
    fcntl = None  # type: ignore[assignment]

STORE_ENV = "REPRO_TABLE_STORE"
CAP_ENV = "REPRO_TABLE_STORE_CAP_MB"

SCHEMA_VERSION = 1
MAGIC = b"RPTB"
_HEADER_LEN = len(MAGIC) + 1 + 32        # magic + schema byte + sha256

DEFAULT_CAP_MB = 2048
DEFAULT_LOCK_TIMEOUT_S = 5.0

STORE_STATS: Dict[str, int] = {}


def _zero_stats() -> None:
    STORE_STATS.update(store_hits=0, store_misses=0, store_corrupt=0,
                       store_evicted=0, store_lock_timeouts=0,
                       store_writes=0)


_zero_stats()


def store_stats() -> Dict[str, int]:
    """Process-lifetime counters of every active store (a copy)."""
    return dict(STORE_STATS)


def reset_store_stats() -> None:
    _zero_stats()


def env_int(name: str, default: int) -> int:
    """``int(os.environ[name])`` with a loud fallback: a garbage value
    warns (``RuntimeWarning`` naming variable and value) and returns the
    default instead of being silently swallowed."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        return int(raw)
    except ValueError:
        warnings.warn(
            f"ignoring invalid {name}={raw!r} (expected an integer); "
            f"using default {default}", RuntimeWarning, stacklevel=2)
        return default


def env_float(name: str, default: float) -> float:
    """Float twin of ``env_int`` — same loud-fallback contract."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        return float(raw)
    except ValueError:
        warnings.warn(
            f"ignoring invalid {name}={raw!r} (expected a number); "
            f"using default {default}", RuntimeWarning, stacklevel=2)
        return default


class TableStore:
    """One on-disk table store rooted at a directory.

    ``load``/``save`` never raise on a damaged store: corruption
    quarantines, I/O errors warn and degrade to miss/no-op.  The store
    only trusts files it can fully validate, so any mix of concurrent
    writers and crashed processes leaves it serving correct entries."""

    def __init__(self, root: Union[str, Path],
                 cap_bytes: Optional[int] = None,
                 lock_timeout_s: float = DEFAULT_LOCK_TIMEOUT_S):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.quarantine_dir = self.root / "quarantine"
        if cap_bytes is None:
            cap_bytes = env_int(CAP_ENV, DEFAULT_CAP_MB) * 1024 * 1024
        self.cap_bytes = cap_bytes
        self.lock_timeout_s = lock_timeout_s
        self._seq = 0

    # ---- addressing --------------------------------------------------------

    def entry_path(self, kind: str, key: tuple) -> Path:
        """Content address of ``(kind, key)`` under the current schema."""
        digest = hashlib.sha256(
            f"v{SCHEMA_VERSION}|{kind}|{stable_key_repr(key)}"
            .encode()).hexdigest()
        return self.root / f"{kind}-{digest}.tbl"

    def contains(self, kind: str, key: tuple) -> bool:
        """Existence probe (no validation, no counters) — used to keep
        parallel builders from rebuilding entries the store already
        holds."""
        return self.entry_path(kind, key).is_file()

    # ---- load / save -------------------------------------------------------

    def load(self, kind: str, key: tuple, expect_type: type = object):
        """Validated fetch: the stored object, or ``None`` on miss.  Any
        corruption — bad magic/schema/digest, unpicklable payload, key or
        type mismatch — quarantines the file and returns ``None``."""
        path = self.entry_path(kind, key)
        try:
            blob = path.read_bytes()
        except FileNotFoundError:
            STORE_STATS["store_misses"] += 1
            return None
        except OSError as exc:
            warnings.warn(f"table store read failed for {path.name}: {exc}",
                          RuntimeWarning, stacklevel=2)
            STORE_STATS["store_misses"] += 1
            return None
        obj = self._validate(path, blob, kind, key, expect_type)
        if obj is None:
            self._quarantine(path)
            STORE_STATS["store_corrupt"] += 1
            return None
        STORE_STATS["store_hits"] += 1
        with contextlib.suppress(OSError):
            os.utime(path)               # refresh LRU recency
        return obj

    def _validate(self, path: Path, blob: bytes, kind: str, key: tuple,
                  expect_type: type):
        if len(blob) <= _HEADER_LEN or blob[:4] != MAGIC \
                or blob[4] != SCHEMA_VERSION:
            return None
        payload = blob[_HEADER_LEN:]
        if hashlib.sha256(payload).digest() != blob[5:_HEADER_LEN]:
            return None
        try:
            stored_kind, stored_key, obj = pickle.loads(payload)
        except Exception:
            return None
        if stored_kind != kind or stored_key != stable_key_repr(key) \
                or not isinstance(obj, expect_type):
            return None
        return obj

    def save(self, kind: str, key: tuple, obj) -> None:
        """Atomic, checksummed write of one entry, then an eviction pass.
        Best-effort: on I/O failure the store warns and the caller keeps
        its in-memory table."""
        payload = pickle.dumps((kind, stable_key_repr(key), obj),
                               protocol=pickle.HIGHEST_PROTOCOL)
        blob = (MAGIC + bytes([SCHEMA_VERSION])
                + hashlib.sha256(payload).digest() + payload)
        path = self.entry_path(kind, key)
        self._seq += 1
        tmp = self.root / f".tmp-{os.getpid()}-{self._seq}-{path.name}"
        try:
            with self._locked():
                with open(tmp, "wb") as fh:
                    fh.write(blob)
                    fh.flush()
                    os.fsync(fh.fileno())
                os.replace(tmp, path)
                self._inject_damage(path)
                STORE_STATS["store_writes"] += 1
                self._evict_to_cap()
        except OSError as exc:
            warnings.warn(f"table store write failed for {path.name}: {exc}",
                          RuntimeWarning, stacklevel=2)
            with contextlib.suppress(OSError):
                tmp.unlink()

    def _inject_damage(self, path: Path) -> None:
        """Deterministic corruption hooks (tests/CI fault suite only)."""
        if faultinject.fire("store_corrupt"):
            with open(path, "r+b") as fh:
                fh.seek(_HEADER_LEN + 1)
                b = fh.read(1)
                fh.seek(_HEADER_LEN + 1)
                fh.write(bytes([b[0] ^ 0xFF]) if b else b"\xff")
        if faultinject.fire("store_truncate"):
            size = path.stat().st_size
            with open(path, "r+b") as fh:
                fh.truncate(size // 2)

    # ---- corruption / eviction ---------------------------------------------

    def _quarantine(self, path: Path) -> None:
        try:
            self.quarantine_dir.mkdir(exist_ok=True)
            self._seq += 1
            dest = self.quarantine_dir \
                / f"{path.name}.{os.getpid()}-{self._seq}"
            os.replace(path, dest)
        except OSError:
            # Last resort: make sure the bad file at least stops being
            # served (another process may have quarantined it already).
            with contextlib.suppress(OSError):
                path.unlink()

    def entries(self) -> Iterator[Path]:
        """The validated-format entry files currently in the store."""
        for p in self.root.glob("*.tbl"):
            if p.is_file():
                yield p

    def total_bytes(self) -> int:
        total = 0
        for p in self.entries():
            with contextlib.suppress(OSError):
                total += p.stat().st_size
        return total

    def _evict_to_cap(self) -> None:
        """Drop least-recently-used entries until under ``cap_bytes``.
        LRU recency is file mtime, refreshed by ``load``; a concurrent
        deletion of the same victim is benign."""
        if self.cap_bytes is None or self.cap_bytes <= 0:
            return
        files = []
        for p in self.entries():
            with contextlib.suppress(OSError):
                st = p.stat()
                files.append((st.st_mtime, st.st_size, p))
        total = sum(size for _, size, _ in files)
        if total <= self.cap_bytes:
            return
        for _, size, p in sorted(files, key=lambda f: f[0]):
            if total <= self.cap_bytes:
                break
            with contextlib.suppress(OSError):
                p.unlink()
                total -= size
                STORE_STATS["store_evicted"] += 1

    # ---- advisory locking --------------------------------------------------

    @contextlib.contextmanager
    def _locked(self):
        """Advisory exclusive lock on the store, bounded wait.  On
        timeout — or on platforms without ``fcntl`` — the critical
        section proceeds unlocked: writes stay safe through atomic
        renames, so contention degrades to extra work, never to
        corruption or deadlock."""
        if fcntl is None:
            yield
            return
        fh: Optional[io.IOBase] = None
        locked = False
        try:
            try:
                fh = open(self.root / ".lock", "a+b")
            except OSError:
                yield
                return
            deadline = time.monotonic() + self.lock_timeout_s
            while True:
                try:
                    fcntl.flock(fh.fileno(), fcntl.LOCK_EX | fcntl.LOCK_NB)
                    locked = True
                    break
                except OSError:
                    if time.monotonic() >= deadline:
                        STORE_STATS["store_lock_timeouts"] += 1
                        break
                    time.sleep(0.01)
            hold = faultinject.fire("store_lock_hold")
            if hold is not None:
                time.sleep(hold.arg if hold.arg is not None else 1.0)
            yield
        finally:
            if fh is not None:
                if locked:
                    with contextlib.suppress(OSError):
                        fcntl.flock(fh.fileno(), fcntl.LOCK_UN)
                fh.close()

    def __repr__(self) -> str:
        return (f"TableStore({str(self.root)!r}, "
                f"cap_bytes={self.cap_bytes})")


# ---------------------------------------------------------------------------
# Active-store resolution
#
# Precedence: an explicit override (``set_default_store`` / the
# ``store_context`` manager, used by ``Study(store=...)``) wins; otherwise
# the ``REPRO_TABLE_STORE`` environment variable names the store root;
# otherwise the store is off and every table path behaves exactly as
# before this module existed.
# ---------------------------------------------------------------------------

_UNSET = object()
_OVERRIDE = _UNSET                       # TableStore | None | _UNSET
_ENV_STORES: Dict[str, TableStore] = {}


def _coerce_store(spec: Union["TableStore", str, Path, None]
                  ) -> Optional[TableStore]:
    if spec is None or isinstance(spec, TableStore):
        return spec
    return TableStore(spec)


def set_default_store(spec: Union[TableStore, str, Path, None]) -> None:
    """Install a process-wide store override (``None`` disables the store
    even when ``REPRO_TABLE_STORE`` is set).  Prefer ``store_context``
    for scoped use."""
    global _OVERRIDE
    _OVERRIDE = _coerce_store(spec)


def clear_default_store() -> None:
    """Remove the override: resolution falls back to the environment."""
    global _OVERRIDE
    _OVERRIDE = _UNSET


@contextlib.contextmanager
def store_context(spec: Union[TableStore, str, Path, None]):
    """Scoped store override: inside the block every table fetch goes
    through ``spec`` (or none, for ``spec=None``); on exit the previous
    resolution is restored."""
    global _OVERRIDE
    prev = _OVERRIDE
    _OVERRIDE = _coerce_store(spec)
    try:
        yield _OVERRIDE
    finally:
        _OVERRIDE = prev


def active_store() -> Optional[TableStore]:
    """The store table fetches should use right now, or ``None``."""
    if _OVERRIDE is not _UNSET:
        return _OVERRIDE                 # type: ignore[return-value]
    path = os.environ.get(STORE_ENV)
    if not path or not path.strip():
        return None
    path = path.strip()
    store = _ENV_STORES.get(path, _UNSET)
    if store is _UNSET:
        try:
            store = TableStore(path)
        except OSError as exc:
            warnings.warn(
                f"ignoring invalid {STORE_ENV}={path!r} (cannot use as a "
                f"store directory: {exc}); persistent table store disabled",
                RuntimeWarning, stacklevel=2)
            store = None
        _ENV_STORES[path] = store        # cache the failure too: warn once
    return store                         # type: ignore[return-value]
