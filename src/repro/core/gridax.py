"""On-device DSE grid evaluation — the JAX backend of the grid front-end.

The exhaustive search is two separable cost matrices plus a handful of
reductions: an outer add routed through the ``s3_of``/``b3_of``/``v_of``/
``w_of`` projections, argmin/argmax for best/worst, the within-frac
frontier mask, objective scoring over the grid, and the 2-D Pareto mask.
This module runs all of them on the default JAX device —
``jax.jit``/``jax.vmap`` for the general path, and a fused Pallas
outer-add+argmin/argmax kernel (``repro.kernels.reduce``) for the hot
cycles-only reduction — selected per search via ``Study(backend="jax")``
/ ``Study(backend="jax-fused")`` or ``$REPRO_DSE_BACKEND``.

Bit-identity contract (pinned by ``tests/test_gridax*.py`` against the
numpy engine and the scalar ``search_reference``):

  * **int64 cycles.**  Every entry point runs under
    ``jax.experimental.enable_x64()``: outside it jnp silently defaults
    to int32 and large cycle grids (anything past 2**31) would truncate.
    x64 participates in the jit cache key, so these jits never collide
    with the repo's f32 kernel wrappers.
  * **First-occurrence ties.**  ``jnp.argmin``/``argmax`` return the
    first occurrence, matching the legacy strict-inequality
    (size-outer, bandwidth-inner) walk; the fused Pallas kernel
    preserves the same contract via its sequential strict-update
    running reduction.
  * **Float scoring.**  Energy/EDP/power grids are elementwise
    float64 broadcasts of host-presummed per-axis vectors (see
    ``_EnergyFields``), so XLA performs the same IEEE operations in the
    same order as numpy — equality is exact, not approximate.  Custom
    objectives that compute in numpy still work: jax arrays coerce via
    ``__array__`` and the scores round-trip losslessly.

Results return as numpy arrays: the retained ``DSEGrid``/``DSEResult``
machinery downstream is shared with the numpy backend, which is what
keeps every accessor (``points``, ``economic_min_*``, ``pareto`` …)
identical by construction.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..kernels.reduce import grid_minmax_pallas


def _x64(fn):
    """Run ``fn`` (tracing and execution) under the x64 context so int64
    grids stay int64 — the context is thread-local and part of the jit
    cache key."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        with enable_x64():
            return fn(*args, **kwargs)
    return wrapper


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# jit'd reductions
# ---------------------------------------------------------------------------

def _outer_add_impl(conv, simd, s3_of, b3_of, v_of, w_of):
    return conv[s3_of][:, b3_of] + simd[v_of][:, w_of]


def _reduce_cycles_impl(conv, simd, s3_of, b3_of, v_of, w_of, mult):
    costs = _outer_add_impl(conv, simd, s3_of, b3_of, v_of, w_of)
    flat = costs.ravel()
    bi = jnp.argmin(flat)
    wi = jnp.argmax(flat)
    frontier = flat <= flat[bi] * mult
    return costs, bi, wi, frontier


def _frontier_impl(conv, simd, s3_of, b3_of, v_of, w_of, bi, mult):
    costs = _outer_add_impl(conv, simd, s3_of, b3_of, v_of, w_of)
    flat = costs.ravel()
    return costs, flat <= flat[bi] * mult


def _score_reduce_impl(scores, mult):
    flat = scores.ravel()
    finite = jnp.isfinite(flat)
    # mask both sides: a NaN (or +-inf) score marks an infeasible
    # candidate and must poison neither argmin nor argmax
    bi = jnp.where(finite, flat, jnp.inf).argmin()
    wi = jnp.where(finite, flat, -jnp.inf).argmax()
    frontier = flat <= flat[bi] * mult
    return bi, wi, finite.any(), frontier


def _within_impl(values, limit):
    return values.ravel() <= limit


def _pareto_impl(cycles, energy):
    n = cycles.shape[0]
    order = jnp.lexsort((jnp.arange(n), energy, cycles))
    e_sorted = energy[order]
    run_min = jax.lax.cummin(e_sorted)
    prev_min = jnp.concatenate(
        [jnp.full((1,), jnp.inf, e_sorted.dtype), run_min[:-1]])
    keep_sorted = e_sorted < prev_min
    return jnp.zeros(n, dtype=bool).at[order].set(keep_sorted)


def _gather_panels_impl(conv, simd, b3_of, w_of):
    return conv[:, b3_of], simd[:, w_of]


_outer_add_jit = _x64(jax.jit(_outer_add_impl))
_reduce_cycles_one = _x64(jax.jit(_reduce_cycles_impl))
# vmap over stacked per-network matrices: the projections are shared by
# every network of one search, so a multi-net cycles sweep is a single
# batched dispatch
_reduce_cycles_vmap = _x64(jax.jit(jax.vmap(
    _reduce_cycles_impl, in_axes=(0, 0, None, None, None, None, None))))
_frontier_jit = _x64(jax.jit(_frontier_impl))
_score_reduce_jit = _x64(jax.jit(_score_reduce_impl))
_within_jit = _x64(jax.jit(_within_impl))
_pareto_jit = _x64(jax.jit(_pareto_impl))
_gather_panels = _x64(jax.jit(_gather_panels_impl))


# ---------------------------------------------------------------------------
# Public entry points (numpy in, numpy out)
# ---------------------------------------------------------------------------

@_x64
def outer_add(conv: np.ndarray, simd: np.ndarray,
              s3_of: np.ndarray, b3_of: np.ndarray,
              v_of: np.ndarray, w_of: np.ndarray) -> np.ndarray:
    """The device outer-add composition — int64-exact equivalent of
    ``conv[np.ix_(s3_of, b3_of)] + simd[np.ix_(v_of, w_of)]``."""
    return np.asarray(_outer_add_jit(conv, simd, s3_of, b3_of, v_of, w_of))


@_x64
def fused_minmax(conv: np.ndarray, simd: np.ndarray,
                 s3_of: np.ndarray, b3_of: np.ndarray,
                 v_of: np.ndarray, w_of: np.ndarray,
                 interpret: Optional[bool] = None) -> Tuple[int, int]:
    """(argmin, argmax) flat indices of the virtual cost grid via the
    fused Pallas kernel — the grid itself is never materialized: columns
    are pre-gathered into two small operand panels, rows are gathered
    per grid step by scalar prefetch."""
    if interpret is None:
        interpret = _default_interpret()
    cb, sb = _gather_panels(jnp.asarray(conv), jnp.asarray(simd),
                            jnp.asarray(b3_of), jnp.asarray(w_of))
    out = np.asarray(grid_minmax_pallas(
        cb, sb, jnp.asarray(s3_of, dtype=jnp.int32),
        jnp.asarray(v_of, dtype=jnp.int32), interpret=interpret))
    return int(out[1]), int(out[3])


@_x64
def reduce_cycles_many(convs: Sequence[np.ndarray],
                       simds: Sequence[np.ndarray],
                       s3_of: np.ndarray, b3_of: np.ndarray,
                       v_of: np.ndarray, w_of: np.ndarray, *,
                       frontier_mult: float, fused: bool = False,
                       interpret: Optional[bool] = None
                       ) -> List[Tuple[np.ndarray, int, int, np.ndarray]]:
    """The cycles-objective reduction for N networks sharing one
    candidate space: per network ``(costs, best_idx, worst_idx,
    frontier_mask)`` with ``frontier_mask = costs <= best*frontier_mult``
    (flat).  Multiple networks run as one vmapped dispatch; ``fused``
    routes best/worst through the Pallas kernel instead of XLA argmin."""
    if fused:
        out = []
        for conv, simd in zip(convs, simds):
            bi, wi = fused_minmax(conv, simd, s3_of, b3_of, v_of, w_of,
                                  interpret=interpret)
            costs, fm = _frontier_jit(conv, simd, s3_of, b3_of, v_of,
                                      w_of, bi, frontier_mult)
            out.append((np.asarray(costs), bi, wi, np.asarray(fm)))
        return out
    if len(convs) == 1:
        costs, bi, wi, fm = _reduce_cycles_one(
            convs[0], simds[0], s3_of, b3_of, v_of, w_of, frontier_mult)
        return [(np.asarray(costs), int(bi), int(wi), np.asarray(fm))]
    costs, bi, wi, fm = _reduce_cycles_vmap(
        jnp.stack([jnp.asarray(c) for c in convs]),
        jnp.stack([jnp.asarray(s) for s in simds]),
        s3_of, b3_of, v_of, w_of, frontier_mult)
    costs, bi, wi, fm = (np.asarray(costs), np.asarray(bi),
                         np.asarray(wi), np.asarray(fm))
    return [(costs[n], int(bi[n]), int(wi[n]), fm[n])
            for n in range(len(convs))]


@_x64
def reduce_scored(conv: np.ndarray, simd: np.ndarray,
                  s3_of: np.ndarray, b3_of: np.ndarray,
                  v_of: np.ndarray, w_of: np.ndarray, *,
                  objective, energy_grids_fn: Callable, frontier_mult: float
                  ) -> Tuple[np.ndarray, np.ndarray,
                             Optional[Dict[str, np.ndarray]],
                             int, int, bool, np.ndarray]:
    """The general-objective reduction for one network: build the device
    cost grid, score it through ``objective`` (energy grids, if the
    objective pulls them, come from ``energy_grids_fn(costs)`` — the
    xp-aware ``compute_energy_batch`` keeps them on device), then the
    non-finite-masked best/worst and the frontier mask.

    Returns ``(costs, scores, energy_report_or_None, best_idx,
    worst_idx, any_feasible, frontier_mask)`` — all numpy."""
    from .objectives import MetricBatch
    costs_dev = _outer_add_jit(conv, simd, s3_of, b3_of, v_of, w_of)
    mb = MetricBatch(costs_dev, lambda c=costs_dev: energy_grids_fn(c))
    scores_dev = jnp.asarray(objective.score(mb), dtype=float)
    bi, wi, feasible, fm = _score_reduce_jit(scores_dev, frontier_mult)
    report = None if mb._report is None else \
        {k: np.asarray(v) for k, v in mb._report.items()}
    return (np.asarray(costs_dev), np.asarray(scores_dev), report,
            int(bi), int(wi), bool(feasible), np.asarray(fm))


def within_mask(values: np.ndarray, limit: float) -> np.ndarray:
    """Flat boolean mask ``values <= limit`` computed on device —
    identical promotion semantics to the numpy comparison (int64 and the
    float limit both promote to float64)."""
    return np.asarray(_within_jit(np.asarray(values), float(limit)))


def pareto_mask(cycles: np.ndarray, energy: np.ndarray) -> np.ndarray:
    """Device analogue of ``dse._pareto_mask`` — bit-identical, but
    vectorized (the numpy version is a sequential Python walk).

    Equivalence argument: after lexsorting by (cycles, energy, index),
    the scalar walk keeps an element iff its energy is strictly below
    the running minimum over *kept* predecessors — which equals the
    running minimum over all predecessors, since any element that
    lowered the minimum was itself kept.  The exclusive prefix-min
    therefore reproduces the sequential rule exactly, and the trailing
    index key makes the lexsort order unique (stability-independent)."""
    return np.asarray(_pareto_jit(np.asarray(cycles),
                                  np.asarray(energy, dtype=float)))
