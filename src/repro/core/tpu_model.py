"""SimDIT methodology instantiated for the TPU v5e target (beyond-paper).

Two pieces:

1. ``RooflineTerms`` — the three-term roofline the dry-run analysis reports
   per (arch x mesh):
       compute    = HLO_FLOPs        / (chips * 197e12 FLOP/s)
       memory     = HLO_bytes        / (chips * 819e9  B/s)
       collective = collective_bytes / (chips * 50e9   B/s/link)
   This extends the paper's stall model (max over parallel DRAM interfaces,
   Eq. 18) with the interface class the paper's single-chip ASIC lacks: the
   inter-chip interconnect.

2. ``select_matmul_block`` — the paper's tile-based DRAM-access/stall model
   (Secs. IV-B..D) ported from conv loops to the GEMM loop nest, used to
   pick Pallas BlockSpec shapes: outer tiles sized to VMEM (the paper's
   SRAM), inner tiles fixed by the MXU (the paper's J x K = 128 x 128), HBM
   traffic per Eqs. 4/7/10 with the weight-stationary reuse argument, and
   the per-tile segment time as max(compute, load, store) per Eq. 18.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

# ---- TPU v5e-class hardware constants (per chip) ---------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link
MXU = 128                         # systolic dimension (the paper's J = K)
VMEM_BYTES = 128 * 1024 * 1024    # on-chip vector memory


@dataclass(frozen=True)
class RooflineTerms:
    """Three-term roofline for one compiled step on one mesh."""
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW_PER_LINK)

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Paper-style segment time: max over parallel engines (Eq. 18
        generalized to compute/HBM/ICI)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-resource roofline actually achieved by
        the *useful* compute: t_compute / step_time."""
        st = self.step_time
        return self.t_compute / st if st > 0 else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bound": self.bound,
            "step_time_s": self.step_time,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(n_active_params: int, tokens: int, training: bool) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for a forward/serve step."""
    return (6.0 if training else 2.0) * n_active_params * tokens


# ---------------------------------------------------------------------------
# GEMM block-shape selection via the paper's tile model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MatmulBlock:
    bm: int
    bn: int
    bk: int
    est_cycles: float          # model-estimated segment cycles (Eq. 18 analog)
    hbm_bytes: float           # model-estimated HBM traffic


def _blocks(dim: int, lo: int = 128, hi: int = 2048) -> List[int]:
    out = []
    b = lo
    while b <= min(dim, hi):
        out.append(b)
        b *= 2
    return out or [min(dim, lo)]


def matmul_cost(m: int, n: int, k: int, bm: int, bn: int, bk: int,
                bytes_in: int = 2, bytes_out: int = 2,
                vmem: int = VMEM_BYTES) -> Optional[Tuple[float, float]]:
    """(segment_cycles, hbm_bytes) for C[m,n] = A[m,k] @ B[k,n] tiled
    (bm, bn, bk), or None if the working set exceeds VMEM.

    Maps the paper's conv model onto the GEMM nest:
      outer multipliers  m_m = ceil(m/bm), m_n, m_k            (Eq. 1)
      B ("weight") traffic: each B tile loaded m_m times       (Eq. 6 analog,
        weight-stationary order makes it 1 when bm covers m)   (Eq. 4)
      A ("ifmap") traffic: loaded for every (m,n,k) tile       (Eq. 7)
      C ("psum")  traffic: 2*m_k - 1 accesses per tile         (Eq. 9)
      per-tile time = max(MXU compute, HBM streams)            (Eq. 18)
    """
    work = (bm * bk + bk * bn) * bytes_in + bm * bn * 4   # f32 accumulator
    if 2 * work > vmem:                                   # double-buffered
        return None
    m_m = -(-m // bm); m_n = -(-n // bn); m_k = -(-k // bk)
    # HBM bytes (whole GEMM)
    a_bytes = bm * bk * bytes_in * m_m * m_k * m_n
    b_bytes = bk * bn * bytes_in * m_k * m_n              # B reused across m
    c_bytes = bm * bn * bytes_out * m_m * m_n * max(1, 2 * m_k - 1)
    hbm = a_bytes + b_bytes + c_bytes
    # per-tile segment cycles at MXU rate (one 128x128x128 MAC block / cycle)
    compute = (bm / MXU) * (bn / MXU) * bk
    hbm_cycles_per_byte = PEAK_FLOPS_BF16 / (2 * MXU * MXU) / HBM_BW
    load = (bm * bk + bk * bn) * bytes_in * hbm_cycles_per_byte
    store = bm * bn * bytes_out * hbm_cycles_per_byte
    seg = max(compute, load, store)
    total = seg * m_m * m_n * m_k
    return total, float(hbm)


def select_matmul_block(m: int, n: int, k: int, bytes_in: int = 2,
                        bytes_out: int = 2,
                        vmem: int = VMEM_BYTES) -> MatmulBlock:
    """DSE over block shapes (the paper's Sec. VII-B applied to one GEMM)."""
    best: Optional[MatmulBlock] = None
    for bm in _blocks(m):
        for bn in _blocks(n):
            for bk in _blocks(k):
                res = matmul_cost(m, n, k, bm, bn, bk, bytes_in, bytes_out,
                                  vmem)
                if res is None:
                    continue
                cyc, hbm = res
                if best is None or cyc < best.est_cycles or (
                        cyc == best.est_cycles and hbm < best.hbm_bytes):
                    best = MatmulBlock(bm, bn, bk, cyc, hbm)
    if best is None:   # tiny problem: single block
        return MatmulBlock(min(m, MXU), min(n, MXU), min(k, MXU), 0.0, 0.0)
    return best
