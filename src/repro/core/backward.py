"""Training-phase expansion — paper Sec. V.

``dx_conv`` / ``dw_conv`` implement the Table V tensor-transformation
formulas that turn the two Conv backward ops into *plain forward
convolutions* (dilate by S-1, pad by K-1, flip kernels, swap channel axes),
so they reuse the Sections IV-C/IV-D systolic models unchanged — including
kernel-dimension tiling, which is mandatory here because the dW-conv
"kernel" is S(OH-1)+1 wide (223x223 for early ResNet-50 layers).

``expand_training_graph`` turns an inference layer list into the full
forward + backward + parameter-update operation list of Table I.
"""
from __future__ import annotations

from typing import List, Union

from dataclasses import replace

from . import layers as L
from .layers import ConvLayer, GemmLayer, SimdLayer

Layer = Union[ConvLayer, GemmLayer, SimdLayer]

__all__ = ["dx_conv", "dw_conv", "dx_gemm", "dw_gemm",
           "expand_training_graph"]


def dx_conv(f: ConvLayer) -> ConvLayer:
    """Conv computing dL/dX^l (Table V, top half).

    ifmap  = dL/dX^{l+1} dilated by (S-1), padded by (K-1)   [N, IH^B, IW^B, OC^F]
    filter = W^l flipped, channel axes swapped               [Kh, Kw, OC^F, IC^F]
    ofmap  = dL/dX^l                                          [N, IH^F, IW^F, IC^F]
    """
    ih_b = f.s * (f.oh - 1) + 1 + 2 * (f.kh - 1)
    iw_b = f.s * (f.ow - 1) + 1 + 2 * (f.kw - 1)
    return ConvLayer(
        name=f"{f.name}.dX", n=f.n,
        ic=f.oc, ih=ih_b, iw=iw_b,
        oc=f.ic, oh=f.ih, ow=f.iw,
        kh=f.kh, kw=f.kw, s=1, has_bias=False,
        phase="bwd_dx", kind=f.kind)


def dw_conv(f: ConvLayer) -> ConvLayer:
    """Conv computing dL/dW^l (Table V, bottom half).

    ifmap  = X^l with (ic <-> n) swapped                      [IC^F, IH, IW, N^F]
    filter = dilated dL/dX^{l+1}                              [Kh^B, Kw^B, N^F, OC^F]
    ofmap  = dL/dW^l                                          [IC^F, Kh^F, Kw^F, OC^F]
    """
    kh_b = f.s * (f.oh - 1) + 1
    kw_b = f.s * (f.ow - 1) + 1
    return ConvLayer(
        name=f"{f.name}.dW", n=f.ic,
        ic=f.n, ih=f.ih, iw=f.iw,
        oc=f.oc, oh=f.kh, ow=f.kw,
        kh=kh_b, kw=kw_b, s=1, has_bias=False,
        phase="bwd_dw", kind=f.kind)


def dx_gemm(f: GemmLayer) -> GemmLayer:
    """GEMM computing dL/dX = dY . W^T: an [m x k] output reducing over
    n — the same M/N/K model with n and k swapped, so a dX GEMM whose
    swapped shape matches some forward GEMM shares its table column."""
    return replace(f, name=f"{f.name}.dX", n=f.k, k=f.n,
                   has_bias=False, phase="bwd_dx")


def dw_gemm(f: GemmLayer) -> GemmLayer:
    """GEMM computing dL/dW = X^T . dY: a [k x n] output reducing over
    the streamed dim m."""
    return replace(f, name=f"{f.name}.dW", m=f.k, k=f.m,
                   has_bias=False, phase="bwd_dw")


# Non-conv forward ops whose backward is modeled as a mirror-cost SIMD op
# (same iteration space and tensor traffic as the forward — first-order
# exact for elementwise/rotary ops and the standard softmax/norm backward
# recomputation schedules).  Parameterized norms additionally update
# their 1-D scale (and shift) vectors.
_MIRROR_OPS = ("softmax", "rotary", "rmsnorm", "layernorm", "conv1d")
_MIRROR_PREFIXES = ("act_", "gate_", "scan_")


def expand_training_graph(net: List[Layer]) -> List[Layer]:
    """Forward pass + backward pass + parameter updates (Table I).

    The backward pass walks the network in reverse.  Per layer:
      Conv/FC : dX conv (skipped for the input layer), dW conv, bias grad
                reduction (if biased), 4D weight update, 1D bias update.
      GEMM    : dX GEMM (dY.W^T) + dW GEMM (X^T.dY); weight/bias updates
                only for parameter GEMMs (``param=True``).
      Norms   : mirror-cost backward + 1D scale/shift updates; softmax/
                rotary/activations mirror without parameters.
      BN      : BN_back (Algorithm 1) + 1D scale/shift updates.
      ReLU    : relu_back.
      Pool    : pool_back (max routes through saved argmax; avg broadcasts).
      Add     : gradient junction = Tensor-add of the two incoming grads.
      GAP     : gap_back broadcast.
    """
    out: List[Layer] = list(net)
    # Positional, not identity-based: frozen layer dataclasses may be reused
    # (shape-identical blocks), so "the input layer" is the first conv *slot*.
    first_conv_pos = next((i for i, l in enumerate(net)
                           if isinstance(l, ConvLayer)), None)

    for pos in range(len(net) - 1, -1, -1):
        layer = net[pos]
        if isinstance(layer, ConvLayer):
            if pos != first_conv_pos:
                out.append(dx_conv(layer))
            out.append(dw_conv(layer))
            if layer.has_bias:
                out.append(L.bias_grad(f"{layer.name}.db", layer.oh, layer.ow,
                                       layer.n, layer.oc))
                out.append(L.param_update(f"{layer.name}.upd_b", layer.oc, 1))
            out.append(L.param_update(f"{layer.name}.upd_w",
                                      layer.weight_elems, 4))
        elif isinstance(layer, GemmLayer):
            # Both operand gradients are themselves GEMMs (dX = dY.W^T,
            # dW = X^T.dY); for activation-activation GEMMs (attention
            # scores, A.V — param=False) "dW" is just the other operand's
            # gradient and there is no parameter to update.
            out.append(dx_gemm(layer))
            out.append(dw_gemm(layer))
            if layer.param:
                if layer.has_bias:
                    out.append(L.bias_grad(f"{layer.name}.db", 1, 1,
                                           layer.m * layer.count, layer.n))
                    out.append(L.param_update(f"{layer.name}.upd_b",
                                              layer.n * layer.count, 1))
                out.append(L.param_update(
                    f"{layer.name}.upd_w",
                    layer.weight_elems * layer.count, 2))
        elif isinstance(layer, SimdLayer):
            if layer.op == "bn":
                out.append(L.bn_back(f"{layer.name}.back", layer.h, layer.w,
                                     layer.n, layer.c))
                out.append(L.param_update(f"{layer.name}.upd_g", layer.c, 1))
                out.append(L.param_update(f"{layer.name}.upd_b", layer.c, 1))
            elif layer.op == "relu":
                out.append(L.relu_back(f"{layer.name}.back", layer.h, layer.w,
                                       layer.n, layer.c))
            elif layer.op.startswith("pool_"):
                mode = layer.op.split("_")[1]
                r, s = (layer.pool_r or 2), (layer.pool_s or 2)
                out.append(L.pool_back(f"{layer.name}.back", layer.h, layer.w,
                                       layer.n, layer.c, r, s, mode))
            elif layer.op == "gap":
                out.append(L.gap_back(f"{layer.name}.back", layer.h, layer.w,
                                      layer.n, layer.c))
            elif layer.op == "tensor_add":
                out.append(L.tensor_add(f"{layer.name}.back", layer.h, layer.w,
                                        layer.n, layer.c, phase="bwd"))
            elif (layer.op in _MIRROR_OPS
                  or layer.op.startswith(_MIRROR_PREFIXES)):
                out.append(replace(layer, name=f"{layer.name}.back",
                                   phase="bwd"))
                if layer.op == "rmsnorm":
                    out.append(L.param_update(f"{layer.name}.upd_g",
                                              layer.c, 1))
                elif layer.op == "layernorm":
                    out.append(L.param_update(f"{layer.name}.upd_g",
                                              layer.c, 1))
                    out.append(L.param_update(f"{layer.name}.upd_b",
                                              layer.c, 1))
    return out
