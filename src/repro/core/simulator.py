"""End-to-end SimDIT simulator (paper Fig. 1).

Input : HardwareSpec + a layer list (DNN Specifications) [+ optional
        externally-supplied tilings, mirroring the paper's compiler hook].
Output: per-layer and aggregate performance statistics — cycle counts
        (compute + DRAM stall), on-chip / off-chip access counts, op
        counts — plus the Sec. VI energy/power rollup and a Conv vs
        non-Conv breakdown (the paper's headline analysis, Tables VI-VII).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from .backward import expand_training_graph
from .conv_model import PerfStats, simulate_conv
from .energy import DEFAULT_ENERGY, EnergyModel, compute_energy
from .hardware import HardwareSpec
from .layers import ConvLayer, SimdLayer
from .networks import NETWORKS
from .simd_model import simulate_simd
from .tiling import ConvTiling, SimdTiling

Layer = Union[ConvLayer, SimdLayer]


@dataclass
class LayerReport:
    name: str
    engine: str
    phase: str
    op: str
    stats: PerfStats


@dataclass
class _Aggregates:
    """One-pass rollup of a layer list: per-engine cycle/traffic sums so the
    NetworkReport properties stop re-scanning every layer on each access."""
    total_cycles: int = 0
    stall_cycles: int = 0
    compute_by_engine: Dict[str, int] = field(default_factory=dict)
    cycles_by_engine: Dict[str, int] = field(default_factory=dict)
    cycles_by_phase: Dict[str, int] = field(default_factory=dict)
    dram_by_engine: Dict[str, int] = field(default_factory=dict)
    sram_by_engine: Dict[str, int] = field(default_factory=dict)
    dram_total: int = 0
    sram_total: int = 0
    sram_by_buffer: Dict[str, int] = field(default_factory=dict)
    ops: Dict[str, int] = field(default_factory=dict)

    @classmethod
    def scan(cls, layers: List["LayerReport"]) -> "_Aggregates":
        ag = cls()
        for r in layers:
            s = r.stats
            tc = s.total_cycles
            dram = s.dram_total_bits
            sram = s.sram_total_bits
            ag.total_cycles += tc
            ag.stall_cycles += s.stall_cycles
            e = r.engine
            ag.compute_by_engine[e] = \
                ag.compute_by_engine.get(e, 0) + s.compute_cycles
            ag.cycles_by_engine[e] = ag.cycles_by_engine.get(e, 0) + tc
            # same namespaced keys as the DSE phase grids ('sa' -> 'conv')
            pk = f"{'conv' if e == 'sa' else 'simd'}:{r.phase}"
            ag.cycles_by_phase[pk] = ag.cycles_by_phase.get(pk, 0) + tc
            ag.dram_by_engine[e] = ag.dram_by_engine.get(e, 0) + dram
            ag.sram_by_engine[e] = ag.sram_by_engine.get(e, 0) + sram
            ag.dram_total += dram
            ag.sram_total += sram
            for k, v in s.sram_bits.items():
                ag.sram_by_buffer[k] = ag.sram_by_buffer.get(k, 0) + v
            for k, v in s.ops.items():
                ag.ops[k] = ag.ops.get(k, 0) + v
        return ag


@dataclass
class NetworkReport:
    layers: List[LayerReport] = field(default_factory=list)
    _agg: Optional[_Aggregates] = field(default=None, repr=False, compare=False)
    _agg_len: int = field(default=-1, repr=False, compare=False)

    # ---- aggregates --------------------------------------------------------
    def _aggregates(self) -> _Aggregates:
        """Cached one-pass rollup; recomputed when layers are appended or
        removed (keyed on the list length — replacing a layer in place
        without changing the count is not supported)."""
        if self._agg is None or self._agg_len != len(self.layers):
            self._agg = _Aggregates.scan(self.layers)
            self._agg_len = len(self.layers)
        return self._agg

    @property
    def total_cycles(self) -> int:
        return self._aggregates().total_cycles

    @property
    def compute_cycles_sa(self) -> int:
        return self._aggregates().compute_by_engine.get("sa", 0)

    @property
    def compute_cycles_simd(self) -> int:
        return self._aggregates().compute_by_engine.get("simd", 0)

    @property
    def stall_cycles(self) -> int:
        return self._aggregates().stall_cycles

    def cycles(self, engine: Optional[str] = None) -> int:
        ag = self._aggregates()
        return ag.total_cycles if engine is None \
            else ag.cycles_by_engine.get(engine, 0)

    def dram_bits(self, engine: Optional[str] = None) -> int:
        ag = self._aggregates()
        return ag.dram_total if engine is None \
            else ag.dram_by_engine.get(engine, 0)

    def sram_bits(self, engine: Optional[str] = None) -> int:
        ag = self._aggregates()
        return ag.sram_total if engine is None \
            else ag.sram_by_engine.get(engine, 0)

    def sram_bits_by_buffer(self) -> Dict[str, int]:
        return dict(self._aggregates().sram_by_buffer)

    def ops(self) -> Dict[str, int]:
        return dict(self._aggregates().ops)

    def cycles_by_phase(self) -> Dict[str, int]:
        """Phase-resolved cycle attribution, keyed like the DSE phase
        grids ('conv:fwd', 'conv:bwd_dx', 'conv:bwd_dw', 'simd:fwd',
        'simd:bwd'); values sum exactly to ``total_cycles``."""
        return dict(self._aggregates().cycles_by_phase)

    def phase_shares(self) -> Dict[str, float]:
        """Each phase's fraction of total cycles."""
        tot = self.total_cycles
        return {k: (v / tot if tot else 0.0)
                for k, v in self._aggregates().cycles_by_phase.items()}

    def nonconv_fraction(self, metric: str = "cycles") -> float:
        """Fraction of the metric attributable to non-Conv (SIMD) layers."""
        if metric == "cycles":
            tot, sub = self.cycles(), self.cycles("simd")
        elif metric == "dram":
            tot, sub = self.dram_bits(), self.dram_bits("simd")
        elif metric == "sram":
            tot, sub = self.sram_bits(), self.sram_bits("simd")
        else:
            raise ValueError(metric)
        return sub / tot if tot else 0.0

    def energy_inputs(self) -> Dict[str, object]:
        """The exact per-network quantities ``energy()`` hands to
        ``compute_energy`` — busy cycles per engine, total cycles, SRAM
        bits by buffer, DRAM bits.  The DSE cost tables carry the same
        five quantities per candidate; exposing them here is what lets
        the batched energy tensors be validated against the simulator."""
        return dict(
            c_sa=self.compute_cycles_sa,
            c_simd=self.compute_cycles_simd,
            l_total=self.total_cycles,
            sram_bits=self.sram_bits_by_buffer(),
            dram_bits=self.dram_bits())

    def energy(self, hw: HardwareSpec,
               em: EnergyModel = DEFAULT_ENERGY) -> Dict[str, float]:
        return compute_energy(hw, em=em, **self.energy_inputs())

    def nonconv_energy_fraction(self, hw: HardwareSpec,
                                em: EnergyModel = DEFAULT_ENERGY) -> float:
        """Energy attribution: SIMD compute + SIMD-side accesses vs total.

        Leakage is apportioned by each engine's share of total cycles."""
        conv = NetworkReport([r for r in self.layers if r.engine == "sa"])
        nonc = NetworkReport([r for r in self.layers if r.engine == "simd"])
        tot = self.energy(hw, em)["E_total"]
        if tot <= 0:
            return 0.0
        e_n = compute_energy(hw, c_sa=0,
                             c_simd=nonc.compute_cycles_simd,
                             l_total=nonc.total_cycles,
                             sram_bits=nonc.sram_bits_by_buffer(),
                             dram_bits=nonc.dram_bits(), em=em)["E_total"]
        return e_n / tot


def simulate_network(hw: HardwareSpec, net: List[Layer],
                     stall_model: str = "simdit",
                     tilings: Optional[Dict[str, Union[ConvTiling, SimdTiling]]] = None,
                     ) -> NetworkReport:
    report = NetworkReport()
    tilings = tilings or {}
    for layer in net:
        if isinstance(layer, ConvLayer):
            stats = simulate_conv(hw, layer, tilings.get(layer.name),
                                  stall_model=stall_model)
            report.layers.append(LayerReport(layer.name, "sa", layer.phase,
                                             layer.kind, stats))
        else:
            stats = simulate_simd(hw, layer, tilings.get(layer.name),
                                  stall_model=stall_model)
            report.layers.append(LayerReport(layer.name, "simd", layer.phase,
                                             layer.op, stats))
    return report


def simulate(hw: HardwareSpec, network: str, mode: str = "inference",
             batch: Optional[int] = None,
             stall_model: str = "simdit") -> NetworkReport:
    """Convenience entry: network name + phase -> report.

    mode='inference' uses batch=1 by default; mode='training' expands the
    graph per Table I and uses batch=32 by default (paper Sec. VII-A).
    """
    if batch is None:
        batch = 1 if mode == "inference" else 32
    # BN is a training-phase layer (Sec. V-A); inference graphs are BN-folded.
    net = NETWORKS[network](batch, bn=(mode == "training"))
    if mode == "training":
        net = expand_training_graph(net)
    return simulate_network(hw, net, stall_model=stall_model)
