"""Design-space exploration — paper Sec. VII-B.

Exhaustively searches the 8-parameter space (sizes and DRAM bandwidths of
WBuf, IBuf, OBuf, VMem) under total-SRAM and total-bandwidth budgets, with
every candidate within +/-15% of the budgets (paper's setup).  The search
exploits two structural properties of the model:

  * separability: Conv cost depends only on (wbuf, ibuf, obuf) x
    (bw_w, bw_i, bw_o); non-Conv cost only on (vmem) x (bw_v);
  * tiling depends on buffer *sizes* only, so for a fixed size triple the
    per-tile quantities (compute cycles, per-stream bits, case-occurrence
    counts) are bandwidth-independent and the bandwidth sweep reduces to a
    vectorized max over parallel streams (Eq. 18) per valid case.

The vectorized tables are exact (tested against ``simulate_conv`` /
``simulate_simd``), so the search is numerically identical to brute force.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .conv_model import conv_multipliers, conv_tile_compute_cycles
from .hardware import KB, HardwareSpec
from .layers import ConvLayer, SimdLayer
from .simd_model import simulate_simd
from .tiling import ceil_div, make_conv_tiling, make_simd_tiling

Layer = Union[ConvLayer, SimdLayer]

SIZES_KB = (32, 64, 128, 256, 512, 1024, 2048)
BWS = (32, 64, 128, 256, 512, 1024, 2048)


# ---------------------------------------------------------------------------
# Vectorized per-size-triple cost tables
# ---------------------------------------------------------------------------

class ConvTable:
    """Bandwidth-independent per-layer quantities for fixed buffer sizes."""

    def __init__(self, hw: HardwareSpec, layers: Sequence[ConvLayer]):
        n = len(layers)
        self.c_tile = np.zeros(n)          # compute cycles / tile (incl. PSO)
        self.o1 = np.zeros(n); self.o2 = np.zeros(n)
        self.o4 = np.zeros(n); self.o5 = np.zeros(n)
        self.w_bits = np.zeros(n); self.wb_bits = np.zeros(n)
        self.i_bits = np.zeros(n)
        self.ps_bits = np.zeros(n); self.pls_bits = np.zeros(n)
        for x, layer in enumerate(layers):
            t = make_conv_tiling(hw, layer)
            m = conv_multipliers(layer, t)
            self.c_tile[x] = conv_tile_compute_cycles(hw, t) + hw.pso_sa
            o5 = m.m_oc
            o4 = m.m_w_tile - m.m_oc
            o1 = m.m_oc * (m.m_spatial - 1)
            o2 = (m.m_outer - m.m_spatial * m.m_oc) - o4
            self.o1[x], self.o2[x], self.o4[x], self.o5[x] = o1, o2, o4, o5
            w = t.weight_tile_elems() * hw.b_w
            b = t.T_oc * hw.b_b if layer.has_bias else 0
            self.w_bits[x] = w
            self.wb_bits[x] = w + b
            self.i_bits[x] = t.ifmap_tile_elems(layer.s) * hw.b_i
            p = t.psum_tile_elems() * hw.b_p
            self.ps_bits[x] = p
            self.pls_bits[x] = 2 * p

    def cycles(self, bw_w: int, bw_i: int, bw_o: int) -> int:
        t_w = np.ceil(self.w_bits / bw_w)
        t_wb = np.ceil(self.wb_bits / bw_w)
        t_i = np.ceil(self.i_bits / bw_i)
        t_ps = np.ceil(self.ps_bits / bw_o)
        t_pls = np.ceil(self.pls_bits / bw_o)
        c = self.c_tile
        seg1 = np.maximum(np.maximum(c, t_i), t_ps)
        seg2 = np.maximum(np.maximum(c, t_i), t_pls)
        seg4 = np.maximum(np.maximum(np.maximum(c, t_w), t_i), t_pls)
        seg5 = np.maximum(np.maximum(np.maximum(c, t_wb), t_i), t_ps)
        total = (self.o1 * seg1 + self.o2 * seg2
                 + self.o4 * seg4 + self.o5 * seg5)
        return int(total.sum())


class SimdTable:
    """Bandwidth-independent SIMD quantities for a fixed VMem size."""

    def __init__(self, hw: HardwareSpec, layers: Sequence[SimdLayer]):
        rows_b4, rows_b1, rows_mhwn, rows_mc = [], [], [], []
        self.compute = 0
        for layer in layers:
            t = make_simd_tiling(hw, layer)
            st = simulate_simd(hw, layer, t, stall_model="no_stall")
            self.compute += st.compute_cycles
            m_h = ceil_div(layer.h, t.T_h); m_w = ceil_div(layer.w, t.T_w)
            m_n = ceil_div(layer.n, t.T_n); m_c = ceil_div(layer.c, t.T_c)
            v4 = t.T_h * t.T_w * t.T_n * t.T_c
            for part in layer.parts:
                b4 = sum(int(np.ceil(v4 * ref.scale))
                         * (hw.b_in if ref.io == "in" else hw.b_out)
                         for ref in part.tensors if ref.rank == "4d")
                b1 = sum(t.T_c * (hw.b_in if ref.io == "in" else hw.b_out)
                         for ref in part.tensors if ref.rank == "1d")
                rows_b4.append(b4); rows_b1.append(b1)
                rows_mhwn.append(m_h * m_w * m_n); rows_mc.append(m_c)
        self.b4 = np.array(rows_b4, dtype=float)
        self.b1 = np.array(rows_b1, dtype=float)
        self.m_hwn = np.array(rows_mhwn, dtype=float)
        self.m_c = np.array(rows_mc, dtype=float)

    def cycles(self, bw_v: int) -> int:
        stall = (np.ceil(self.b4 / bw_v) * self.m_hwn
                 + np.where(self.b1 > 0, np.ceil(self.b1 / bw_v), 0.0)) * self.m_c
        return int(self.compute + stall.sum())


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DSEPoint:
    sizes_kb: Tuple[int, int, int, int]     # wbuf, ibuf, obuf, vmem
    bws: Tuple[int, int, int, int]          # bw_w, bw_i, bw_o, bw_v
    cycles: int

    @property
    def total_size_kb(self) -> int:
        return sum(self.sizes_kb)

    @property
    def total_bw(self) -> int:
        return sum(self.bws)


@dataclass
class DSEResult:
    best: DSEPoint
    worst: DSEPoint
    points: List[DSEPoint] = field(default_factory=list)

    @property
    def improvement(self) -> float:
        return self.worst.cycles / self.best.cycles

    def within(self, frac: float) -> List[DSEPoint]:
        lim = self.best.cycles * (1 + frac)
        return [p for p in self.points if p.cycles <= lim]

    def economic_min_sram(self, frac: float = 0.15) -> DSEPoint:
        return min(self.within(frac), key=lambda p: (p.total_size_kb, p.cycles))

    def economic_min_bw(self, frac: float = 0.15) -> DSEPoint:
        return min(self.within(frac),
                   key=lambda p: (p.total_bw, p.total_size_kb, p.cycles))


def _tuples(values: Sequence[int], n: int, lo: float, hi: float
            ) -> List[Tuple[int, ...]]:
    return [t for t in itertools.product(values, repeat=n)
            if lo <= sum(t) <= hi]


class _Engine:
    def __init__(self, hw_base: HardwareSpec, net: List[Layer]):
        self.hw = hw_base
        self.conv_layers = tuple(l for l in net if isinstance(l, ConvLayer))
        self.simd_layers = tuple(l for l in net if isinstance(l, SimdLayer))

    @lru_cache(maxsize=None)
    def _conv_table(self, wbuf_kb: int, ibuf_kb: int, obuf_kb: int) -> ConvTable:
        hw = self.hw.replace(wbuf=wbuf_kb * KB, ibuf=ibuf_kb * KB,
                             obuf=obuf_kb * KB)
        return ConvTable(hw, self.conv_layers)

    @lru_cache(maxsize=None)
    def _simd_table(self, vmem_kb: int) -> SimdTable:
        return SimdTable(self.hw.replace(vmem=vmem_kb * KB), self.simd_layers)

    @lru_cache(maxsize=None)
    def conv_cycles(self, wbuf_kb: int, ibuf_kb: int, obuf_kb: int,
                    bw_w: int, bw_i: int, bw_o: int) -> int:
        return self._conv_table(wbuf_kb, ibuf_kb, obuf_kb).cycles(bw_w, bw_i, bw_o)

    @lru_cache(maxsize=None)
    def simd_cycles(self, vmem_kb: int, bw_v: int) -> int:
        return self._simd_table(vmem_kb).cycles(bw_v)

    def cycles(self, sz: Tuple[int, ...], bw: Tuple[int, ...]) -> int:
        return (self.conv_cycles(sz[0], sz[1], sz[2], bw[0], bw[1], bw[2])
                + self.simd_cycles(sz[3], bw[3]))


def search(hw_base: HardwareSpec, net: List[Layer],
           size_budget_kb: int, bw_budget: int,
           sizes: Sequence[int] = SIZES_KB, bws: Sequence[int] = BWS,
           tol: float = 0.15, lower_bound: bool = True,
           collect: bool = True) -> DSEResult:
    """Exhaustive DSE. ``lower_bound=False`` drops the lower budget bound
    (used for the Fig. 11 / Table X economic-design landscape, where points
    far below budget are of interest); with ``collect=False`` only the
    best/worst and the within-15% frontier points are retained (streaming)."""
    eng = _Engine(hw_base, net)
    lo_s = size_budget_kb * (1 - tol) if lower_bound else 0
    lo_b = bw_budget * (1 - tol) if lower_bound else 0
    size_tuples = _tuples(sizes, 4, lo_s, size_budget_kb * (1 + tol))
    bw_tuples = _tuples(bws, 4, lo_b, bw_budget * (1 + tol))
    if not size_tuples or not bw_tuples:
        raise ValueError("empty DSE space; widen grids or budgets")

    best: Optional[DSEPoint] = None
    worst: Optional[DSEPoint] = None
    points: List[DSEPoint] = []
    for sz in size_tuples:
        for bw in bw_tuples:
            cyc = eng.cycles(sz, bw)
            if best is None or cyc < best.cycles:
                best = DSEPoint(sz, bw, cyc)
            if worst is None or cyc > worst.cycles:
                worst = DSEPoint(sz, bw, cyc)
            if collect:
                points.append(DSEPoint(sz, bw, cyc))

    if not collect:
        # second streaming pass: keep only the 15%-of-optimal frontier
        lim = best.cycles * 1.15
        for sz in size_tuples:
            for bw in bw_tuples:
                cyc = eng.cycles(sz, bw)
                if cyc <= lim:
                    points.append(DSEPoint(sz, bw, cyc))
    return DSEResult(best=best, worst=worst, points=points)


def sensitivity(hw_opt: HardwareSpec, net: List[Layer],
                sizes: Sequence[int] = SIZES_KB,
                bws: Sequence[int] = BWS) -> Dict[str, Dict[int, float]]:
    """Fig. 12: vary one parameter at a time around the optimal point;
    report cycles normalized to the optimal."""
    from .conv_model import simulate_conv

    def cost(hw: HardwareSpec) -> int:
        return sum((simulate_conv(hw, l) if isinstance(l, ConvLayer)
                    else simulate_simd(hw, l)).total_cycles for l in net)

    base = cost(hw_opt)
    out: Dict[str, Dict[int, float]] = {}
    for param, vals, unit in (
            ("wbuf", sizes, KB), ("ibuf", sizes, KB), ("obuf", sizes, KB),
            ("vmem", sizes, KB),
            ("bw_w", bws, 1), ("bw_i", bws, 1), ("bw_o", bws, 1),
            ("bw_v", bws, 1)):
        out[param] = {}
        for v in vals:
            hw = hw_opt.replace(**{param: v * unit})
            out[param][v] = cost(hw) / base
    return out
