"""Design-space exploration — paper Sec. VII-B, tensorized.

Exhaustively evaluates the 8-parameter space (sizes and DRAM bandwidths of
WBuf, IBuf, OBuf, VMem) under total-SRAM and total-bandwidth budgets, with
every candidate within +/-15% of the budgets (paper's setup).  The grid is
evaluated as dense array operations, never as a per-candidate Python loop.

Evaluation order of the tensorized engine:

  1. The candidate tuples are projected onto the model's separable axes:
     Conv cost depends only on (wbuf, ibuf, obuf) x (bw_w, bw_i, bw_o);
     non-Conv cost only on (vmem) x (bw_v).  Unique size triples / vmem
     values and unique bandwidth triples / bw_v values are enumerated once.
  2. For every unique size triple one ``ConvTable`` is built (tiling
     depends on buffer *sizes* only, so the per-tile quantities — compute
     cycles, per-stream bits, Table-IV case-occurrence counts — are
     bandwidth-independent); its ``cycles_batch`` then evaluates *all*
     bandwidth triples in one broadcasted ``np.maximum`` reduction over
     [n_bw_triples x n_layers], yielding a ``[n_size_triples x
     n_bw_triples]`` conv-cost matrix.  A ``[n_vmem x n_bw_v]`` SIMD-cost
     matrix is built the same way from ``SimdTable.cycles_batch``.
  3. The full grid cost is the outer addition of the two matrices routed
     through the budget-filtered candidate lists with ``np.ix_`` fancy
     indexing — one ``[n_size_tuples x n_bw_tuples]`` int64 array whose
     row-major order equals the legacy (size-outer, bandwidth-inner)
     iteration order.
  4. best/worst come from flat ``argmin``/``argmax`` (first occurrence ==
     legacy strict-inequality tie-break); the within-``frac`` frontier
     comes from a boolean mask.  ``DSEPoint`` objects are materialized
     only for the frontier, never for the full grid.

Tables are deduplicated across identically-shaped layers (names/phases
stripped) and — via ``search_many`` — shared across networks, so a Table IX
style multi-network sweep builds each per-size table once.  On top of
that, ``get_conv_table``/``get_simd_table`` keep a *process-lifetime*
cache keyed on (hw invariants, size triple, layer-shape+phase tuple), so
repeated ``search`` calls — a sweep over budgets whose size-tuple windows
overlap, or a training sweep after an inference sweep — rebuild nothing
(``table_cache_stats`` exposes the hit counters).

Training workloads (``training=True`` on ``search``/``search_many``) are
expanded once through ``expand_training_graph`` (Table I) and evaluated on
the same grid engine; the per-network *per-phase* matrices built alongside
the totals make the cost of any candidate phase-resolvable —
``DSEResult.phase_breakdown`` splits any grid point's cycles into
conv fwd / dX / dW and SIMD fwd / bwd (exactly partitioning the total),
and ``phase_profile`` does the same for a single fixed configuration.

The tensorized path is numerically identical to brute force: the retained
reference implementation ``search_reference`` walks the same grid with
scalar calls, and the equivalence is asserted bit-for-bit in
``tests/test_dse_equivalence.py``.

The search is front-end-pluggable (``method=...``): the exhaustive grid
above is the default and the reference; ``method="refine"`` dispatches to
the budget-constrained local search in ``core.optimize``, which drives
the same batched tables off the power-of-two lattice down to arbitrary
integer splits (see that module's docstring).

Both tables carry, alongside the cycle quantities, the per-layer *energy*
tensors of Sec. VI — busy cycles, SRAM bits per buffer, DRAM bits — all
bandwidth-independent, so any ``Objective`` (energy, EDP, power caps; see
``core.objectives``) prices the whole grid from one vectorized
``compute_energy_batch`` application and a cycles sweep followed by an
energy sweep rebuilds nothing.  The serial default builds uncached
per-size-triple tables through ``batch_build_conv_tables`` — the tiling
derivation and every table quantity are computed for ALL candidate size
triples in one vectorized pass per layer (``derive_conv_tilings_batch``
+ ``conv_quantities_batch``), never one Python walk per (triple, layer)
pair; ``prefetch_conv_tables`` remains the many-core option that fans
scalar builds across worker processes (``Study(workers=N)`` /
``$REPRO_DSE_WORKERS``).  Both are bit-identical to the scalar loop.

The preferred entry point is ``repro.core.study.Study`` (Workload /
Objective / Study); ``search``/``search_many`` below survive as thin
deprecation shims over a default ``Study``, bit-identical under the
default cycles objective.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from dataclasses import dataclass, field, replace
from functools import lru_cache
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from . import faultinject
from .backward import expand_training_graph
from .conv_model import (conv_dram_bits, conv_multipliers,
                         conv_quantities_batch, conv_segment_quantities,
                         conv_sram_bits)
from .energy import DEFAULT_ENERGY, EnergyModel, compute_energy_batch
from .gemm_model import (gemm_dram_bits, gemm_multipliers,
                         gemm_quantities_batch, gemm_segment_quantities,
                         gemm_sram_bits)
from .hardware import KB, HardwareSpec
from .store import active_store, env_float, reset_store_stats, store_stats
from .objectives import Cycles, MetricBatch, Objective, resolve_objective
from .layers import ConvLayer, GemmLayer, SimdLayer
from .simd_model import simd_part_tile_bits, simulate_simd
from .tiling import (_conv_hw_key, _conv_layer_key,
                     _derive_conv_tiling_arrays,
                     _derive_gemm_tiling_arrays, _gemm_layer_key,
                     _simd_hw_key, _simd_layer_key, ceil_div,
                     make_conv_tiling, make_gemm_tiling,
                     make_simd_tiling, prefill_simd_tilings)

Layer = Union[ConvLayer, GemmLayer, SimdLayer]

SIZES_KB = (32, 64, 128, 256, 512, 1024, 2048)
BWS = (32, 64, 128, 256, 512, 1024, 2048)

FRONTIER_FRAC = 0.15          # paper's "economic design" band (Table X)

BACKEND_ENV = "REPRO_DSE_BACKEND"
# Grid-evaluation backends of the exhaustive front-end: host numpy (the
# default and the reference), on-device jit/vmap reductions, and the
# jit/vmap path with best/worst routed through the fused Pallas
# outer-add+argmin kernel (``repro.core.gridax``).  All three are pinned
# bit-identical.
DSE_BACKENDS = ("numpy", "jax", "jax-fused")


def resolve_backend(backend: Optional[str]) -> str:
    """``None`` -> ``$REPRO_DSE_BACKEND`` (else ``"numpy"``); a known
    name passes through.  An unknown explicit argument raises; a garbage
    environment value warns (``RuntimeWarning`` naming it) and falls
    back to numpy — never a silent behavior change."""
    if backend is not None:
        if backend not in DSE_BACKENDS:
            raise ValueError(f"unknown DSE backend {backend!r}; "
                             f"known: {', '.join(DSE_BACKENDS)}")
        return backend
    val = os.environ.get(BACKEND_ENV)
    if not val:
        return "numpy"
    if val not in DSE_BACKENDS:
        import warnings
        warnings.warn(
            f"ignoring garbage {BACKEND_ENV}={val!r} "
            f"(known: {', '.join(DSE_BACKENDS)}); using 'numpy'",
            RuntimeWarning, stacklevel=2)
        return "numpy"
    return val


def _load_gridax(backend: str):
    """Import the JAX backend on demand (keeps ``import repro.core.dse``
    jax-free for numpy-only use), with a pointed error if jax is absent
    or broken in this environment."""
    try:
        from . import gridax
    except Exception as e:                       # pragma: no cover
        raise RuntimeError(
            f"DSE backend {backend!r} requires jax "
            f"(import failed: {e}); use backend='numpy' or unset "
            f"${BACKEND_ENV}") from e
    return gridax


# ---------------------------------------------------------------------------
# Vectorized per-size-triple cost tables
# ---------------------------------------------------------------------------

class ConvTable:
    """Bandwidth-independent per-layer quantities for fixed buffer sizes.

    Arrays are indexed [layer]; ``cycles_batch`` broadcasts them against a
    vector of bandwidth triples.  Alongside the cycle quantities the table
    carries the per-layer *energy* tensors — busy (compute) cycles, SRAM
    bits per buffer, total DRAM bits (Secs. IV-C, Table III) — so any
    energy-aware objective prices a candidate from the same cached table
    that prices its cycles (a cycles sweep followed by an energy sweep
    rebuilds nothing).
    """

    @classmethod
    def _from_columns(cls, phases: Tuple[str, ...],
                      cols: Mapping[str, np.ndarray],
                      busy: np.ndarray, dram: np.ndarray,
                      sram: Dict[str, np.ndarray]) -> "ConvTable":
        """Assemble a table from precomputed per-layer column vectors (the
        ``batch_build_conv_tables`` path: one vectorized quantity pass per
        layer covers every size triple, and each table is a column slice).
        Field values are bit-identical to the scalar ``__init__``."""
        t = cls.__new__(cls)
        t.phases = phases
        t.c_tile = cols["c_tile"]
        t.o1, t.o2 = cols["o1"], cols["o2"]
        t.o4, t.o5 = cols["o4"], cols["o5"]
        t.w_bits, t.wb_bits = cols["w_bits"], cols["wb_bits"]
        t.i_bits = cols["i_bits"]
        t.ps_bits, t.pls_bits = cols["ps_bits"], cols["pls_bits"]
        t.busy, t.dram, t.sram = busy, dram, sram
        return t

    def __init__(self, hw: HardwareSpec, layers: Sequence[ConvLayer]):
        n = len(layers)
        self.phases: Tuple[str, ...] = tuple(l.phase for l in layers)
        self.c_tile = np.zeros(n)          # compute cycles / tile (incl. PSO)
        self.o1 = np.zeros(n); self.o2 = np.zeros(n)
        self.o4 = np.zeros(n); self.o5 = np.zeros(n)
        self.w_bits = np.zeros(n); self.wb_bits = np.zeros(n)
        self.i_bits = np.zeros(n)
        self.ps_bits = np.zeros(n); self.pls_bits = np.zeros(n)
        self.busy = np.zeros(n, dtype=np.int64)      # compute cycles (C_SA)
        self.dram = np.zeros(n, dtype=np.int64)      # all streams, bits
        self.sram = {buf: np.zeros(n, dtype=np.int64)
                     for buf in ("wbuf", "ibuf", "obuf", "bbuf")}
        for x, layer in enumerate(layers):
            t = make_conv_tiling(hw, layer)
            m = conv_multipliers(layer, t)
            q = conv_segment_quantities(hw, layer, t, m)
            self.c_tile[x] = q.c_tile
            self.o1[x], self.o2[x] = q.o1, q.o2
            self.o4[x], self.o5[x] = q.o4, q.o5
            self.w_bits[x], self.wb_bits[x] = q.w_bits, q.wb_bits
            self.i_bits[x] = q.i_bits
            self.ps_bits[x], self.pls_bits[x] = q.ps_bits, q.pls_bits
            self.busy[x] = q.c_tile * (q.o1 + q.o2 + q.o4 + q.o5)
            self.dram[x] = sum(conv_dram_bits(hw, layer, t, m).values())
            for buf, bits in conv_sram_bits(hw, layer, t, m).items():
                self.sram[buf][x] = bits

    def layer_cycles_batch(self, bw_w, bw_i, bw_o) -> np.ndarray:
        """Per-layer segment-summed cycles for a *vector* of bandwidth
        triples: returns float64 [n_bw_triples x n_layers]."""
        bw_w = np.asarray(bw_w, dtype=float).reshape(-1, 1)
        bw_i = np.asarray(bw_i, dtype=float).reshape(-1, 1)
        bw_o = np.asarray(bw_o, dtype=float).reshape(-1, 1)
        t_w = np.ceil(self.w_bits / bw_w)
        t_wb = np.ceil(self.wb_bits / bw_w)
        t_i = np.ceil(self.i_bits / bw_i)
        t_ps = np.ceil(self.ps_bits / bw_o)
        t_pls = np.ceil(self.pls_bits / bw_o)
        c = self.c_tile
        seg1 = np.maximum(np.maximum(c, t_i), t_ps)
        seg2 = np.maximum(np.maximum(c, t_i), t_pls)
        seg4 = np.maximum(np.maximum(np.maximum(c, t_w), t_i), t_pls)
        seg5 = np.maximum(np.maximum(np.maximum(c, t_wb), t_i), t_ps)
        return (self.o1 * seg1 + self.o2 * seg2
                + self.o4 * seg4 + self.o5 * seg5)

    def cycles_batch(self, bw_w, bw_i, bw_o) -> np.ndarray:
        """Network cycles for a vector of bandwidth triples: int64 [m]."""
        return self.layer_cycles_batch(bw_w, bw_i, bw_o) \
            .sum(axis=1).astype(np.int64)

    def phase_cycles_batch(self, bw_w, bw_i, bw_o) -> Dict[str, np.ndarray]:
        """Per-phase cycles (reduced over the phase's layer columns) for a
        vector of bandwidth triples: {phase: int64 [m]}.  The phase sums
        partition the layer set, so they add up exactly to
        ``cycles_batch`` (all quantities are integers in float64)."""
        per_layer = self.layer_cycles_batch(bw_w, bw_i, bw_o)
        out: Dict[str, np.ndarray] = {}
        for ph in dict.fromkeys(self.phases):
            cols = [x for x, p in enumerate(self.phases) if p == ph]
            out[ph] = per_layer[:, cols].sum(axis=1).astype(np.int64)
        return out

    def cycles(self, bw_w: int, bw_i: int, bw_o: int) -> int:
        return int(self.cycles_batch([bw_w], [bw_i], [bw_o])[0])


class SimdTable:
    """Bandwidth-independent SIMD quantities for a fixed VMem size.

    Rows are indexed [layer-part]; ``layer_rows`` records each layer's
    contiguous row slice so a union table can serve several networks.
    """

    def __init__(self, hw: HardwareSpec, layers: Sequence[SimdLayer]):
        rows_b4, rows_b1, rows_mhwn, rows_mc = [], [], [], []
        self.compute = 0
        self.phases: Tuple[str, ...] = tuple(l.phase for l in layers)
        self.layer_compute: List[int] = []
        self.layer_rows: List[Tuple[int, int]] = []
        layer_dram, layer_sram = [], []
        for layer in layers:
            t = make_simd_tiling(hw, layer)
            st = simulate_simd(hw, layer, t, stall_model="no_stall")
            self.compute += st.compute_cycles
            self.layer_compute.append(st.compute_cycles)
            layer_dram.append(st.dram_total_bits)
            layer_sram.append(st.sram_total_bits)
            m_h = ceil_div(layer.h, t.T_h); m_w = ceil_div(layer.w, t.T_w)
            m_n = ceil_div(layer.n, t.T_n); m_c = ceil_div(layer.c, t.T_c)
            start = len(rows_b4)
            for part in layer.parts:
                b4, b1 = simd_part_tile_bits(hw, part, t)
                rows_b4.append(b4); rows_b1.append(b1)
                rows_mhwn.append(m_h * m_w * m_n); rows_mc.append(m_c)
            self.layer_rows.append((start, len(rows_b4)))
        self.b4 = np.array(rows_b4, dtype=float)
        self.b1 = np.array(rows_b1, dtype=float)
        self.m_hwn = np.array(rows_mhwn, dtype=float)
        self.m_c = np.array(rows_mc, dtype=float)
        # Energy tensors (Eqs. 34-36): busy cycles C_SIMD, VMem bits, DRAM
        # bits per layer — bandwidth-independent, cached with the table.
        self.busy = np.array(self.layer_compute, dtype=np.int64)
        self.dram = np.array(layer_dram, dtype=np.int64)
        self.sram_vmem = np.array(layer_sram, dtype=np.int64)

    def row_stall_batch(self, bw_v) -> np.ndarray:
        """Per-row stall cycles for a vector of bw_v: float64 [m x n_rows]."""
        bw = np.asarray(bw_v, dtype=float).reshape(-1, 1)
        return (np.ceil(self.b4 / bw) * self.m_hwn
                + np.where(self.b1 > 0, np.ceil(self.b1 / bw), 0.0)) * self.m_c

    def cycles_batch(self, bw_v) -> np.ndarray:
        """Network cycles for a vector of bw_v values: int64 [m]."""
        return (self.compute
                + self.row_stall_batch(bw_v).sum(axis=1)).astype(np.int64)

    def phase_cycles_batch(self, bw_v) -> Dict[str, np.ndarray]:
        """Per-phase cycles for a vector of bw_v values: {phase: int64 [m]}.
        Partitions ``cycles_batch`` exactly, like the ConvTable variant."""
        row_stall = self.row_stall_batch(bw_v)
        out: Dict[str, np.ndarray] = {}
        for ph in dict.fromkeys(self.phases):
            ids = [x for x, p in enumerate(self.phases) if p == ph]
            rows = [r for i in ids for r in range(*self.layer_rows[i])]
            compute = sum(self.layer_compute[i] for i in ids)
            out[ph] = (compute + row_stall[:, rows].sum(axis=1)) \
                .astype(np.int64)
        return out

    def cycles(self, bw_v: int) -> int:
        return int(self.cycles_batch([bw_v])[0])


class GemmTable(ConvTable):
    """Bandwidth-independent per-layer GEMM quantities for fixed buffer
    sizes.  The stall-segment reduction and the energy tensor layout are
    the systolic-array ones ``ConvTable`` already implements (a GEMM is
    the conv model's unit-kernel specialization), so every batch accessor
    — ``layer_cycles_batch``/``cycles_batch``/``phase_cycles_batch`` and
    the ``_from_columns`` assembly path — is inherited unchanged; only
    the per-layer quantity derivation differs.  ``layer.count`` is folded
    into the occurrence counts and energy tensors (all linear), never the
    per-block volumes the segment maxima read."""

    def __init__(self, hw: HardwareSpec, layers: Sequence[GemmLayer]):
        n = len(layers)
        self.phases: Tuple[str, ...] = tuple(l.phase for l in layers)
        self.c_tile = np.zeros(n)
        self.o1 = np.zeros(n); self.o2 = np.zeros(n)
        self.o4 = np.zeros(n); self.o5 = np.zeros(n)
        self.w_bits = np.zeros(n); self.wb_bits = np.zeros(n)
        self.i_bits = np.zeros(n)
        self.ps_bits = np.zeros(n); self.pls_bits = np.zeros(n)
        self.busy = np.zeros(n, dtype=np.int64)
        self.dram = np.zeros(n, dtype=np.int64)
        self.sram = {buf: np.zeros(n, dtype=np.int64)
                     for buf in ("wbuf", "ibuf", "obuf", "bbuf")}
        for x, layer in enumerate(layers):
            t = make_gemm_tiling(hw, layer)
            m = gemm_multipliers(layer, t)
            q = gemm_segment_quantities(hw, layer, t, m)
            cnt = layer.count
            self.c_tile[x] = q.c_tile
            self.o1[x], self.o2[x] = q.o1 * cnt, q.o2 * cnt
            self.o4[x], self.o5[x] = q.o4 * cnt, q.o5 * cnt
            self.w_bits[x], self.wb_bits[x] = q.w_bits, q.wb_bits
            self.i_bits[x] = q.i_bits
            self.ps_bits[x], self.pls_bits[x] = q.ps_bits, q.pls_bits
            self.busy[x] = q.c_tile * (q.o1 + q.o2 + q.o4 + q.o5) * cnt
            self.dram[x] = sum(gemm_dram_bits(hw, layer, t, m).values()) * cnt
            for buf, bits in gemm_sram_bits(hw, layer, t, m).items():
                self.sram[buf][x] = bits * cnt


# ---------------------------------------------------------------------------
# Process-lifetime table cache
#
# A ConvTable depends only on the conv-relevant hardware invariants
# (buffer sizes, bit widths, array dims — exactly ``_conv_hw_key``) and the
# layer *shapes*; a SimdTable on (vmem, b_in, K) — the tiling key — plus
# b_out and the ALU latency table, which its tile bits / compute bake in.
# Caching them across ``search`` calls means a Table VIII style sweep over
# *budgets* rebuilds nothing for the size triples the budget windows share,
# and a training sweep reuses every table an earlier inference sweep of the
# same shapes built.  Phases ride along in the key so a cached table's
# ``phases`` vector always matches its caller's layer list.
# ---------------------------------------------------------------------------

_CONV_TABLE_CACHE: Dict[tuple, ConvTable] = {}   # guarded-by: _CACHE_LOCK
_SIMD_TABLE_CACHE: Dict[tuple, SimdTable] = {}   # guarded-by: _CACHE_LOCK
_GEMM_TABLE_CACHE: Dict[tuple, GemmTable] = {}   # guarded-by: _CACHE_LOCK
_PREFETCHED_UNTOUCHED: set = set()               # guarded-by: _CACHE_LOCK
# One lock guards every L1 dict, the miss-accounting set, and the stat
# counters: the serving subsystem (``repro.serve``) drives these caches
# from a dispatcher thread plus arbitrary client threads, where unlocked
# check-then-build races would double-build tables and `+=` on the
# counters would lose updates.  Reentrant because a build path may call
# back into another getter (e.g. a store load validating against the
# cache).  Held across table construction on purpose: the barrier test
# in tests/test_dse_threadsafety.py pins "concurrent identical gets
# build exactly once".
_CACHE_LOCK = threading.RLock()
_TABLE_CACHE_STATS = {"conv_hits": 0, "conv_misses": 0,  # guarded-by: _CACHE_LOCK
                      "simd_hits": 0, "simd_misses": 0,
                      "gemm_hits": 0, "gemm_misses": 0,
                      "conv_parallel_builds": 0,
                      "conv_batch_builds": 0,
                      "gemm_batch_builds": 0,
                      "conv_builds": 0, "simd_builds": 0, "gemm_builds": 0}


def _conv_table_key(hw: HardwareSpec, layers: Sequence[ConvLayer]) -> tuple:
    return (_conv_hw_key(hw),
            tuple((_conv_layer_key(l), l.phase) for l in layers))


def _gemm_table_key(hw: HardwareSpec, layers: Sequence[GemmLayer]) -> tuple:
    # the conv hw invariants are exactly the GEMM-relevant ones (buffer
    # sizes, bit widths, array dims); count scales the table linearly so
    # it must key alongside the shape
    return (_conv_hw_key(hw),
            tuple((_gemm_layer_key(l), l.count, l.phase) for l in layers))


def _simd_table_key(hw: HardwareSpec, layers: Sequence[SimdLayer]) -> tuple:
    return (_simd_hw_key(hw), hw.b_out, tuple(sorted(hw.lat.items())),
            tuple((_simd_layer_key(l), l.phase) for l in layers))


def get_conv_table(hw: HardwareSpec, layers: Sequence[ConvLayer]) -> ConvTable:
    """Shared, process-lifetime ConvTable constructor — the L1 over the
    optional persistent store (``core.store``): an in-memory miss first
    consults the active store (validated, checksummed load) and only
    builds on a store miss, writing the fresh table back.  Thread-safe:
    the whole check-then-build is one critical section, so concurrent
    identical gets build exactly once."""
    key = _conv_table_key(hw, layers)
    with _CACHE_LOCK:
        t = _CONV_TABLE_CACHE.get(key)
        if t is not None:
            if key in _PREFETCHED_UNTOUCHED:
                # First retrieval of a parallel-prefetched (or store-seeded)
                # table: account it as the miss the caller's serial loop
                # would have recorded, so hit/miss statistics are identical
                # between workers=0/>1 and store on/off.
                _PREFETCHED_UNTOUCHED.discard(key)
                _TABLE_CACHE_STATS["conv_misses"] += 1
            else:
                _TABLE_CACHE_STATS["conv_hits"] += 1
            return t
        _TABLE_CACHE_STATS["conv_misses"] += 1
        store = active_store()
        if store is not None:
            t = store.load("conv", key, ConvTable)
            if t is not None:
                _CONV_TABLE_CACHE[key] = t
                return t
        _TABLE_CACHE_STATS["conv_builds"] += 1
        t = _CONV_TABLE_CACHE[key] = ConvTable(hw, layers)
        if store is not None:
            store.save("conv", key, t)
        return t


def get_simd_table(hw: HardwareSpec, layers: Sequence[SimdLayer]) -> SimdTable:
    """Shared, process-lifetime SimdTable constructor (L1 over the
    optional persistent store, like ``get_conv_table``; same
    single-build thread-safety contract)."""
    key = _simd_table_key(hw, layers)
    with _CACHE_LOCK:
        t = _SIMD_TABLE_CACHE.get(key)
        if t is not None:
            _TABLE_CACHE_STATS["simd_hits"] += 1
            return t
        _TABLE_CACHE_STATS["simd_misses"] += 1
        store = active_store()
        if store is not None:
            t = store.load("simd", key, SimdTable)
            if t is not None:
                _SIMD_TABLE_CACHE[key] = t
                return t
        _TABLE_CACHE_STATS["simd_builds"] += 1
        t = _SIMD_TABLE_CACHE[key] = SimdTable(hw, layers)
        if store is not None:
            store.save("simd", key, t)
        return t


def get_gemm_table(hw: HardwareSpec, layers: Sequence[GemmLayer]) -> GemmTable:
    """Shared, process-lifetime GemmTable constructor (L1 over the
    optional persistent store, like ``get_conv_table`` — store kind
    ``"gemm"``).  Seeded entries from ``batch_build_gemm_tables`` count a
    miss on first retrieval, keeping statistics path-independent."""
    key = _gemm_table_key(hw, layers)
    with _CACHE_LOCK:
        t = _GEMM_TABLE_CACHE.get(key)
        if t is not None:
            if key in _PREFETCHED_UNTOUCHED:
                _PREFETCHED_UNTOUCHED.discard(key)
                _TABLE_CACHE_STATS["gemm_misses"] += 1
            else:
                _TABLE_CACHE_STATS["gemm_hits"] += 1
            return t
        _TABLE_CACHE_STATS["gemm_misses"] += 1
        store = active_store()
        if store is not None:
            t = store.load("gemm", key, GemmTable)
            if t is not None:
                _GEMM_TABLE_CACHE[key] = t
                return t
        _TABLE_CACHE_STATS["gemm_builds"] += 1
        t = _GEMM_TABLE_CACHE[key] = GemmTable(hw, layers)
        if store is not None:
            store.save("gemm", key, t)
        return t


def _build_conv_table(args) -> ConvTable:
    """Worker-process entry point for the parallel table prefetch.  The
    optional third element is a fault directive injected (and consumed)
    on the submission side by ``core.faultinject`` — ``times=N`` there
    means exactly N poisoned *tasks*, independent of worker count."""
    hw, layers, directive = args if len(args) == 3 else (*args, None)
    if directive is not None:
        kind = directive[0]
        if kind == "exc":
            raise RuntimeError("faultinject: injected worker exception")
        if kind == "crash":
            os._exit(17)
        if kind == "hang":
            time.sleep(directive[1])
    return ConvTable(hw, layers)


def batch_build_conv_tables(hws: Sequence[HardwareSpec],
                            layers: Sequence[ConvLayer]) -> None:
    """Build the ConvTables for every hardware variant not already cached
    in ONE vectorized pass per layer, and seed the shared cache.

    This is the serial fast path (and the default): the greedy tiling
    derivation runs once per layer over the whole candidate axis — in
    struct-of-arrays form (``_derive_conv_tiling_arrays``), so no
    per-candidate ``ConvTiling`` objects are ever materialized — the
    per-layer table quantities are computed as candidate-axis vectors
    (``conv_quantities_batch``), and each table is a column slice: no
    per-(size triple, layer) Python walk anywhere.  Bit-identical to the scalar ``ConvTable`` loop; each
    seeded table is accounted as a miss on first retrieval (exactly like
    the fork-pool prefetch), so cache statistics match the legacy serial
    path.  ``table_cache_stats()['conv_batch_builds']`` counts the tables
    built this way."""
    layers = list(layers)
    if not layers:
        # zero-conv networks (pure GEMM/SIMD transformers): nothing to
        # derive, and an empty table would only pollute the cache
        return
    with _CACHE_LOCK:
        _batch_build_conv_tables_locked(hws, layers)


def _batch_build_conv_tables_locked(hws: Sequence[HardwareSpec],  # holds-lock: _CACHE_LOCK
                                    layers: List[ConvLayer]) -> None:
    # one layers-part tuple shared by every per-variant cache key (the
    # inner tuple of _conv_table_key, hoisted out of the hw loop)
    lpart = tuple((_conv_layer_key(l), l.phase) for l in layers)
    missing = [(key, hw) for hw in dict.fromkeys(hws)
               if (key := (_conv_hw_key(hw), lpart))
               not in _CONV_TABLE_CACHE]
    store = active_store()
    if store is not None and missing:
        # L2 pass: validated store loads seed the L1 before anything is
        # rebuilt.  Loaded entries count a miss on first retrieval (the
        # _PREFETCHED_UNTOUCHED contract), keeping the legacy counters
        # identical whether the store is on or off.
        still = []
        for key, hw in missing:
            t = store.load("conv", key, ConvTable)
            if t is None:
                still.append((key, hw))
            else:
                _CONV_TABLE_CACHE[key] = t
                _PREFETCHED_UNTOUCHED.add(key)
        missing = still
    if not missing:
        return
    base = missing[0][1]
    tail = _conv_hw_key(base)[3:]       # bbuf, bit widths, J, K
    if any(key[0][3:] != tail for key, _ in missing):
        raise ValueError("batch_build_conv_tables requires all hardware "
                         "variants to share every conv invariant except "
                         "the wbuf/ibuf/obuf sizes")
    triples = [(hw.wbuf, hw.ibuf, hw.obuf) for _, hw in missing]
    n_l, n_t = len(layers), len(triples)
    f_fields = ("c_tile", "o1", "o2", "o4", "o5", "w_bits", "wb_bits",
                "i_bits", "ps_bits", "pls_bits")
    mats = {f: np.zeros((n_l, n_t)) for f in f_fields}
    busy = np.zeros((n_l, n_t), dtype=np.int64)
    dram = np.zeros((n_l, n_t), dtype=np.int64)
    sram = {buf: np.zeros((n_l, n_t), dtype=np.int64)
            for buf in ("wbuf", "ibuf", "obuf", "bbuf")}
    for x, layer in enumerate(layers):
        q = conv_quantities_batch(
            base, layer, _derive_conv_tiling_arrays(base, triples, layer))
        for f in f_fields:
            mats[f][x] = q[f]
        busy[x] = q["busy"]
        dram[x] = q["dram"]
        for buf in sram:
            sram[buf][x] = q["sram"][buf]
    phases = tuple(l.phase for l in layers)
    # column views into the [n_layers x n_triples] matrices (a few KB per
    # matrix — cheaper than 14 copies per table, and numerically identical)
    for i, (key, _hw) in enumerate(missing):
        t = _CONV_TABLE_CACHE[key] = ConvTable._from_columns(
            phases, {f: mats[f][:, i] for f in f_fields},
            busy[:, i], dram[:, i],
            {buf: sram[buf][:, i] for buf in sram})
        _PREFETCHED_UNTOUCHED.add(key)
        _TABLE_CACHE_STATS["conv_batch_builds"] += 1
        _TABLE_CACHE_STATS["conv_builds"] += 1
        if store is not None:
            store.save("conv", key, t)


def batch_build_gemm_tables(hws: Sequence[HardwareSpec],
                            layers: Sequence[GemmLayer]) -> None:
    """Build the GemmTables for every hardware variant not already cached
    in ONE vectorized pass per layer (the GEMM twin of
    ``batch_build_conv_tables``: struct-of-arrays tiling derivation +
    ``gemm_quantities_batch``, each table a column slice), and seed the
    shared cache.  Bit-identical to the scalar ``GemmTable`` loop; an
    empty layer union is a clean no-op."""
    layers = list(layers)
    if not layers:
        return
    with _CACHE_LOCK:
        _batch_build_gemm_tables_locked(hws, layers)


def _batch_build_gemm_tables_locked(hws: Sequence[HardwareSpec],  # holds-lock: _CACHE_LOCK
                                    layers: List[GemmLayer]) -> None:
    lpart = tuple((_gemm_layer_key(l), l.count, l.phase) for l in layers)
    missing = [(key, hw) for hw in dict.fromkeys(hws)
               if (key := (_conv_hw_key(hw), lpart))
               not in _GEMM_TABLE_CACHE]
    store = active_store()
    if store is not None and missing:
        still = []
        for key, hw in missing:
            t = store.load("gemm", key, GemmTable)
            if t is None:
                still.append((key, hw))
            else:
                _GEMM_TABLE_CACHE[key] = t
                _PREFETCHED_UNTOUCHED.add(key)
        missing = still
    if not missing:
        return
    base = missing[0][1]
    tail = _conv_hw_key(base)[3:]       # bbuf, bit widths, J, K
    if any(key[0][3:] != tail for key, _ in missing):
        raise ValueError("batch_build_gemm_tables requires all hardware "
                         "variants to share every invariant except the "
                         "wbuf/ibuf/obuf sizes")
    triples = [(hw.wbuf, hw.ibuf, hw.obuf) for _, hw in missing]
    n_l, n_t = len(layers), len(triples)
    f_fields = ("c_tile", "o1", "o2", "o4", "o5", "w_bits", "wb_bits",
                "i_bits", "ps_bits", "pls_bits")
    mats = {f: np.zeros((n_l, n_t)) for f in f_fields}
    busy = np.zeros((n_l, n_t), dtype=np.int64)
    dram = np.zeros((n_l, n_t), dtype=np.int64)
    sram = {buf: np.zeros((n_l, n_t), dtype=np.int64)
            for buf in ("wbuf", "ibuf", "obuf", "bbuf")}
    for x, layer in enumerate(layers):
        q = gemm_quantities_batch(
            base, layer, _derive_gemm_tiling_arrays(base, triples, layer))
        for f in f_fields:
            mats[f][x] = q[f]
        busy[x] = q["busy"]
        dram[x] = q["dram"]
        for buf in sram:
            sram[buf][x] = q["sram"][buf]
    phases = tuple(l.phase for l in layers)
    for i, (key, _hw) in enumerate(missing):
        t = _GEMM_TABLE_CACHE[key] = GemmTable._from_columns(
            phases, {f: mats[f][:, i] for f in f_fields},
            busy[:, i], dram[:, i],
            {buf: sram[buf][:, i] for buf in sram})
        _PREFETCHED_UNTOUCHED.add(key)
        _TABLE_CACHE_STATS["gemm_batch_builds"] += 1
        _TABLE_CACHE_STATS["gemm_builds"] += 1
        if store is not None:
            store.save("gemm", key, t)


PREFETCH_TIMEOUT_ENV = "REPRO_DSE_BUILD_TIMEOUT"
PREFETCH_DEFAULT_TIMEOUT_S = 120.0     # per retry attempt, whole task batch
PREFETCH_RETRIES = 2                   # re-pool attempts after a failure
PREFETCH_BACKOFF_S = 0.05              # sleep base between attempts


def _fault_directive() -> Optional[tuple]:
    """Submission-side fault consumption for the parallel build tasks
    (see ``_build_conv_table``)."""
    if faultinject.fire("conv_worker_exc"):
        return ("exc",)
    if faultinject.fire("conv_worker_crash"):
        return ("crash",)
    f = faultinject.fire("conv_worker_hang")
    if f is not None:
        return ("hang", f.arg if f.arg is not None else 3600.0)
    return None


def _terminate_pool(pool) -> None:
    """Best-effort teardown of a pool that may hold hung or dead workers:
    never join (a hung worker would hang *us* — the failure mode this
    layer exists to prevent), just cancel and kill."""
    try:
        pool.shutdown(wait=False, cancel_futures=True)
    except Exception:
        pass
    for proc in list((getattr(pool, "_processes", None) or {}).values()):
        try:
            proc.terminate()
        except Exception:
            pass


def prefetch_conv_tables(hws: Sequence[HardwareSpec],
                         layers: Sequence[ConvLayer],
                         workers: int, *,
                         timeout_s: Optional[float] = None,
                         retries: Optional[int] = None) -> None:
    """Build the ConvTables for every hardware variant not already cached,
    fanned out across ``workers`` processes, and seed the shared cache.

    The per-size-triple builds are independent, so the fan-out is
    embarrassingly parallel and — each build being deterministic —
    bit-identical to the serial path.  Since the serial path itself now
    vectorizes the tiling derivation and table quantities across the
    whole candidate axis (``batch_build_conv_tables``), the fork pool is
    the *many-core* option for heavy shape unions, not the default.  Each
    prefetched table is accounted as a miss on its first retrieval (not a
    hit), so cache statistics match the serial path exactly; callers with
    ``workers <= 1`` (or a single missing table, or no fork start method)
    fall back to the vectorized serial build implicitly.

    Fault tolerance: a worker that raises, hard-exits (the pool breaks),
    or hangs past the per-attempt ``timeout_s`` (default
    ``$REPRO_DSE_BUILD_TIMEOUT`` or 120 s) can neither poison the cache
    nor hang the sweep.  Completed tables are salvaged even from a
    broken or timed-out pool, failed tasks are retried on a fresh pool
    (``retries`` attempts with linear backoff), and whatever still fails
    is simply left missing — the caller's ``batch_build_conv_tables``
    pass rebuilds it serially, so the only cost of any worker fault is
    wall time.  This function never raises on worker failure."""
    if not layers:
        # zero-conv networks: never spin up a pool for an empty union
        return
    store = active_store()
    with _CACHE_LOCK:
        missing = [(key, hw) for hw in dict.fromkeys(hws)
                   if (key := _conv_table_key(hw, layers))
                   not in _CONV_TABLE_CACHE
                   and not (store is not None
                            and store.contains("conv", key))]
    if workers <= 1 or len(missing) < 2:
        return
    from concurrent.futures import TimeoutError as FutTimeout
    from concurrent.futures import ProcessPoolExecutor, as_completed
    from multiprocessing import get_context
    try:
        ctx = get_context("fork")      # cheap workers via COW; no re-import
    except ValueError:                 # platform without fork: stay serial
        return
    if timeout_s is None:
        timeout_s = env_float(PREFETCH_TIMEOUT_ENV,
                              PREFETCH_DEFAULT_TIMEOUT_S)
    if retries is None:
        retries = PREFETCH_RETRIES
    layers = tuple(layers)

    def seed(key: tuple, table: ConvTable) -> None:
        with _CACHE_LOCK:
            _CONV_TABLE_CACHE[key] = table
            _PREFETCHED_UNTOUCHED.add(key)
            _TABLE_CACHE_STATS["conv_parallel_builds"] += 1
            _TABLE_CACHE_STATS["conv_builds"] += 1
            if store is not None:
                store.save("conv", key, table)

    for attempt in range(retries + 1):
        n = min(int(workers), len(missing))
        pool = ProcessPoolExecutor(max_workers=n, mp_context=ctx)
        futs: Dict[object, Tuple[tuple, HardwareSpec]] = {}
        failed: List[Tuple[tuple, HardwareSpec]] = []
        for key, hw in missing:
            try:
                futs[pool.submit(_build_conv_table,
                                 (hw, layers, _fault_directive()))] = (key, hw)
            except Exception:          # pool already broken mid-submission
                failed.append((key, hw))
        pending = dict(futs)
        try:
            for fut in as_completed(futs, timeout=timeout_s):
                key, hw = pending.pop(fut)
                try:
                    seed(key, fut.result(timeout=0))
                except Exception:      # worker exception or broken pool
                    failed.append((key, hw))
        except FutTimeout:
            pass
        # Salvage: a timeout above abandons the iteration, but tasks that
        # finished before the deadline still carry valid tables.
        for fut, (key, hw) in pending.items():
            if fut.done():
                try:
                    seed(key, fut.result(timeout=0))
                    continue
                except Exception:
                    pass
            else:
                fut.cancel()
            failed.append((key, hw))
        _terminate_pool(pool)
        missing = failed
        if not missing:
            return
        time.sleep(PREFETCH_BACKOFF_S * (attempt + 1))
    # retries exhausted: leave the remainder to the caller's guaranteed
    # serial fallback (batch_build_conv_tables)


def table_cache_stats() -> Dict[str, object]:
    """Hit/miss counters plus current entry counts of the shared caches.
    ``by_kind`` nests the same numbers per table kind for dashboards that
    track conv and simd (and future kinds) separately.  The ``store_*``
    counters come from the persistent L2 (``core.store``): store hits
    (validated on-disk loads), misses, quarantined corruptions, LRU
    evictions and lock-wait timeouts; ``conv_builds``/``simd_builds``
    count actual table constructions across every path, so a warm-store
    sweep is assertable as "store hits only, zero builds".  The counter
    copy is taken under the cache lock, so callers (e.g. the service
    metrics snapshot in ``repro.serve``) always see a consistent cut —
    never a miss without its matching build."""
    with _CACHE_LOCK:
        stats = dict(_TABLE_CACHE_STATS,
                     conv_entries=len(_CONV_TABLE_CACHE),
                     simd_entries=len(_SIMD_TABLE_CACHE),
                     gemm_entries=len(_GEMM_TABLE_CACHE))
        stats.update(store_stats())
    stats["by_kind"] = {
        "conv": {"hits": stats["conv_hits"], "misses": stats["conv_misses"],
                 "entries": stats["conv_entries"],
                 "builds": stats["conv_builds"],
                 "parallel_builds": stats["conv_parallel_builds"],
                 "batch_builds": stats["conv_batch_builds"]},
        "simd": {"hits": stats["simd_hits"], "misses": stats["simd_misses"],
                 "entries": stats["simd_entries"],
                 "builds": stats["simd_builds"], "parallel_builds": 0,
                 "batch_builds": 0},
        "gemm": {"hits": stats["gemm_hits"], "misses": stats["gemm_misses"],
                 "entries": stats["gemm_entries"],
                 "builds": stats["gemm_builds"], "parallel_builds": 0,
                 "batch_builds": stats["gemm_batch_builds"]},
    }
    return stats


def clear_table_caches() -> None:
    """Drop all cached tables and zero the counters (benchmark fairness).
    The persistent store's *files* are untouched — surviving the death of
    the in-memory cache is their whole point — but its counters reset."""
    with _CACHE_LOCK:
        _CONV_TABLE_CACHE.clear()
        _SIMD_TABLE_CACHE.clear()
        _GEMM_TABLE_CACHE.clear()
        _PREFETCHED_UNTOUCHED.clear()
        for k in _TABLE_CACHE_STATS:
            _TABLE_CACHE_STATS[k] = 0
        reset_store_stats()


# ---------------------------------------------------------------------------
# Result types
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DSEPoint:
    sizes_kb: Tuple[int, int, int, int]     # wbuf, ibuf, obuf, vmem
    bws: Tuple[int, int, int, int]          # bw_w, bw_i, bw_o, bw_v
    cycles: int

    @property
    def total_size_kb(self) -> int:
        return sum(self.sizes_kb)

    @property
    def total_bw(self) -> int:
        return sum(self.bws)


@dataclass(frozen=True)
class PhaseBreakdown:
    """Phase-resolved cycle attribution of one design point.

    ``cycles`` maps namespaced phase keys ('conv:fwd', 'conv:bwd_dx',
    'conv:bwd_dw', 'gemm:fwd', 'gemm:bwd_dx', 'gemm:bwd_dw', 'simd:fwd',
    'simd:bwd') to cycle counts; the keys partition the layer set, so the
    values sum exactly to the point's total cycles.  Derived shares give
    the paper's Table VI style conv-vs-non-conv and fwd-vs-bwd splits for
    *any* grid candidate; GEMM phases run on the systolic array, so they
    count toward ``conv_cycles`` (the array side of the split) and are
    also exposed separately as ``gemm_cycles``."""
    cycles: Tuple[Tuple[str, int], ...]

    @classmethod
    def from_dict(cls, d: Mapping[str, int]) -> "PhaseBreakdown":
        return cls(tuple(sorted(d.items())))

    def as_dict(self) -> Dict[str, int]:
        return dict(self.cycles)

    @property
    def total(self) -> int:
        return sum(v for _, v in self.cycles)

    @property
    def conv_cycles(self) -> int:
        return sum(v for k, v in self.cycles
                   if k.startswith(("conv:", "gemm:")))

    @property
    def gemm_cycles(self) -> int:
        return sum(v for k, v in self.cycles if k.startswith("gemm:"))

    @property
    def nonconv_cycles(self) -> int:
        return sum(v for k, v in self.cycles if k.startswith("simd:"))

    @property
    def fwd_cycles(self) -> int:
        return sum(v for k, v in self.cycles if k.endswith(":fwd"))

    @property
    def bwd_cycles(self) -> int:
        return self.total - self.fwd_cycles

    @property
    def nonconv_share(self) -> float:
        t = self.total
        return self.nonconv_cycles / t if t else 0.0

    @property
    def bwd_share(self) -> float:
        t = self.total
        return self.bwd_cycles / t if t else 0.0


@dataclass(eq=False)          # ndarray field: compare grids by identity
class DSEGrid:
    """The evaluated grid: an int64 cost matrix over the budget-filtered
    candidate tuples, size tuples along rows (legacy outer loop) and
    bandwidth tuples along columns (legacy inner loop)."""
    costs: np.ndarray                        # [n_size_tuples x n_bw_tuples]
    size_tuples: List[Tuple[int, int, int, int]]
    bw_tuples: List[Tuple[int, int, int, int]]

    @property
    def n_candidates(self) -> int:
        return int(self.costs.size)

    def point(self, flat_index: int) -> DSEPoint:
        n_bw = len(self.bw_tuples)
        return DSEPoint(self.size_tuples[flat_index // n_bw],
                        self.bw_tuples[flat_index % n_bw],
                        int(self.costs.flat[flat_index]))

    def points_below(self, limit: float,
                     values: Optional[np.ndarray] = None) -> List[DSEPoint]:
        """Materialize DSEPoints whose value (cycles by default, or the
        given objective-score array) is <= limit, in grid order."""
        vals = self.costs if values is None else values
        idx = np.nonzero(vals.ravel() <= limit)[0]
        return [self.point(int(i)) for i in idx]

    def locate(self, point: DSEPoint) -> Tuple[int, int]:
        """(size-row, bandwidth-column) indices of a point's tuples."""
        if not hasattr(self, "_size_index"):
            self._size_index = {t: i for i, t in enumerate(self.size_tuples)}
            self._bw_index = {t: i for i, t in enumerate(self.bw_tuples)}
        try:
            return self._size_index[point.sizes_kb], self._bw_index[point.bws]
        except KeyError:
            raise ValueError(f"point {point} is not on this grid") from None


@dataclass(eq=False)
class _PhaseGrids:
    """Per-phase cost matrices over the same separable axes as the total
    grid: conv matrices are [n_size_triples x n_bw_triples], simd matrices
    [n_vmem x n_bw_v]; the ``*_of`` projections route any candidate's grid
    coordinates into them.  Together they phase-resolve every candidate of
    the search space without materializing per-phase full grids."""
    conv: Dict[str, np.ndarray]          # 'conv:<phase>' -> matrix
    simd: Dict[str, np.ndarray]          # 'simd:<phase>' -> matrix
    s3_of: np.ndarray
    b3_of: np.ndarray
    v_of: np.ndarray
    w_of: np.ndarray

    def breakdown_at(self, si: int, bi: int) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ph, m in self.conv.items():
            out[ph] = int(m[self.s3_of[si], self.b3_of[bi]])
        for ph, m in self.simd.items():
            out[ph] = int(m[self.v_of[si], self.w_of[bi]])
        return out


@dataclass(eq=False)
class _EnergyFields:
    """Per-candidate energy inputs over the grid's separable axes.

    The five quantities ``compute_energy`` needs — busy cycles per
    engine, SRAM bits per buffer, DRAM bits — are bandwidth-independent,
    so one vector over the unique size triples (conv side) plus one over
    the unique VMem values (SIMD side) prices the whole grid; ``grids``
    broadcasts them (via the ``s3_of``/``v_of`` row projections) against
    the cycles matrix through the vectorized energy model.  Kept on every
    grid result and applied lazily, so pure-cycles searches never pay."""
    hw: HardwareSpec
    em: EnergyModel
    conv: Dict[str, np.ndarray]          # over size triples
    simd: Dict[str, np.ndarray]          # over vmem values
    s3_of: np.ndarray
    v_of: np.ndarray
    sizes_kb: np.ndarray                 # [n_size_tuples x 4]

    def grids(self, l_total: np.ndarray) -> Dict[str, np.ndarray]:
        """The full vectorized energy report, shaped like ``l_total``
        ([n_size_tuples x n_bw_tuples] cycles)."""
        def col(v: np.ndarray) -> np.ndarray:
            return v[:, None]

        conv, simd = self.conv, self.simd
        sram_bits = {"wbuf": col(conv["wbuf"][self.s3_of]),
                     "ibuf": col(conv["ibuf"][self.s3_of]),
                     "obuf": col(conv["obuf"][self.s3_of]),
                     "bbuf": col(conv["bbuf"][self.s3_of]),
                     "vmem": col(simd["vmem"][self.v_of])}
        sram_sizes = {"wbuf": col(self.sizes_kb[:, 0] * KB),
                      "ibuf": col(self.sizes_kb[:, 1] * KB),
                      "obuf": col(self.sizes_kb[:, 2] * KB),
                      "bbuf": self.hw.bbuf,
                      "vmem": col(self.sizes_kb[:, 3] * KB)}
        return compute_energy_batch(
            self.hw, em=self.em,
            c_sa=col(conv["busy"][self.s3_of]),
            c_simd=col(simd["busy"][self.v_of]),
            l_total=l_total,
            sram_bits=sram_bits, sram_sizes=sram_sizes,
            dram_bits=col(conv["dram"][self.s3_of]
                          + simd["dram"][self.v_of]))


def _pareto_mask(cycles: np.ndarray, energy: np.ndarray) -> np.ndarray:
    """Boolean mask of the 2-D Pareto frontier (minimize both).  Weak
    dominance: of several candidates with identical (cycles, energy) the
    first in input order is kept."""
    n = len(cycles)
    order = np.lexsort((np.arange(n), energy, cycles))
    keep = np.zeros(n, dtype=bool)
    best_e = np.inf
    for i in order:
        if energy[i] < best_e:
            keep[i] = True
            best_e = energy[i]
    return keep


@dataclass
class DSEResult:
    """Outcome of one DSE run, from either search front-end.

    Grid results carry the full cost matrix (``grid``) plus the per-phase
    matrices; refine results instead carry the optimizer's evaluation
    ``archive`` (every candidate it costed, in evaluation order — the
    off-lattice analogue of the grid), its ``refine`` trace, and a
    table-backed phase attribution hook, so ``points``/``within``/
    ``economic_min_*``/``phase_breakdown`` work identically for both.
    For refine results ``worst`` is the worst *evaluated* candidate (a
    local search never visits the global worst), so ``improvement`` is a
    lower bound on the grid's best/worst ratio.

    ``objective`` names the metric the search minimized; ``best``/
    ``worst``/``points``/``within`` are all in terms of its score (for
    the default cycles objective the score IS the cycle count, so the
    legacy behavior is unchanged bit for bit).  Independently of the
    objective, every result can price any of its candidates —
    ``energy_of``/``power_of``/``edp_of``/``energy_report`` — and
    ``pareto()`` materializes the 2-D (cycles, energy) frontier."""
    best: DSEPoint
    worst: DSEPoint
    grid: Optional[DSEGrid] = field(default=None, repr=False, compare=False)
    phase_grids: Optional[_PhaseGrids] = field(
        default=None, repr=False, compare=False)
    _frontier: Optional[List[DSEPoint]] = field(
        default=None, repr=False, compare=False)
    refine: Optional["RefineTrace"] = field(
        default=None, repr=False, compare=False)
    archive: Optional[List[DSEPoint]] = field(
        default=None, repr=False, compare=False)
    _phase_at: Optional[object] = field(       # Callable[[DSEPoint], dict]
        default=None, repr=False, compare=False)
    objective: str = "cycles"
    grid_scores: Optional[np.ndarray] = field(   # None -> grid.costs
        default=None, repr=False, compare=False)
    archive_scores: Optional[List[float]] = field(  # None -> archive cycles
        default=None, repr=False, compare=False)
    _energy: Optional[_EnergyFields] = field(
        default=None, repr=False, compare=False)
    _energy_at: Optional[object] = field(      # Callable[[DSEPoint], dict]
        default=None, repr=False, compare=False)
    _energy_many: Optional[object] = field(    # Callable[[pts], E_total arr]
        default=None, repr=False, compare=False)
    _energy_grids: Optional[Dict[str, np.ndarray]] = field(
        default=None, repr=False, compare=False)
    _pareto_mask_fn: Optional[object] = field(  # Callable[(cyc, e), mask]
        default=None, repr=False, compare=False)

    @property
    def improvement(self) -> float:
        return self.worst.cycles / self.best.cycles

    @property
    def n_candidates(self) -> int:
        """Candidates whose cost was computed: the full grid for the
        exhaustive front-end, the optimizer's unique evaluations for
        refine (the denominator/numerator of the >=10x saving claim)."""
        if self.grid is not None:
            return self.grid.n_candidates
        if self.refine is not None:
            return self.refine.n_evals
        return 0

    # ---- objective scores --------------------------------------------------

    @property
    def best_score(self) -> float:
        """The minimized objective score of ``best`` (== ``best.cycles``
        for the cycles objective)."""
        return self.score_of(self.best)

    def score_of(self, point: DSEPoint) -> float:
        """The objective score of any evaluated candidate."""
        if self.grid is not None:
            if self.grid_scores is None:
                return point.cycles
            si, bi = self.grid.locate(point)
            return float(self.grid_scores[si, bi])
        if self.archive is not None:
            if self.archive_scores is None:
                return point.cycles
            return float(self.archive_scores[self._archive_index(point)])
        raise ValueError("result has no retained grid or archive")

    def _archive_index(self, point: DSEPoint) -> int:
        if not hasattr(self, "_arch_idx"):
            self._arch_idx = {(p.sizes_kb, p.bws): i
                              for i, p in enumerate(self.archive)}
        try:
            return self._arch_idx[(point.sizes_kb, point.bws)]
        except KeyError:
            raise ValueError(f"point {point} was never evaluated") from None

    # ---- energy accessors --------------------------------------------------

    def _grid_energy(self) -> Dict[str, np.ndarray]:
        if self._energy_grids is None:
            if self._energy is None:
                raise ValueError("result carries no energy tensors")
            self._energy_grids = self._energy.grids(self.grid.costs)
        return self._energy_grids

    def energy_report(self, point: Optional[DSEPoint] = None
                      ) -> Dict[str, float]:
        """The full Sec. VI energy/power breakdown of any evaluated
        candidate (default: best) — the vectorized analogue of
        ``NetworkReport.energy``, keys as in ``compute_energy``."""
        point = point if point is not None else self.best
        if self.grid is not None:
            si, bi = self.grid.locate(point)
            return {k: float(v[si, bi])
                    for k, v in self._grid_energy().items()}
        if self._energy_at is not None:
            return {k: float(v) for k, v in self._energy_at(point).items()}
        raise ValueError("result carries no energy tensors")

    def energy_of(self, point: Optional[DSEPoint] = None) -> float:
        """E_total (Joules) of any evaluated candidate (default: best)."""
        return self.energy_report(point)["E_total"]

    def power_of(self, point: Optional[DSEPoint] = None) -> float:
        """P_avg (Watts) of any evaluated candidate (default: best)."""
        return self.energy_report(point)["P_avg"]

    def edp_of(self, point: Optional[DSEPoint] = None) -> float:
        """Energy-delay product (Joule-seconds) of any candidate."""
        rep = self.energy_report(point)
        return rep["E_total"] * rep["runtime_s"]

    def pareto(self) -> List[DSEPoint]:
        """The 2-D (cycles, energy) Pareto frontier over every evaluated
        candidate, in grid/evaluation order: no frontier member is beaten
        on both metrics by any other candidate.  Configurations achieving
        the minimum cycles and the minimum energy are always represented
        (on an exact tie in one metric, the representative is the tied
        point with the better other metric)."""
        # engines may install a bit-identical accelerated mask (the jax
        # backend's vectorized lexsort+prefix-min vs the host walk)
        mask_fn = self._pareto_mask_fn if self._pareto_mask_fn is not None \
            else _pareto_mask
        if self.grid is not None:
            cycles = self.grid.costs.ravel()
            energy = self._grid_energy()["E_total"].ravel()
            idx = np.nonzero(mask_fn(cycles, energy))[0]
            return [self.grid.point(int(i)) for i in idx]
        if self.archive is not None:
            cycles = np.array([p.cycles for p in self.archive], dtype=float)
            if self._energy_many is not None:
                energy = np.asarray(self._energy_many(self.archive))
            else:
                energy = np.array([self.energy_of(p) for p in self.archive])
            mask = mask_fn(cycles, energy)
            return [p for p, k in zip(self.archive, mask) if k]
        raise ValueError("result has no retained grid or archive")

    # ---- frontiers ---------------------------------------------------------

    @property
    def points(self) -> List[DSEPoint]:
        """The within-15%-of-optimal frontier (paper Table X / Fig. 11),
        measured in the result's objective.  Only these points are ever
        materialized as objects; the full grid stays an array in
        ``grid.costs`` (grid results) and refine results filter their
        evaluation archive."""
        if self._frontier is None:
            self._frontier = self.within(FRONTIER_FRAC)
        return self._frontier

    def within(self, frac: float) -> List[DSEPoint]:
        """Candidates whose objective score is within ``frac`` of the
        optimum (infeasible candidates — score inf — never qualify)."""
        limit = self.best_score * (1 + frac)
        if self.grid is not None:
            return self.grid.points_below(limit, self.grid_scores)
        if self.archive is not None:
            if self.archive_scores is None:
                return [p for p in self.archive if p.cycles <= limit]
            return [p for p, s in zip(self.archive, self.archive_scores)
                    if s <= limit]
        raise ValueError("result has no retained grid or archive")

    def economic_min_sram(self, frac: float = FRONTIER_FRAC) -> DSEPoint:
        return min(self.within(frac), key=lambda p: (p.total_size_kb, p.cycles))

    def economic_min_bw(self, frac: float = FRONTIER_FRAC) -> DSEPoint:
        return min(self.within(frac),
                   key=lambda p: (p.total_bw, p.total_size_kb, p.cycles))

    def phase_breakdown(self, point: Optional[DSEPoint] = None
                        ) -> PhaseBreakdown:
        """Phase-resolved cycle attribution for any candidate (default:
        the best point).  Grid results route the point's coordinates into
        the per-phase matrices; refine results re-derive the phase sums
        through the shared cost tables, which works for *any* point —
        on-lattice or off — and still partitions the total exactly."""
        point = point if point is not None else self.best
        if self.grid is not None and self.phase_grids is not None:
            si, bi = self.grid.locate(point)
            return PhaseBreakdown.from_dict(
                self.phase_grids.breakdown_at(si, bi))
        if self._phase_at is not None:
            return PhaseBreakdown.from_dict(self._phase_at(point))
        raise ValueError("result has no retained phase grids")


# ---------------------------------------------------------------------------
# Grid construction
# ---------------------------------------------------------------------------

def _tuples(values: Sequence[int], n: int, lo: float, hi: float
            ) -> List[Tuple[int, ...]]:
    return [t for t in itertools.product(values, repeat=n)
            if lo <= sum(t) <= hi]


def _project(tuples: Sequence[tuple], sel) -> Tuple[list, np.ndarray]:
    """Unique projections of the candidate tuples (first-seen order) and
    the per-candidate index into that unique list."""
    uniq: Dict[object, int] = {}
    idx = np.empty(len(tuples), dtype=np.intp)
    out: list = []
    for i, t in enumerate(tuples):
        key = sel(t)
        j = uniq.get(key)
        if j is None:
            j = uniq[key] = len(out)
            out.append(key)
        idx[i] = j
    return out, idx


def _norm_conv(layer: ConvLayer) -> ConvLayer:
    """Strip fields the cost model never reads, so identically-shaped
    layers share one table column."""
    return replace(layer, name="", phase="fwd", kind="conv")


def _norm_simd(layer: SimdLayer) -> SimdLayer:
    return replace(layer, name="", phase="fwd", pool_r=0, pool_s=0)


def _norm_gemm(layer: GemmLayer) -> GemmLayer:
    """Strip fields the cost model never reads (``param`` only gates the
    training expansion; ``count`` scales the cost so it stays) — a dW
    GEMM shape-equal to some fwd GEMM shares its table column."""
    return replace(layer, name="", phase="fwd", param=True)


class _GridEngine:
    """Shared batched cost tables for one or more networks.

    Builds each per-size-triple ``ConvTable`` / per-vmem ``SimdTable`` once
    over the *union* of unique layer shapes across all networks; per-network
    costs are column gathers over the union arrays (same value sequence as a
    dedicated per-network table, hence bit-identical sums).
    """

    def __init__(self, hw_base: HardwareSpec,
                 nets: Mapping[str, Sequence[Layer]]):
        self.hw = hw_base
        self._conv_union: List[ConvLayer] = []
        self._simd_union: List[SimdLayer] = []
        self._gemm_union: List[GemmLayer] = []
        conv_index: Dict[ConvLayer, int] = {}
        simd_index: Dict[SimdLayer, int] = {}
        gemm_index: Dict[GemmLayer, int] = {}
        self.conv_cols: Dict[str, List[int]] = {}
        self.simd_ids: Dict[str, List[int]] = {}
        self.gemm_cols: Dict[str, List[int]] = {}
        # Per-network per-phase column/id lists.  Dedup is by *shape* (phase
        # stripped), so a fwd conv and a shape-identical dX conv share one
        # table column but are attributed to their own phases here.
        self.conv_phase_cols: Dict[str, Dict[str, List[int]]] = {}
        self.simd_phase_ids: Dict[str, Dict[str, List[int]]] = {}
        self.gemm_phase_cols: Dict[str, Dict[str, List[int]]] = {}
        for name, net in nets.items():
            ccols: List[int] = []
            sids: List[int] = []
            gcols: List[int] = []
            pcols: Dict[str, List[int]] = {}
            pids: Dict[str, List[int]] = {}
            gpcols: Dict[str, List[int]] = {}
            for layer in net:
                if isinstance(layer, ConvLayer):
                    k = _norm_conv(layer)
                    j = conv_index.get(k)
                    if j is None:
                        j = conv_index[k] = len(self._conv_union)
                        self._conv_union.append(k)
                    ccols.append(j)
                    pcols.setdefault(f"conv:{layer.phase}", []).append(j)
                elif isinstance(layer, GemmLayer):
                    k = _norm_gemm(layer)
                    j = gemm_index.get(k)
                    if j is None:
                        j = gemm_index[k] = len(self._gemm_union)
                        self._gemm_union.append(k)
                    gcols.append(j)
                    gpcols.setdefault(f"gemm:{layer.phase}", []).append(j)
                else:
                    k = _norm_simd(layer)
                    j = simd_index.get(k)
                    if j is None:
                        j = simd_index[k] = len(self._simd_union)
                        self._simd_union.append(k)
                    sids.append(j)
                    pids.setdefault(f"simd:{layer.phase}", []).append(j)
            self.conv_cols[name] = ccols
            self.simd_ids[name] = sids
            self.gemm_cols[name] = gcols
            self.conv_phase_cols[name] = pcols
            self.simd_phase_ids[name] = pids
            self.gemm_phase_cols[name] = gpcols

    def conv_matrices(self, s3s: Sequence[Tuple[int, int, int]],
                      b3s: Sequence[Tuple[int, int, int]],
                      workers: int = 0
                      ) -> Tuple[Dict[str, np.ndarray],
                                 Dict[str, Dict[str, np.ndarray]],
                                 Dict[str, Dict[str, np.ndarray]]]:
        """Per-network [n_size_triples x n_bw_triples] conv-cost matrices:
        (totals, per-phase, energy fields).  Totals are computed over the
        full column list exactly as before the phase split (same summation
        order, hence bit-identical to the scalar reference); phase matrices
        partition them.  The energy fields are per-network vectors over the
        size triples — busy cycles, SRAM bits per buffer, DRAM bits — the
        bandwidth-independent half of the Sec. VI model.  Uncached tables
        are built up front: ``workers > 1`` fans scalar builds out across
        processes, and whatever remains is batch-built serially in one
        vectorized pass per layer (``batch_build_conv_tables``) before
        the per-triple loop walks the cache."""
        bw_w = np.array([b[0] for b in b3s], dtype=float)
        bw_i = np.array([b[1] for b in b3s], dtype=float)
        bw_o = np.array([b[2] for b in b3s], dtype=float)
        mats = {name: np.zeros((len(s3s), len(b3s)), dtype=np.int64)
                for name in self.conv_cols}
        # Single-phase networks (all inference sweeps): the one phase's
        # column list IS the total's, so alias the totals matrix instead of
        # re-reducing every row.
        pmats = {name: {ph: np.zeros((len(s3s), len(b3s)), dtype=np.int64)
                        for ph in phases} if len(phases) > 1
                 else {ph: mats[name] for ph in phases}
                 for name, phases in self.conv_phase_cols.items()}
        efields = {name: {k: np.zeros(len(s3s), dtype=np.int64)
                          for k in ("busy", "wbuf", "ibuf", "obuf",
                                    "bbuf", "dram")}
                   for name in self.conv_cols}
        if not self._conv_union:
            # zero-conv networks (pure GEMM/SIMD): the zeroed matrices
            # and empty per-phase dicts ARE the conv contribution — never
            # build or fetch an empty-union table
            return mats, pmats, efields
        hws = [self.hw.replace(wbuf=wb * KB, ibuf=ib * KB, obuf=ob * KB)
               for wb, ib, ob in s3s]
        if workers > 1:
            prefetch_conv_tables(hws, self._conv_union, workers)
        batch_build_conv_tables(hws, self._conv_union)
        for si, hw in enumerate(hws):
            table = get_conv_table(hw, self._conv_union)
            per_layer = table.layer_cycles_batch(bw_w, bw_i, bw_o)
            for name, cols in self.conv_cols.items():
                if cols:
                    mats[name][si] = per_layer[:, cols].sum(axis=1) \
                        .astype(np.int64)
                    e = efields[name]
                    e["busy"][si] = table.busy[cols].sum()
                    e["dram"][si] = table.dram[cols].sum()
                    for buf in ("wbuf", "ibuf", "obuf", "bbuf"):
                        e[buf][si] = table.sram[buf][cols].sum()
                pcs = self.conv_phase_cols[name]
                if len(pcs) > 1:
                    for ph, pc in pcs.items():
                        pmats[name][ph][si] = per_layer[:, pc].sum(axis=1) \
                            .astype(np.int64)
        return mats, pmats, efields

    def simd_matrices(self, vmems: Sequence[int], bw_vs: Sequence[int]
                      ) -> Tuple[Dict[str, np.ndarray],
                                 Dict[str, Dict[str, np.ndarray]],
                                 Dict[str, Dict[str, np.ndarray]]]:
        """Per-network [n_vmem x n_bw_v] SIMD-cost matrices:
        (totals, per-phase, energy fields over the vmem values)."""
        bw_v = np.array(bw_vs, dtype=float)
        mats = {name: np.zeros((len(vmems), len(bw_vs)), dtype=np.int64)
                for name in self.simd_ids}
        # Same single-phase aliasing as conv_matrices.
        pmats = {name: {ph: np.zeros((len(vmems), len(bw_vs)), dtype=np.int64)
                        for ph in phases} if len(phases) > 1
                 else {ph: mats[name] for ph in phases}
                 for name, phases in self.simd_phase_ids.items()}
        efields = {name: {k: np.zeros(len(vmems), dtype=np.int64)
                          for k in ("busy", "vmem", "dram")}
                   for name in self.simd_ids}
        if not self._simd_union:
            # SIMD-free networks: zeroed contribution, no empty tables
            return mats, pmats, efields
        # One vectorized derivation per layer covers every VMem candidate
        # before the per-size loop (the table builds then hit the cache).
        prefill_simd_tilings(self.hw, [vm * KB for vm in vmems],
                             self._simd_union)
        for vi, vm in enumerate(vmems):
            table = get_simd_table(self.hw.replace(vmem=vm * KB),
                                   self._simd_union)
            row_stall = table.row_stall_batch(bw_v)

            def net_cycles(ids: List[int]) -> np.ndarray:
                rows = [r for i in ids
                        for r in range(*table.layer_rows[i])]
                compute = sum(table.layer_compute[i] for i in ids)
                return (compute + row_stall[:, rows].sum(axis=1)) \
                    .astype(np.int64)

            for name, ids in self.simd_ids.items():
                if ids:
                    mats[name][vi] = net_cycles(ids)
                    e = efields[name]
                    e["busy"][vi] = table.busy[ids].sum()
                    e["vmem"][vi] = table.sram_vmem[ids].sum()
                    e["dram"][vi] = table.dram[ids].sum()
                pis = self.simd_phase_ids[name]
                if len(pis) > 1:
                    for ph, pi in pis.items():
                        pmats[name][ph][vi] = net_cycles(pi)
        return mats, pmats, efields

    def gemm_matrices(self, s3s: Sequence[Tuple[int, int, int]],
                      b3s: Sequence[Tuple[int, int, int]]
                      ) -> Tuple[Dict[str, np.ndarray],
                                 Dict[str, Dict[str, np.ndarray]],
                                 Dict[str, Dict[str, np.ndarray]]]:
        """Per-network [n_size_triples x n_bw_triples] GEMM-cost matrices
        over the SAME separable axes as ``conv_matrices`` (GEMMs live on
        the systolic array: WBuf/IBuf/OBuf sizes, w/i/o bandwidths), so
        the caller outer-adds them into the conv matrices before the grid
        composition.  Same (totals, per-phase, energy fields) contract;
        tables are batch-built serially in one vectorized pass per layer
        (``batch_build_gemm_tables``)."""
        bw_w = np.array([b[0] for b in b3s], dtype=float)
        bw_i = np.array([b[1] for b in b3s], dtype=float)
        bw_o = np.array([b[2] for b in b3s], dtype=float)
        mats = {name: np.zeros((len(s3s), len(b3s)), dtype=np.int64)
                for name in self.gemm_cols}
        # Same single-phase aliasing as conv_matrices.
        pmats = {name: {ph: np.zeros((len(s3s), len(b3s)), dtype=np.int64)
                        for ph in phases} if len(phases) > 1
                 else {ph: mats[name] for ph in phases}
                 for name, phases in self.gemm_phase_cols.items()}
        efields = {name: {k: np.zeros(len(s3s), dtype=np.int64)
                          for k in ("busy", "wbuf", "ibuf", "obuf",
                                    "bbuf", "dram")}
                   for name in self.gemm_cols}
        if not self._gemm_union:
            return mats, pmats, efields
        hws = [self.hw.replace(wbuf=wb * KB, ibuf=ib * KB, obuf=ob * KB)
               for wb, ib, ob in s3s]
        batch_build_gemm_tables(hws, self._gemm_union)
        for si, hw in enumerate(hws):
            table = get_gemm_table(hw, self._gemm_union)
            per_layer = table.layer_cycles_batch(bw_w, bw_i, bw_o)
            for name, cols in self.gemm_cols.items():
                if cols:
                    mats[name][si] = per_layer[:, cols].sum(axis=1) \
                        .astype(np.int64)
                    e = efields[name]
                    e["busy"][si] = table.busy[cols].sum()
                    e["dram"][si] = table.dram[cols].sum()
                    for buf in ("wbuf", "ibuf", "obuf", "bbuf"):
                        e[buf][si] = table.sram[buf][cols].sum()
                pcs = self.gemm_phase_cols[name]
                if len(pcs) > 1:
                    for ph, pc in pcs.items():
                        pmats[name][ph][si] = per_layer[:, pc].sum(axis=1) \
                            .astype(np.int64)
        return mats, pmats, efields


# ---------------------------------------------------------------------------
# Search front-ends
#
# ``search``/``search_many`` dispatch on ``method`` through a registry of
# pluggable front-ends.  Every front-end receives the (already
# training-expanded) networks plus the budget/grid description and returns
# per-network ``DSEResult``s:
#
#   * "grid"   — the tensorized exhaustive sweep below (the default and
#                the reference: bit-identical to ``search_reference``).
#   * "refine" — the budget-constrained local search in ``core.optimize``
#                (seeded multi-start coordinate descent with successive
#                lattice refinement down to arbitrary integer splits),
#                registered lazily on first use.
# ---------------------------------------------------------------------------

SEARCH_METHODS: Dict[str, object] = {}


def register_search_method(name: str, fn) -> None:
    """Register a search front-end under ``method=name``.  ``fn`` is
    called as ``fn(hw_base, nets, size_budget_kb, bw_budget, sizes=...,
    bws=..., tol=..., lower_bound=..., refine=..., objective=...,
    em=..., workers=...)`` and must return a ``{name: DSEResult}``
    mapping whose results are scored in the given ``Objective``.  If
    ``fn`` additionally accepts a ``backend=...`` keyword (or
    ``**kwargs``), a ``Study`` forwards its grid-evaluation backend
    (``DSE_BACKENDS``); front-ends without the parameter are called
    without it."""
    SEARCH_METHODS[name] = fn


def _grid_search_many(hw_base: HardwareSpec,
                      nets: Mapping[str, Sequence[Layer]],
                      size_budget_kb: int, bw_budget: int, *,
                      sizes: Sequence[int], bws: Sequence[int],
                      tol: float, lower_bound: bool,
                      refine=None, objective: Optional[Objective] = None,
                      em: EnergyModel = DEFAULT_ENERGY,
                      workers: int = 0,
                      backend: Optional[str] = None) -> Dict[str, DSEResult]:
    """The tensorized exhaustive front-end (``method="grid"``).

    ``backend`` picks where the grid *reductions* run (``DSE_BACKENDS``:
    ``"numpy"`` host default, ``"jax"`` on-device jit/vmap,
    ``"jax-fused"`` with best/worst through the fused Pallas kernel;
    ``None`` follows ``$REPRO_DSE_BACKEND``).  Table construction, the
    retained grids, and every ``DSEResult`` accessor are shared, and the
    backends are pinned bit-identical — same best/worst/frontier/Pareto,
    int64-exact cycles (the jax path runs under x64)."""
    if refine is not None:
        raise ValueError("refine config only applies to method='refine'")
    obj = resolve_objective(objective)
    backend = resolve_backend(backend)
    gridax = _load_gridax(backend) if backend != "numpy" else None
    lo_s = size_budget_kb * (1 - tol) if lower_bound else 0
    lo_b = bw_budget * (1 - tol) if lower_bound else 0
    size_tuples = _tuples(sizes, 4, lo_s, size_budget_kb * (1 + tol))
    bw_tuples = _tuples(bws, 4, lo_b, bw_budget * (1 + tol))
    if not size_tuples or not bw_tuples:
        raise ValueError("empty DSE space; widen grids or budgets")

    s3s, s3_of = _project(size_tuples, lambda t: t[:3])
    vs, v_of = _project(size_tuples, lambda t: t[3])
    b3s, b3_of = _project(bw_tuples, lambda t: t[:3])
    ws, w_of = _project(bw_tuples, lambda t: t[3])

    eng = _GridEngine(hw_base, nets)
    conv_mats, conv_pmats, conv_e = eng.conv_matrices(s3s, b3s,
                                                      workers=workers)
    simd_mats, simd_pmats, simd_e = eng.simd_matrices(vs, ws)
    if eng._gemm_union:
        # GEMMs share the conv separable axes (systolic-array buffers and
        # bandwidths), so fold them into the conv-side structures before
        # the grid composition — OUT-OF-PLACE: single-phase conv pmats
        # alias their totals matrix, so the originals must not mutate.
        # The phase dicts union disjoint "conv:*"/"gemm:*" keys and the
        # energy fields add per key; everything downstream (gridax, the
        # energy model, phase routing) is unchanged.
        gemm_mats, gemm_pmats, gemm_e = eng.gemm_matrices(s3s, b3s)
        conv_mats = {n: conv_mats[n] + gemm_mats[n] for n in conv_mats}
        conv_pmats = {n: {**conv_pmats[n], **gemm_pmats[n]}
                      for n in conv_pmats}
        conv_e = {n: {k: v + gemm_e[n][k] for k, v in conv_e[n].items()}
                  for n in conv_e}
    sizes_arr = np.array(size_tuples, dtype=np.int64)
    frontier_mult = 1.0 + FRONTIER_FRAC

    # On-device cycles sweeps reduce all networks in one vmapped dispatch
    # (the candidate-space projections are shared); general objectives
    # reduce per network inside the loop.
    jax_cycles = None
    if gridax is not None and type(obj) is Cycles:
        names = list(nets)
        jax_cycles = dict(zip(names, gridax.reduce_cycles_many(
            [conv_mats[n] for n in names], [simd_mats[n] for n in names],
            s3_of, b3_of, v_of, w_of, frontier_mult=frontier_mult,
            fused=(backend == "jax-fused"))))

    out: Dict[str, DSEResult] = {}
    for name in nets:
        energy = _EnergyFields(hw=hw_base, em=em, conv=conv_e[name],
                               simd=simd_e[name], s3_of=s3_of, v_of=v_of,
                               sizes_kb=sizes_arr)
        fmask = None             # flat within-FRONTIER_FRAC mask (device)
        report = None            # energy report grids, if already scored
        if type(obj) is Cycles:
            # Legacy fast path: the score IS the int64 cycle count.
            # (Exact-type check: a custom objective registered under the
            # "cycles" name still gets its score() called below.)
            scores = None
            if jax_cycles is not None:
                costs, bi, wi, fmask = jax_cycles[name]
                grid = DSEGrid(costs, size_tuples, bw_tuples)
                best = grid.point(bi)
                worst = grid.point(wi)
            else:
                costs = (conv_mats[name][np.ix_(s3_of, b3_of)]
                         + simd_mats[name][np.ix_(v_of, w_of)])
                grid = DSEGrid(costs, size_tuples, bw_tuples)
                flat = costs.ravel()
                # argmin/argmax return the first occurrence, matching the
                # legacy strict-inequality update order (size-outer,
                # bandwidth-inner).
                best = grid.point(int(flat.argmin()))
                worst = grid.point(int(flat.argmax()))
        elif gridax is not None:
            costs, scores, report, bi, wi, feasible, fmask = \
                gridax.reduce_scored(
                    conv_mats[name], simd_mats[name], s3_of, b3_of,
                    v_of, w_of, objective=obj,
                    energy_grids_fn=energy.grids,
                    frontier_mult=frontier_mult)
            if not feasible:
                raise ValueError(
                    f"objective {obj.name!r} marks every candidate "
                    f"infeasible for network {name!r}")
            grid = DSEGrid(costs, size_tuples, bw_tuples)
            best = grid.point(bi)
            worst = grid.point(wi)
        else:
            costs = (conv_mats[name][np.ix_(s3_of, b3_of)]
                     + simd_mats[name][np.ix_(v_of, w_of)])
            grid = DSEGrid(costs, size_tuples, bw_tuples)
            mb = MetricBatch(costs, lambda e=energy, c=costs: e.grids(c))
            scores = np.asarray(obj.score(mb), dtype=float)
            flat = scores.ravel()
            feasible = np.isfinite(flat)
            if not feasible.any():
                raise ValueError(
                    f"objective {obj.name!r} marks every candidate "
                    f"infeasible for network {name!r}")
            # mask BOTH extremes: a NaN score would otherwise poison
            # argmin (the worst side always masked; the best side is the
            # bugfix regression-tested in test_gridax.py)
            best = grid.point(int(np.where(feasible, flat, np.inf)
                                  .argmin()))
            worst = grid.point(int(np.where(feasible, flat, -np.inf)
                                   .argmax()))
            # reuse the report the scoring pass already computed (None
            # if the objective never pulled energy)
            report = mb._report
        phases = _PhaseGrids(conv=conv_pmats[name], simd=simd_pmats[name],
                             s3_of=s3_of, b3_of=b3_of, v_of=v_of, w_of=w_of)
        # The device backends computed the FRONTIER_FRAC mask in the same
        # dispatch as best/worst — materialize it eagerly (identical to
        # the lazy host path: same promoted comparison, same grid order);
        # they also install the vectorized Pareto mask.
        frontier = None if fmask is None else \
            [grid.point(int(i)) for i in np.nonzero(fmask)[0]]
        out[name] = DSEResult(best=best, worst=worst, grid=grid,
                              phase_grids=phases, objective=obj.name,
                              grid_scores=scores, _energy=energy,
                              _frontier=frontier,
                              _energy_grids=report,
                              _pareto_mask_fn=None if gridax is None
                              else gridax.pareto_mask)
    return out


register_search_method("grid", _grid_search_many)


def _deprecated_search_study(hw_base: HardwareSpec,
                             sizes: Sequence[int], bws: Sequence[int],
                             tol: float, lower_bound: bool):
    import warnings
    warnings.warn(
        "search()/search_many() are deprecated; build a "
        "repro.core.study.Study and call study.search(Workload(...), ...) "
        "— same results, plus objectives (energy/EDP/power caps) and "
        "parallel table builds", DeprecationWarning, stacklevel=3)
    from .study import Study
    return Study(hw_base, sizes=sizes, bws=bws, tol=tol,
                 lower_bound=lower_bound)


def search_many(hw_base: HardwareSpec, nets: Mapping[str, Sequence[Layer]],
                size_budget_kb: int, bw_budget: int,
                sizes: Sequence[int] = SIZES_KB, bws: Sequence[int] = BWS,
                tol: float = 0.15, lower_bound: bool = True,
                training: bool = False, method: str = "grid",
                refine=None) -> Dict[str, DSEResult]:
    """Deprecated: the legacy multi-network entry point, now a thin shim
    over ``repro.core.study.Study`` (which adds first-class ``Workload``
    and ``Objective`` axes — energy, EDP, power caps — on the same
    engines).  Results are bit-identical to the ``Study`` path with the
    default cycles objective; see that module for the new API.

    ``training=True`` expands each network through the Table I training
    graph; ``method`` selects the front-end (``"grid"`` exhaustive,
    ``"refine"`` local search, with ``refine=RefineConfig(...)``);
    ``lower_bound=False`` drops the lower budget bound (Fig. 11 /
    Table X landscapes)."""
    from .study import Workload
    study = _deprecated_search_study(hw_base, sizes, bws, tol, lower_bound)
    return study.search_many(
        {name: Workload(net=tuple(net), training=training)
         for name, net in nets.items()},
        size_budget_kb, bw_budget, method=method, refine=refine)


def search(hw_base: HardwareSpec, net: Sequence[Layer],
           size_budget_kb: int, bw_budget: int,
           sizes: Sequence[int] = SIZES_KB, bws: Sequence[int] = BWS,
           tol: float = 0.15, lower_bound: bool = True,
           training: bool = False, method: str = "grid",
           refine=None) -> DSEResult:
    """Deprecated: single-network shim over ``Study``; see
    ``search_many``.  The full grid is kept as an array (``result.grid``)
    by the grid front-end, the evaluation archive by refine;
    ``result.points`` materializes only the within-15% frontier either
    way."""
    from .study import Workload
    study = _deprecated_search_study(hw_base, sizes, bws, tol, lower_bound)
    return study.search(Workload(net=tuple(net), training=training),
                        size_budget_kb, bw_budget,
                        method=method, refine=refine)


def phase_profile(hw: HardwareSpec, net: Sequence[Layer],
                  training: bool = False) -> PhaseBreakdown:
    """Phase-resolved cycles of one fixed configuration, evaluated through
    the batched cost tables (cycle-identical to the scalar simulator's
    'simdit' stall model, and sharing the process-lifetime table cache
    with any DSE sweep of the same shapes)."""
    if training:
        net = expand_training_graph(list(net))
    convs = [l for l in net if isinstance(l, ConvLayer)]
    gemms = [l for l in net if isinstance(l, GemmLayer)]
    simds = [l for l in net if isinstance(l, SimdLayer)]
    cycles: Dict[str, int] = {}
    if convs:
        per_phase = get_conv_table(hw, convs).phase_cycles_batch(
            [hw.bw_w], [hw.bw_i], [hw.bw_o])
        cycles.update({f"conv:{ph}": int(v[0])
                       for ph, v in per_phase.items()})
    if gemms:
        per_phase = get_gemm_table(hw, gemms).phase_cycles_batch(
            [hw.bw_w], [hw.bw_i], [hw.bw_o])
        for ph, v in per_phase.items():
            key = f"gemm:{ph}"
            cycles[key] = cycles.get(key, 0) + int(v[0])
    if simds:
        per_phase = get_simd_table(hw, simds).phase_cycles_batch([hw.bw_v])
        cycles.update({f"simd:{ph}": int(v[0])
                       for ph, v in per_phase.items()})
    return PhaseBreakdown.from_dict(cycles)


def frontier_shift(inference: DSEResult, training: DSEResult
                   ) -> Dict[str, float]:
    """How the optimal allocation moves when the workload switches from
    inference to training (the paper's qualitative Sec. VII-B discussion):
    the SIMD side's share of the best point's SRAM and bandwidth budgets,
    and the fraction of inference-frontier allocations that survive on the
    training frontier."""
    bi, bt = inference.best, training.best
    inf_allocs = {(p.sizes_kb, p.bws) for p in inference.points}
    trn_allocs = {(p.sizes_kb, p.bws) for p in training.points}
    overlap = (len(inf_allocs & trn_allocs) / len(inf_allocs)
               if inf_allocs else 0.0)
    return {
        "vmem_share_inf": bi.sizes_kb[3] / bi.total_size_kb,
        "vmem_share_trn": bt.sizes_kb[3] / bt.total_size_kb,
        "bw_v_share_inf": bi.bws[3] / bi.total_bw,
        "bw_v_share_trn": bt.bws[3] / bt.total_bw,
        "frontier_overlap": overlap,
    }


# ---------------------------------------------------------------------------
# Brute-force reference (the pre-tensorization scalar loop, retained for
# equivalence testing and the dse_scaling micro-benchmark)
# ---------------------------------------------------------------------------

@dataclass
class ReferenceResult:
    """Legacy result shape: every evaluated point materialized."""
    best: DSEPoint
    worst: DSEPoint
    points: List[DSEPoint] = field(default_factory=list)

    @property
    def improvement(self) -> float:
        return self.worst.cycles / self.best.cycles

    def within(self, frac: float) -> List[DSEPoint]:
        lim = self.best.cycles * (1 + frac)
        return [p for p in self.points if p.cycles <= lim]

    def economic_min_sram(self, frac: float = FRONTIER_FRAC) -> DSEPoint:
        return min(self.within(frac), key=lambda p: (p.total_size_kb, p.cycles))

    def economic_min_bw(self, frac: float = FRONTIER_FRAC) -> DSEPoint:
        return min(self.within(frac),
                   key=lambda p: (p.total_bw, p.total_size_kb, p.cycles))


class _Engine:
    """Scalar per-candidate evaluator (legacy path)."""

    def __init__(self, hw_base: HardwareSpec, net: Sequence[Layer]):
        self.hw = hw_base
        self.conv_layers = tuple(l for l in net if isinstance(l, ConvLayer))
        self.gemm_layers = tuple(l for l in net if isinstance(l, GemmLayer))
        self.simd_layers = tuple(l for l in net if isinstance(l, SimdLayer))

    @lru_cache(maxsize=None)
    def _conv_table(self, wbuf_kb: int, ibuf_kb: int, obuf_kb: int) -> ConvTable:
        hw = self.hw.replace(wbuf=wbuf_kb * KB, ibuf=ibuf_kb * KB,
                             obuf=obuf_kb * KB)
        return get_conv_table(hw, self.conv_layers)

    @lru_cache(maxsize=None)
    def _gemm_table(self, wbuf_kb: int, ibuf_kb: int, obuf_kb: int) -> GemmTable:
        hw = self.hw.replace(wbuf=wbuf_kb * KB, ibuf=ibuf_kb * KB,
                             obuf=obuf_kb * KB)
        return get_gemm_table(hw, self.gemm_layers)

    @lru_cache(maxsize=None)
    def _simd_table(self, vmem_kb: int) -> SimdTable:
        return get_simd_table(self.hw.replace(vmem=vmem_kb * KB),
                              self.simd_layers)

    @lru_cache(maxsize=None)
    def conv_cycles(self, wbuf_kb: int, ibuf_kb: int, obuf_kb: int,
                    bw_w: int, bw_i: int, bw_o: int) -> int:
        return self._conv_table(wbuf_kb, ibuf_kb, obuf_kb).cycles(bw_w, bw_i, bw_o)

    @lru_cache(maxsize=None)
    def gemm_cycles(self, wbuf_kb: int, ibuf_kb: int, obuf_kb: int,
                    bw_w: int, bw_i: int, bw_o: int) -> int:
        return self._gemm_table(wbuf_kb, ibuf_kb, obuf_kb).cycles(bw_w, bw_i, bw_o)

    @lru_cache(maxsize=None)
    def simd_cycles(self, vmem_kb: int, bw_v: int) -> int:
        return self._simd_table(vmem_kb).cycles(bw_v)

    def cycles(self, sz: Tuple[int, ...], bw: Tuple[int, ...]) -> int:
        total = self.simd_cycles(sz[3], bw[3])
        if self.conv_layers:
            total += self.conv_cycles(sz[0], sz[1], sz[2],
                                      bw[0], bw[1], bw[2])
        if self.gemm_layers:
            total += self.gemm_cycles(sz[0], sz[1], sz[2],
                                      bw[0], bw[1], bw[2])
        return total


def search_reference(hw_base: HardwareSpec, net: Sequence[Layer],
                     size_budget_kb: int, bw_budget: int,
                     sizes: Sequence[int] = SIZES_KB,
                     bws: Sequence[int] = BWS,
                     tol: float = 0.15, lower_bound: bool = True,
                     collect: bool = True) -> ReferenceResult:
    """The pre-tensorization brute force: a Python double loop with one
    scalar ``cycles()`` call and one ``DSEPoint`` per candidate.  With
    ``collect=False`` only the best/worst and the within-15% frontier are
    retained (second streaming pass)."""
    eng = _Engine(hw_base, net)
    lo_s = size_budget_kb * (1 - tol) if lower_bound else 0
    lo_b = bw_budget * (1 - tol) if lower_bound else 0
    size_tuples = _tuples(sizes, 4, lo_s, size_budget_kb * (1 + tol))
    bw_tuples = _tuples(bws, 4, lo_b, bw_budget * (1 + tol))
    if not size_tuples or not bw_tuples:
        raise ValueError("empty DSE space; widen grids or budgets")

    best: Optional[DSEPoint] = None
    worst: Optional[DSEPoint] = None
    points: List[DSEPoint] = []
    for sz in size_tuples:
        for bw in bw_tuples:
            cyc = eng.cycles(sz, bw)
            if best is None or cyc < best.cycles:
                best = DSEPoint(sz, bw, cyc)
            if worst is None or cyc > worst.cycles:
                worst = DSEPoint(sz, bw, cyc)
            if collect:
                points.append(DSEPoint(sz, bw, cyc))

    if not collect:
        lim = best.cycles * (1 + FRONTIER_FRAC)
        for sz in size_tuples:
            for bw in bw_tuples:
                cyc = eng.cycles(sz, bw)
                if cyc <= lim:
                    points.append(DSEPoint(sz, bw, cyc))
    return ReferenceResult(best=best, worst=worst, points=points)


# ---------------------------------------------------------------------------
# Sensitivity (Fig. 12)
# ---------------------------------------------------------------------------

def sensitivity(hw_opt: HardwareSpec, net: Sequence[Layer],
                sizes: Sequence[int] = SIZES_KB,
                bws: Sequence[int] = BWS) -> Dict[str, Dict[int, float]]:
    """Fig. 12: vary one parameter at a time around the optimal point;
    report cycles normalized to the optimal.  (Tilings are memoized keyed
    on sizes only, so the bandwidth sweeps re-derive nothing.)"""
    from .conv_model import simulate_conv
    from .gemm_model import simulate_gemm

    def sim(hw: HardwareSpec, l: Layer):
        if isinstance(l, ConvLayer):
            return simulate_conv(hw, l)
        if isinstance(l, GemmLayer):
            return simulate_gemm(hw, l)
        return simulate_simd(hw, l)

    def cost(hw: HardwareSpec) -> int:
        return sum(sim(hw, l).total_cycles for l in net)

    base = cost(hw_opt)
    out: Dict[str, Dict[int, float]] = {}
    for param, vals, unit in (
            ("wbuf", sizes, KB), ("ibuf", sizes, KB), ("obuf", sizes, KB),
            ("vmem", sizes, KB),
            ("bw_w", bws, 1), ("bw_i", bws, 1), ("bw_o", bws, 1),
            ("bw_v", bws, 1)):
        out[param] = {}
        for v in vals:
            hw = hw_opt.replace(**{param: v * unit})
            out[param][v] = cost(hw) / base
    return out
