"""CNN workload graphs used in the paper's evaluation (Sec. VII):
ResNet-50, ResNet-18, VGG16, AlexNet, with per-layer tensor shapes matching
the standard torchvision/ONNX-Zoo topologies at 224x224 (AlexNet 227 via the
classic 11x11/4 arithmetic is normalized to the torchvision 224 variant).

Graphs are flat layer lists in execution order; residual topology is
represented by the Tensor-add layers the accelerator actually executes
(the paper models execution cost per layer, not graph routing).
"""
from __future__ import annotations

from typing import List, Union

from . import layers as L
from .layers import ConvLayer, SimdLayer, fc

Layer = Union[ConvLayer, SimdLayer]


def _conv(name: str, n: int, ic: int, ih: int, oc: int, k: int, s: int,
          pad: int, has_bias: bool) -> ConvLayer:
    oh = (ih + 2 * pad - k) // s + 1
    return ConvLayer(name=name, n=n, ic=ic, ih=ih, iw=ih, oc=oc, oh=oh, ow=oh,
                     kh=k, kw=k, s=s, has_bias=has_bias)


def _bn_relu(net: List[Layer], name: str, n: int, c: int, h: int,
             with_bn: bool = True, with_relu: bool = True) -> None:
    if with_bn:
        net.append(L.batch_norm(f"{name}.bn", h, h, n, c))
    if with_relu:
        net.append(L.relu(f"{name}.relu", h, h, n, c))


# BN is a *training-phase* layer in the paper (Sec. V-A: "inference is a
# subset of training ... In addition, it also includes a BN layer"); for
# inference BN folds into the preceding conv, so ResNet builders accept
# ``bn=False`` to emit the folded inference graph.


# ---------------------------------------------------------------------------
# ResNets
# ---------------------------------------------------------------------------

def _resnet_stem(net: List[Layer], n: int, bn: bool = True) -> int:
    net.append(_conv("stem.conv", n, 3, 224, 64, 7, 2, 3, has_bias=not bn))
    _bn_relu(net, "stem", n, 64, 112, with_bn=bn)
    net.append(L.pool("stem.maxpool", 56, 56, n, 64, r=3, s=2))
    return 56


def _bottleneck(net: List[Layer], name: str, n: int, h: int, cin: int,
                cmid: int, stride: int, bn: bool = True) -> int:
    cout = cmid * 4
    h_out = h // stride
    net.append(_conv(f"{name}.c1", n, cin, h, cmid, 1, 1, 0, has_bias=not bn))
    _bn_relu(net, f"{name}.c1", n, cmid, h, with_bn=bn)
    net.append(_conv(f"{name}.c2", n, cmid, h, cmid, 3, stride, 1, has_bias=not bn))
    _bn_relu(net, f"{name}.c2", n, cmid, h_out, with_bn=bn)
    net.append(_conv(f"{name}.c3", n, cmid, h_out, cout, 1, 1, 0, has_bias=not bn))
    _bn_relu(net, f"{name}.c3", n, cout, h_out, with_bn=bn, with_relu=False)
    if stride != 1 or cin != cout:
        net.append(_conv(f"{name}.down", n, cin, h, cout, 1, stride, 0,
                         has_bias=not bn))
        _bn_relu(net, f"{name}.down", n, cout, h_out, with_bn=bn, with_relu=False)
    net.append(L.tensor_add(f"{name}.add", h_out, h_out, n, cout))
    net.append(L.relu(f"{name}.out_relu", h_out, h_out, n, cout))
    return h_out


def _basicblock(net: List[Layer], name: str, n: int, h: int, cin: int,
                cout: int, stride: int, bn: bool = True) -> int:
    h_out = h // stride
    net.append(_conv(f"{name}.c1", n, cin, h, cout, 3, stride, 1, has_bias=not bn))
    _bn_relu(net, f"{name}.c1", n, cout, h_out, with_bn=bn)
    net.append(_conv(f"{name}.c2", n, cout, h_out, cout, 3, 1, 1, has_bias=not bn))
    _bn_relu(net, f"{name}.c2", n, cout, h_out, with_bn=bn, with_relu=False)
    if stride != 1 or cin != cout:
        net.append(_conv(f"{name}.down", n, cin, h, cout, 1, stride, 0,
                         has_bias=not bn))
        _bn_relu(net, f"{name}.down", n, cout, h_out, with_bn=bn, with_relu=False)
    net.append(L.tensor_add(f"{name}.add", h_out, h_out, n, cout))
    net.append(L.relu(f"{name}.out_relu", h_out, h_out, n, cout))
    return h_out


def resnet50(batch: int = 1, bn: bool = True) -> List[Layer]:
    n = batch
    net: List[Layer] = []
    h = _resnet_stem(net, n, bn)
    cfg = [(3, 64, 1), (4, 128, 2), (6, 256, 2), (3, 512, 2)]
    cin = 64
    for si, (blocks, cmid, stride0) in enumerate(cfg):
        for bi in range(blocks):
            stride = stride0 if bi == 0 else 1
            h = _bottleneck(net, f"s{si}.b{bi}", n, h, cin, cmid, stride, bn)
            cin = cmid * 4
    net.append(L.global_avg_pool("gap", h, h, n, cin))
    net.append(fc("fc", n, cin, 1000))
    return net


def resnet18(batch: int = 1, bn: bool = True) -> List[Layer]:
    n = batch
    net: List[Layer] = []
    h = _resnet_stem(net, n, bn)
    cfg = [(2, 64, 1), (2, 128, 2), (2, 256, 2), (2, 512, 2)]
    cin = 64
    for si, (blocks, cout, stride0) in enumerate(cfg):
        for bi in range(blocks):
            stride = stride0 if bi == 0 else 1
            h = _basicblock(net, f"s{si}.b{bi}", n, h, cin, cout, stride, bn)
            cin = cout
    net.append(L.global_avg_pool("gap", h, h, n, cin))
    net.append(fc("fc", n, cin, 1000))
    return net


# ---------------------------------------------------------------------------
# VGG16 / AlexNet (classic, no BN; biased convs)
# ---------------------------------------------------------------------------

def vgg16(batch: int = 1, bn: bool = True) -> List[Layer]:
    n = batch
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    net: List[Layer] = []
    h, cin = 224, 3
    i = 0
    for v in cfg:
        if v == "M":
            h //= 2
            net.append(L.pool(f"pool{i}", h, h, n, cin, r=2, s=2))
        else:
            net.append(_conv(f"conv{i}", n, cin, h, v, 3, 1, 1, has_bias=True))
            net.append(L.relu(f"conv{i}.relu", h, h, n, v))
            cin = v
        i += 1
    net.append(fc("fc0", n, cin * h * h, 4096))
    net.append(L.relu("fc0.relu", 1, 1, n, 4096))
    net.append(fc("fc1", n, 4096, 4096))
    net.append(L.relu("fc1.relu", 1, 1, n, 4096))
    net.append(fc("fc2", n, 4096, 1000))
    return net


def alexnet(batch: int = 1, bn: bool = True) -> List[Layer]:
    n = batch
    net: List[Layer] = []
    net.append(_conv("conv0", n, 3, 224, 64, 11, 4, 2, has_bias=True))   # 55
    net.append(L.relu("conv0.relu", 55, 55, n, 64))
    net.append(L.pool("pool0", 27, 27, n, 64, r=3, s=2))
    net.append(_conv("conv1", n, 64, 27, 192, 5, 1, 2, has_bias=True))   # 27
    net.append(L.relu("conv1.relu", 27, 27, n, 192))
    net.append(L.pool("pool1", 13, 13, n, 192, r=3, s=2))
    net.append(_conv("conv2", n, 192, 13, 384, 3, 1, 1, has_bias=True))
    net.append(L.relu("conv2.relu", 13, 13, n, 384))
    net.append(_conv("conv3", n, 384, 13, 256, 3, 1, 1, has_bias=True))
    net.append(L.relu("conv3.relu", 13, 13, n, 256))
    net.append(_conv("conv4", n, 256, 13, 256, 3, 1, 1, has_bias=True))
    net.append(L.relu("conv4.relu", 13, 13, n, 256))
    net.append(L.pool("pool2", 6, 6, n, 256, r=3, s=2))
    net.append(fc("fc0", n, 256 * 6 * 6, 4096))
    net.append(L.relu("fc0.relu", 1, 1, n, 4096))
    net.append(fc("fc1", n, 4096, 4096))
    net.append(L.relu("fc1.relu", 1, 1, n, 4096))
    net.append(fc("fc2", n, 4096, 1000))
    return net


NETWORKS = {
    "resnet50": resnet50,
    "resnet18": resnet18,
    "vgg16": vgg16,
    "alexnet": alexnet,
}
