"""Fused DSE grid-reduction Pallas kernel: outer-add + argmin/argmax.

The DSE cost grid is separable — ``costs[i, j] = conv[s3_of[i], j'] +
simd[v_of[i], j']`` after the bandwidth columns have been pre-gathered —
so the best/worst search never needs the [n_size x n_bw] grid in memory:
each grid step streams one size-row of both operand panels through VMEM,
adds them, reduces to the row min/max, and folds the result into a
4-scalar running state in SMEM.  Row gathering uses scalar prefetch
(``PrefetchScalarGridSpec``): the ``s3_of``/``v_of`` projection vectors
are prefetched to SMEM and indexed inside the ``BlockSpec`` index maps,
the same pattern a gather-GEMM uses for ragged operands.

Tie-break contract: Pallas executes the grid sequentially in row-major
order and the running update uses strict ``<`` / ``>``, so of several
equal-valued candidates the lowest flat index wins — exactly the legacy
strict-inequality (size-outer, bandwidth-inner) walk that
``core.dse._grid_search_many`` and ``search_reference`` pin.

int64 note: cycle grids are int64; the public entry wraps itself in
``enable_x64()`` (nesting inside an already-guarded caller such as
``core.gridax`` is a no-op), and interpret mode executes int64
faithfully on CPU.
Real TPU lowering of int64 is not supported, so on-device use means
int32-safe grids — the callers keep this kernel on the interpret path
off-TPU and validate it there, like every other kernel in this package.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _minmax_kernel(s3_of_ref, v_of_ref, conv_ref, simd_ref, out_ref):
    del s3_of_ref, v_of_ref            # consumed by the BlockSpec index maps
    i = pl.program_id(0)
    vals = conv_ref[0, :] + simd_ref[0, :]
    nb = vals.shape[0]
    k = jnp.argmin(vals)               # first occurrence within the row
    kx = jnp.argmax(vals)
    bv, wv = vals[k], vals[kx]
    bi, wi = i * nb + k, i * nb + kx   # flat row-major candidate indices

    @pl.when(i == 0)
    def _init():
        out_ref[0] = bv
        out_ref[1] = bi
        out_ref[2] = wv
        out_ref[3] = wi

    @pl.when(i > 0)
    def _update():
        # strict comparisons keep the earliest row on ties (first-occurrence
        # contract); value slot is written after the index slot reads it
        better = bv < out_ref[0]
        out_ref[1] = jnp.where(better, bi, out_ref[1])
        out_ref[0] = jnp.where(better, bv, out_ref[0])
        worse = wv > out_ref[2]
        out_ref[3] = jnp.where(worse, wi, out_ref[3])
        out_ref[2] = jnp.where(worse, wv, out_ref[2])


def grid_minmax_pallas(conv_rows: jax.Array, simd_rows: jax.Array,
                       s3_of: jax.Array, v_of: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """``[min, argmin, max, argmax]`` over the virtual grid
    ``conv_rows[s3_of[i], :] + simd_rows[v_of[i], :]`` (flat row-major
    indices), without materializing it.

    ``conv_rows``/``simd_rows`` are the column-pre-gathered operand
    panels ([n_size_triples x n_bw] and [n_vmem x n_bw]); ``s3_of``/
    ``v_of`` are int32 per-size-row projections into them.
    """
    with enable_x64():
        ns = s3_of.shape[0]
        nb = conv_rows.shape[1]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(ns,),
            in_specs=[pl.BlockSpec((1, nb), lambda i, s3, v: (s3[i], 0)),
                      pl.BlockSpec((1, nb), lambda i, s3, v: (v[i], 0))],
            out_specs=pl.BlockSpec((4,), lambda i, s3, v: (0,),
                                   memory_space=pltpu.SMEM),
        )
        return pl.pallas_call(
            _minmax_kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((4,), conv_rows.dtype),
            interpret=interpret,
        )(s3_of, v_of, conv_rows, simd_rows)
