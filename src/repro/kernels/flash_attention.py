"""Flash-attention Pallas kernel (causal + optional sliding window, GQA).

Online-softmax over KV blocks with running (max, denom, accumulator) in
VMEM scratch — the TPU-target twin of the pure-jnp chunked attention in
``repro.models.attention`` (which is the dry-run/CPU oracle path).  Layout:
q (BH, S, D); k/v (BKV, S, D); the BlockSpec index map folds the GQA
head->kv-head mapping (h // group) so no expanded K/V copy is ever
materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, bq: int, bk: int,
               nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, D)
    k = k_ref[0]                                   # (bk, D)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    # zero fully-masked entries explicitly (guards NEG_INF - NEG_INF = 0)
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           n_heads: int, n_kv: int,
                           causal: bool = True, window: int = 0,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = True) -> jax.Array:
    """q: (B*H, S, D); k, v: (B*KV, S, D) -> (B*H, S, D)."""
    bh, s, d = q.shape
    group = n_heads // n_kv
    bq = min(bq, s)
    bk = min(bk, s)
    pad_q = (-s) % bq
    pad_k = (-s) % bk
    qq = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kk = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vv = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    sq, sk = qq.shape[1], kk.shape[1]
    nq, nk = sq // bq, sk // bk

    def kv_index(ibh, iq, ik):
        b = ibh // n_heads
        h = ibh % n_heads
        return (b * n_kv + h // group, ik, 0)

    kern = functools.partial(
        _fa_kernel, scale=1.0 / np.sqrt(d), causal=causal, window=window,
        bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[pl.BlockSpec((1, bq, d), lambda ibh, iq, ik: (ibh, iq, 0)),
                  pl.BlockSpec((1, bk, d), kv_index),
                  pl.BlockSpec((1, bk, d), kv_index)],
        out_specs=pl.BlockSpec((1, bq, d), lambda ibh, iq, ik: (ibh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(qq, kk, vv)
    return out[:, :s]
