"""Batch-normalization Pallas kernels implementing the paper's SIMD
schedules on the VPU:

* forward: two passes (statistics, then normalize) — Sec. V-A's training
  BN with mu/psi produced for the backward pass (Fig. 10);
* backward: **Algorithm 1's two-part schedule** —
    Part-1 streams (X, dY) row blocks per channel tile, emitting Xhat and
    accumulating dgamma/dbeta in VMEM across the row sweep (the revisited
    output block = the paper's "completed tiles ... reused in Part-2");
    Part-2 streams (Xhat, dY) with the per-channel prefactor
    gamma*psi/N_eff (Eq. 28) to produce dX.

Layout: the 4D (H,W,N,C) tensor is flattened to (N_eff, C) rows — exactly
the paper's reduction of the h/w/n loops to an effective batch (Sec. V-C).
Channel tiles map to VPU lanes (the paper's t_c = K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _stats_kernel(x_ref, sum_ref, sq_ref, *, nr: int):
    ir = pl.program_id(1)

    @pl.when(ir == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    x = x_ref[...].astype(jnp.float32)
    sum_ref[...] += x.sum(0)
    sq_ref[...] += (x * x).sum(0)


def _norm_kernel(x_ref, mu_ref, psi_ref, g_ref, b_ref, y_ref):
    x = x_ref[...].astype(jnp.float32)
    y = (x - mu_ref[...]) * psi_ref[...] * g_ref[...].astype(jnp.float32) \
        + b_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def bn_forward_pallas(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                      eps: float = 1e-5, block_rows: int = 256,
                      block_c: int = 128, interpret: bool = True):
    """x: (N_eff, C) -> (y, mu, psi); psi = 1/sqrt(var + eps)."""
    n, c = x.shape
    br, bc = min(block_rows, n), min(block_c, c)
    pr, pc = (-n) % br, (-c) % bc
    xp = jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x
    nn, cc = xp.shape
    s, sq = pl.pallas_call(
        functools.partial(_stats_kernel, nr=nn // br),
        grid=(cc // bc, nn // br),
        in_specs=[pl.BlockSpec((br, bc), lambda ic, ir: (ir, ic))],
        out_specs=[pl.BlockSpec((bc,), lambda ic, ir: (ic,)),
                   pl.BlockSpec((bc,), lambda ic, ir: (ic,))],
        out_shape=[jax.ShapeDtypeStruct((cc,), jnp.float32),
                   jax.ShapeDtypeStruct((cc,), jnp.float32)],
        interpret=interpret,
    )(xp)
    mu = (s / n)[:c]
    var = (sq / n)[:c] - mu * mu
    psi = jax.lax.rsqrt(var + eps)
    mu_p = jnp.pad(mu, (0, pc)) if pc else mu
    psi_p = jnp.pad(psi, (0, pc)) if pc else psi
    g_p = jnp.pad(gamma, (0, pc)) if pc else gamma
    b_p = jnp.pad(beta, (0, pc)) if pc else beta
    y = pl.pallas_call(
        _norm_kernel,
        grid=(cc // bc, nn // br),
        in_specs=[pl.BlockSpec((br, bc), lambda ic, ir: (ir, ic)),
                  pl.BlockSpec((bc,), lambda ic, ir: (ic,)),
                  pl.BlockSpec((bc,), lambda ic, ir: (ic,)),
                  pl.BlockSpec((bc,), lambda ic, ir: (ic,)),
                  pl.BlockSpec((bc,), lambda ic, ir: (ic,))],
        out_specs=pl.BlockSpec((br, bc), lambda ic, ir: (ir, ic)),
        out_shape=jax.ShapeDtypeStruct((nn, cc), x.dtype),
        interpret=interpret,
    )(xp, mu_p, psi_p, g_p, b_p)
    return y[:n, :c], mu, psi


# ---------------------------------------------------------------------------
# Backward — Algorithm 1
# ---------------------------------------------------------------------------

def _part1_kernel(x_ref, dy_ref, mu_ref, psi_ref,
                  xhat_ref, dg_ref, db_ref):
    ir = pl.program_id(1)

    @pl.when(ir == 0)
    def _init():
        dg_ref[...] = jnp.zeros_like(dg_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    xhat = (x - mu_ref[...]) * psi_ref[...]          # Line 7 (sub, mul)
    xhat_ref[...] = xhat.astype(xhat_ref.dtype)      # Line 9 store
    dg_ref[...] += (dy * xhat).sum(0)                # Line 8 (mul, add)
    db_ref[...] += dy.sum(0)                         # Line 8 (add)


def _part2_kernel(xhat_ref, dy_ref, pref_ref, dg_ref, db_ref, dx_ref, *,
                  n_eff: float):
    xhat = xhat_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    # Eq. 28: dx = (gamma*psi/N) * (N*dy - dgamma*xhat - dbeta)
    dx = pref_ref[...] * (n_eff * dy - dg_ref[...] * xhat - db_ref[...])
    dx_ref[...] = dx.astype(dx_ref.dtype)


def bn_backward_pallas(x: jax.Array, dy: jax.Array, gamma: jax.Array,
                       mu: jax.Array, psi: jax.Array,
                       block_rows: int = 256, block_c: int = 128,
                       interpret: bool = True):
    """x, dy: (N_eff, C) -> (dx, dgamma, dbeta). Algorithm 1 schedule."""
    n, c = x.shape
    br, bc = min(block_rows, n), min(block_c, c)
    pr, pc = (-n) % br, (-c) % bc
    pad2 = lambda a: jnp.pad(a, ((0, pr), (0, pc))) if (pr or pc) else a
    pad1 = lambda a: jnp.pad(a, (0, pc)) if pc else a
    xp, dyp = pad2(x), pad2(dy)
    nn, cc = xp.shape
    grid = (cc // bc, nn // br)
    row_spec = pl.BlockSpec((br, bc), lambda ic, ir: (ir, ic))
    ch_spec = pl.BlockSpec((bc,), lambda ic, ir: (ic,))

    xhat, dg, db = pl.pallas_call(
        _part1_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, ch_spec, ch_spec],
        out_specs=[row_spec, ch_spec, ch_spec],
        out_shape=[jax.ShapeDtypeStruct((nn, cc), x.dtype),
                   jax.ShapeDtypeStruct((cc,), jnp.float32),
                   jax.ShapeDtypeStruct((cc,), jnp.float32)],
        interpret=interpret,
    )(xp, dyp, pad1(mu), pad1(psi))

    pref = pad1(gamma.astype(jnp.float32) * psi / n)   # Line 14 (mul, div)
    dx = pl.pallas_call(
        functools.partial(_part2_kernel, n_eff=float(n)),
        grid=grid,
        in_specs=[row_spec, row_spec, ch_spec, ch_spec, ch_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((nn, cc), x.dtype),
        interpret=interpret,
    )(xhat, dyp, pref, dg, db)
    return dx[:n, :c], dg[:c], db[:c]
