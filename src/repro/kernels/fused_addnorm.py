"""Fused residual-add + RMSNorm Pallas kernel — the VPU analogue of the
paper's representative non-Conv pipeline (Tensor-add, Sec. IV-E, fused with
the adjacent normalization to cut the VMem round trip the paper's
single-buffered SIMD model pays between the two ops).

Rows are blocked over the grid (the paper's (h,w,n) loops); the full model
dimension lives in one block (the paper's T_c covering C when it fits), so
the row statistics need no cross-block reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _addnorm_kernel(x_ref, r_ref, scale_ref, y_ref, res_ref, *, eps: float):
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = s.astype(res_ref.dtype)
    var = (s * s).mean(-1, keepdims=True)
    y = s * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def fused_add_rmsnorm_pallas(x: jax.Array, resid: jax.Array,
                             scale: jax.Array, eps: float = 1e-6,
                             block_rows: int = 256,
                             interpret: bool = True):
    """(x + resid) -> (rmsnorm(x+resid)*scale, x+resid). x: (rows, d)."""
    rows, d = x.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        resid = jnp.pad(resid, ((0, pad), (0, 0)))
    n = x.shape[0] // br
    kern = functools.partial(_addnorm_kernel, eps=eps)
    y, res = pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((x.shape[0], d), x.dtype),
                   jax.ShapeDtypeStruct((x.shape[0], d), x.dtype)],
        interpret=interpret,
    )(x, resid, scale)
    return y[:rows], res[:rows]
