"""Systolic GEMM Pallas kernel — the MXU analogue of the paper's
Conv/FC-as-GEMM inner-tile mapping (Sec. IV-B, Fig. 4).

Grid (m/bm, n/bn, k/bk) with the reduction axis innermost, so the f32
output block stays resident in VMEM across the k sweep (the paper's psum
accumulation in OBuf, Eq. 9: the 2*m_k - 1 psum round trips collapse to one
when the block is revisited) and the (bm, bk)/(bk, bn) operand tiles are
the paper's inner tiles with t_ic = J, t_oc = K generalized to MXU blocks.
Block shapes are chosen by ``repro.core.tpu_model.select_matmul_block`` —
the paper's tiling DSE applied to the GEMM nest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def matmul_pallas(a: jax.Array, b: jax.Array, bm: int = 256, bn: int = 256,
                  bk: int = 256, interpret: bool = True) -> jax.Array:
    """C[m,n] = A[m,k] @ B[k,n], f32 accumulation, output dtype of A."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    if m == 0 or n == 0 or k == 0:
        # Degenerate GEMM: clamping blocks to a zero dimension would zero
        # the grid divisor.  An empty reduction axis (k == 0) contracts to
        # zeros; an empty m or n yields the correctly-shaped empty matrix.
        return jnp.zeros((m, n), a.dtype)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    mm, nn, kk = a.shape[0], b.shape[1], a.shape[1]
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mm // bm, nn // bn, kk // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
                  pl.BlockSpec((bk, bn), lambda i, j, s: (s, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n].astype(a.dtype)
