"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        n_heads: int, n_kv: int, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """q: (B*H, S, D); k, v: (B*KV, S, D)."""
    bh, s, d = q.shape
    group = n_heads // n_kv
    b = bh // n_heads
    qh = q.reshape(b, n_heads, s, d)
    kh = jnp.repeat(k.reshape(b, n_kv, s, d), group, axis=1)
    vh = jnp.repeat(v.reshape(b, n_kv, s, d), group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qh, kh).astype(jnp.float32)
    logits = logits / np.sqrt(d)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= ki <= qi
    if window > 0:
        ok &= ki > qi - window
    logits = jnp.where(ok, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vh)
    return out.reshape(bh, s, d)


def fused_add_rmsnorm_ref(x: jax.Array, resid: jax.Array, scale: jax.Array,
                          eps: float = 1e-6):
    s = x.astype(jnp.float32) + resid.astype(jnp.float32)
    var = (s * s).mean(-1, keepdims=True)
    y = s * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype), s.astype(x.dtype)


def bn_forward_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                   eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(0)
    var = xf.var(0)
    psi = jax.lax.rsqrt(var + eps)
    y = (xf - mu) * psi * gamma.astype(jnp.float32) \
        + beta.astype(jnp.float32)
    return y.astype(x.dtype), mu, psi


def bn_backward_ref(x: jax.Array, dy: jax.Array, gamma: jax.Array,
                    mu: jax.Array, psi: jax.Array):
    n = x.shape[0]
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    xhat = (xf - mu) * psi                         # Eq. 25
    dgamma = (dyf * xhat).sum(0)                   # Eq. 26
    dbeta = dyf.sum(0)                             # Eq. 27
    dx = (gamma.astype(jnp.float32) * psi / n) * (
        n * dyf - dgamma * xhat - dbeta)           # Eq. 28
    return dx.astype(x.dtype), dgamma, dbeta
