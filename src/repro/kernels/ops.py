"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated against the ``ref`` oracles in
interpret mode) and False on a real TPU backend.  Block shapes for the
GEMM default to the SimDIT-TPU tile DSE (``core.tpu_model``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.tpu_model import select_matmul_block

from . import bn as _bn
from . import flash_attention as _fa
from . import fused_addnorm as _an
from . import matmul as _mm
from . import ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a, b, bm: int = 0, bn: int = 0, bk: int = 0,
           interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    if not (bm and bn and bk):
        if 0 in (a.shape[0], b.shape[1], a.shape[1]):
            # degenerate shape: the tile DSE has no valid block; any block
            # triple works because matmul_pallas short-circuits to zeros
            bm = bn = bk = 1
        else:
            blk = select_matmul_block(a.shape[0], b.shape[1], a.shape[1],
                                      bytes_in=a.dtype.itemsize)
            bm, bn, bk = blk.bm, blk.bn, blk.bk
    return _mm.matmul_pallas(a, b, bm, bn, bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "n_heads", "n_kv", "causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, n_heads: int, n_kv: int, causal: bool = True,
                    window: int = 0, bq: int = 512, bk: int = 512,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention_pallas(q, k, v, n_heads, n_kv, causal,
                                      window, bq, bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_add_rmsnorm(x, resid, scale, block_rows: int = 256,
                      interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _an.fused_add_rmsnorm_pallas(x, resid, scale,
                                        block_rows=block_rows,
                                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_c",
                                             "interpret"))
def bn_forward(x, gamma, beta, block_rows: int = 256, block_c: int = 128,
               interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _bn.bn_forward_pallas(x, gamma, beta, block_rows=block_rows,
                                 block_c=block_c, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_c",
                                             "interpret"))
def bn_backward(x, dy, gamma, mu, psi, block_rows: int = 256,
                block_c: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _bn.bn_backward_pallas(x, dy, gamma, mu, psi,
                                  block_rows=block_rows, block_c=block_c,
                                  interpret=interpret)
