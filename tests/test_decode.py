"""Serving-path equivalence: prefill + token-by-token decode must match
the full forward pass for every architecture family (attention w/ GQA +
windows, SSM recurrence, RG-LRU recurrence, MoE routing, enc-dec cross
attention, VLM patch prefix)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models.frontends import synth_frontend_inputs
from repro.models.transformer import Model

FAMILIES = ["qwen3-0.6b", "gemma3-27b", "stablelm-1.6b", "mamba2-130m",
            "recurrentgemma-9b", "granite-moe-1b-a400m",
            "llama4-maverick-400b-a17b", "whisper-tiny", "pixtral-12b"]

B, S, PRE = 2, 24, 16


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch)).replace(
        dtype=jnp.float32, remat=False,
        moe_capacity=8.0)   # no-drop capacity: decode == train numerics
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    fr = synth_frontend_inputs(cfg, B)
    logits, _, _ = model.forward(params, tokens,
                                 frames=fr.get("frames"),
                                 patches=fr.get("patches"))
    if fr.get("patches") is not None:
        logits = logits[:, fr["patches"].shape[1]:]

    last, cache = model.prefill(params, tokens[:, :PRE], max_len=S + 8,
                                frames=fr.get("frames"),
                                patches=fr.get("patches"))
    errs = [float(jnp.abs(last - logits[:, PRE - 1]).max())]
    for t in range(PRE, S):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        errs.append(float(jnp.abs(lg - logits[:, t]).max()))
    assert max(errs) < 5e-3, f"{arch}: max err {max(errs)}"


def test_chunked_attention_equals_dense():
    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype=jnp.float32,
                                                    remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 48), 0,
                                cfg.vocab_size)
    dense, _, _ = model.forward(params, tokens)
    chunked_model = Model(cfg.replace(dense_attn_max_seq=1, attn_block=16))
    chunked, _, _ = chunked_model.forward(params, tokens)
    assert float(jnp.abs(dense - chunked).max()) < 2e-4


def test_int8_kv_cache_close_to_f32():
    """Quantized KV serving (hillclimb cell 1) tracks the f32 cache within
    quantization error."""
    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype=jnp.float32,
                                                    remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    last, cache = model.prefill(params, tokens[:, :PRE], max_len=S + 8)
    m8 = Model(cfg.replace(cache_dtype=jnp.int8))
    last8, cache8 = m8.prefill(params, tokens[:, :PRE], max_len=S + 8)
    assert cache8["blk0"]["k"].dtype == jnp.int8
    # greedy argmax agreement over a few decode steps
    agree = [int((jnp.argmax(last, -1) == jnp.argmax(last8, -1)).sum())]
    for t in range(PRE, PRE + 4):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        lg8, cache8 = m8.decode_step(params, tokens[:, t:t + 1], cache8)
        agree.append(int((jnp.argmax(lg, -1) == jnp.argmax(lg8, -1)).sum()))
    assert sum(agree) >= int(0.8 * B * len(agree))


def test_windowed_equals_full_when_window_covers():
    base = reduced(get_config("smollm-360m")).replace(dtype=jnp.float32,
                                                      remat=False)
    model = Model(base)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0,
                                base.vocab_size)
    full, _, _ = model.forward(params, tokens)
    wide = Model(base.replace(window=64, attn_pattern=("local",)))
    wfull, _, _ = wide.forward(params, tokens)
    assert float(jnp.abs(full - wfull).max()) < 1e-5
    narrow = Model(base.replace(window=4, attn_pattern=("local",)))
    nout, _, _ = narrow.forward(params, tokens)
    assert float(jnp.abs(full - nout).max()) > 1e-4   # must differ
