"""Objective correctness: the batched energy/EDP/power reductions the DSE
engines score candidates with must match ``compute_energy`` applied to the
scalar simulator's outputs — for best, worst, and frontier points, grid and
refine front-ends, inference and training workloads."""
import numpy as np
import pytest

from repro.core import (EDP, Cycles, CyclesUnderPowerCap, Energy, Study,
                        Workload, resolve_objective)
from repro.core.backward import expand_training_graph
from repro.core.energy import compute_energy, compute_energy_batch
from repro.core.hardware import INFER_PRESETS, KB
from repro.core.layers import (ConvLayer, batch_norm, fc, pool, relu,
                               tensor_add)
from repro.core.objectives import MetricBatch
from repro.core.simulator import simulate_network

HW = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 100),
    ]


def tiny_train_net():
    return [
        _conv("c1", has_bias=False),
        batch_norm("c1.bn", 16, 16, 1, 32),
        relu("c1.relu", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 10),
    ]


def _study():
    return Study(HW, sizes=GRID, bws=GRID, tol=0.5)


def _materialize(point):
    """The HardwareSpec of one DSE candidate."""
    return HW.replace(
        wbuf=point.sizes_kb[0] * KB, ibuf=point.sizes_kb[1] * KB,
        obuf=point.sizes_kb[2] * KB, vmem=point.sizes_kb[3] * KB,
        bw_w=point.bws[0], bw_i=point.bws[1], bw_o=point.bws[2],
        bw_v=point.bws[3])


def _simulator_energy(net, training, point):
    layers = expand_training_graph(list(net)) if training else list(net)
    hw = _materialize(point)
    rep = simulate_network(hw, layers)
    return rep, rep.energy(hw)


# ---------------------------------------------------------------------------
# Batched energy == scalar compute_energy on simulator outputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("training", [False, True])
@pytest.mark.parametrize("method", ["grid", "refine"])
def test_batched_energy_matches_simulator(training, method):
    net = tiny_train_net() if training else tiny_net()
    res = _study().search(Workload(net=tuple(net), training=training),
                          256, 256, objective="energy", method=method)
    sample = [res.best, res.worst] + res.points[::7]
    for p in sample:
        rep, want = _simulator_energy(net, training, p)
        assert rep.total_cycles == p.cycles
        got = res.energy_report(p)
        for key in ("E_SA", "E_SIMD", "E_S", "E_D", "E_total",
                    "runtime_s", "P_avg"):
            assert np.isclose(got[key], want[key], rtol=1e-12), (key, p)
        # the objective score IS the batched E_total
        assert res.score_of(p) == got["E_total"]
    assert res.best_score <= min(res.score_of(p) for p in sample)


@pytest.mark.parametrize("training", [False, True])
def test_batched_edp_matches_simulator(training):
    net = tiny_train_net() if training else tiny_net()
    res = _study().search(Workload(net=tuple(net), training=training),
                          256, 256, objective="edp")
    for p in [res.best, res.worst] + res.points[::7]:
        _, want = _simulator_energy(net, training, p)
        assert np.isclose(res.score_of(p),
                          want["E_total"] * want["runtime_s"], rtol=1e-12)
        assert np.isclose(res.edp_of(p),
                          want["E_total"] * want["runtime_s"], rtol=1e-12)


def test_cycles_result_prices_energy_lazily():
    """Even a pure-cycles search can price any of its candidates (the
    energy tensors ride along in the cached tables), and the numbers
    match the simulator."""
    net = tiny_net()
    for method in ("grid", "refine"):
        res = _study().search(Workload(net=tuple(net)), 256, 256,
                              method=method)
        assert res.objective == "cycles"
        _, want = _simulator_energy(net, False, res.best)
        assert np.isclose(res.energy_of(), want["E_total"], rtol=1e-12)
        assert np.isclose(res.power_of(), want["P_avg"], rtol=1e-12)


def test_energy_inputs_roundtrip():
    """NetworkReport.energy_inputs feeds compute_energy exactly like
    NetworkReport.energy does."""
    rep = simulate_network(HW, tiny_net())
    assert rep.energy(HW) == compute_energy(HW, **rep.energy_inputs())


def test_compute_energy_batch_matches_scalar_elementwise():
    """The vectorized energy model is the scalar one, broadcast."""
    rng = np.random.default_rng(0)
    n = 16
    c_sa = rng.integers(1, 10**9, n)
    c_simd = rng.integers(1, 10**8, n)
    l_total = c_sa + c_simd + rng.integers(0, 10**8, n)
    bits = {b: rng.integers(0, 10**12, n)
            for b in ("wbuf", "ibuf", "obuf", "bbuf", "vmem")}
    sizes = {b: rng.integers(1, 2048, n) * KB
             for b in ("wbuf", "ibuf", "obuf", "vmem")}
    sizes["bbuf"] = HW.bbuf
    batch = compute_energy_batch(HW, c_sa=c_sa, c_simd=c_simd,
                                 l_total=l_total, sram_bits=bits,
                                 sram_sizes=sizes, dram_bits=bits["wbuf"])
    for i in range(n):
        hw = HW.replace(wbuf=int(sizes["wbuf"][i]),
                        ibuf=int(sizes["ibuf"][i]),
                        obuf=int(sizes["obuf"][i]),
                        vmem=int(sizes["vmem"][i]))
        want = compute_energy(hw, c_sa=int(c_sa[i]), c_simd=int(c_simd[i]),
                              l_total=int(l_total[i]),
                              sram_bits={b: int(v[i])
                                         for b, v in bits.items()},
                              dram_bits=int(bits["wbuf"][i]))
        for key in ("E_SA", "E_SIMD", "E_S", "E_D", "E_total", "P_avg"):
            assert np.isclose(float(batch[key][i]), want[key], rtol=1e-12)


# ---------------------------------------------------------------------------
# Power-capped search
# ---------------------------------------------------------------------------

def test_cycles_under_power_cap():
    net = tiny_net()
    st = _study()
    wl = Workload(net=tuple(net))
    free = st.search(wl, 256, 256)                 # unconstrained cycles
    # a loose cap (above the unconstrained optimum's power) changes nothing
    loose = st.search(wl, 256, 256, objective=CyclesUnderPowerCap(
        cap_w=free.power_of(free.best) * 2))
    assert loose.best == free.best
    # a binding cap: every qualifying point obeys it, and the constrained
    # optimum cannot beat the unconstrained one
    powers = [free.power_of(p) for p in free.points]
    cap = min(powers) + 0.5 * (max(powers) - min(powers))
    capped = st.search(wl, 256, 256,
                       objective=CyclesUnderPowerCap(cap_w=cap))
    assert capped.power_of(capped.best) <= cap
    assert capped.best.cycles >= free.best.cycles
    for p in capped.points:
        assert capped.power_of(p) <= cap
    # an impossible cap is an explicit error, not a silent empty result
    with pytest.raises(ValueError, match="infeasible"):
        st.search(wl, 256, 256, objective=CyclesUnderPowerCap(cap_w=1e-9))


# ---------------------------------------------------------------------------
# Objective protocol / registry
# ---------------------------------------------------------------------------

def test_resolve_objective():
    assert isinstance(resolve_objective(None), Cycles)
    assert isinstance(resolve_objective("cycles"), Cycles)
    assert isinstance(resolve_objective("energy"), Energy)
    assert isinstance(resolve_objective("edp"), EDP)
    cap = CyclesUnderPowerCap(cap_w=30.0)
    assert resolve_objective(cap) is cap
    with pytest.raises(ValueError, match="unknown objective"):
        resolve_objective("joules_per_furlong")
    with pytest.raises(ValueError, match="cap"):
        resolve_objective("cycles_under_power_cap")


def test_metric_batch_requires_energy_fn():
    mb = MetricBatch(np.array([1, 2, 3], dtype=np.int64))
    assert (Cycles().score(mb) == [1, 2, 3]).all()
    with pytest.raises(ValueError, match="needs_energy"):
        Energy().score(mb)
