"""Per-architecture smoke tests: every assigned arch instantiates at
reduced scale and runs one forward + one real optimizer step on CPU with
finite outputs and correct shapes (the FULL configs are exercised only via
the dry-run)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.launch.train import make_train_step
from repro.models.frontends import synth_frontend_inputs
from repro.models.transformer import Model
from repro.optim.optimizers import AdamW, constant_schedule

B, S = 2, 24


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch)).replace(dtype=jnp.float32,
                                                    remat=False)
            model = Model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    fr = synth_frontend_inputs(cfg, B)
    logits, _, aux = model.forward(params, tokens,
                                   frames=fr.get("frames"),
                                   patches=fr.get("patches"))
    extra = cfg.n_patches if fr.get("patches") is not None else 0
    assert logits.shape == (B, S + extra, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    opt = AdamW(schedule=constant_schedule(1e-3))
    state = {"params": params, "opt": opt.init(params)}
    step = make_train_step(model, opt, rules=None)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, **synth_frontend_inputs(cfg, B)}
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state["params"], new_state["params"])
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_defs(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    import numpy as np
    n_init = sum(int(np.prod(l.shape))
                 for l in jax.tree_util.tree_leaves(params))
    from repro.launch.roofline import count_params
    n_defs, _ = count_params(model.param_defs())
    assert n_init == n_defs
