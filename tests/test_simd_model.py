"""Unit tests for the SIMD (non-Conv) model — paper Secs. IV-E, V-C, App. A."""
import math

from repro.core import HT3
from repro.core import layers as L
from repro.core.simd_model import simulate_simd
from repro.core.tiling import SimdTiling, ceil_div, make_simd_tiling


def test_tensor_add_dram_eq20():
    """Eq. 20: A_D = V_tile * M * (2 b_in + b_out)."""
    hw = HT3
    layer = L.tensor_add("add", 56, 56, 4, 256)
    t = make_simd_tiling(hw, layer)
    st = simulate_simd(hw, layer, t)
    m = (ceil_div(56, t.T_h) * ceil_div(56, t.T_w) * ceil_div(4, t.T_n)
         * ceil_div(256, t.T_c))
    v_tile = t.T_h * t.T_w * t.T_n * t.T_c
    assert st.dram_total_bits == v_tile * m * (2 * hw.b_in + hw.b_out)
    # Sec. IV-E: SRAM access count equals the DRAM expression for Tensor-add
    assert st.sram_total_bits == v_tile * m * (2 * hw.b_in + hw.b_out)


def test_tensor_add_cycles_eq21_22():
    hw = HT3
    layer = L.tensor_add("add", 16, 16, 1, hw.K)   # single tile case
    t = SimdTiling(T_h=16, T_w=16, T_n=1, T_c=hw.K, t_c=hw.K)
    st = simulate_simd(hw, layer, t)
    # Eq. 21/22: (Th*Tw*Tn) * ceil(Tc/K) * lambda_add + PSO, one tile
    assert st.compute_cycles == 16 * 16 * 1 * hw.lam("add") + hw.pso_simd


def test_relu_op_count():
    layer = L.relu("r", 8, 8, 2, 64)
    st = simulate_simd(HT3, layer)
    assert st.ops["max"] >= 8 * 8 * 2 * 64


def test_bn_back_two_parts_and_xhat_writeback():
    """Algorithm 1: Part-1 writes Xhat back to DRAM (three 4D streams) and
    Part-2 reads it again — total 4D DRAM traffic is 6 tensors' worth."""
    hw = HT3
    layer = L.bn_back("bnb", 14, 14, 32, 256)
    st = simulate_simd(hw, layer)
    elems = layer.elems
    # >= six 4D tensor movements (X, dY, Xhat out; Xhat, dY in; dX out)
    assert st.dram_total_bits >= 6 * elems * hw.b_in
    # ... bounded by the same with ceil-padded tiles (h=w=14 pads to the
    # tile grid) + negligible 1D traffic
    assert st.dram_total_bits < 6 * 1.4 * elems * hw.b_in


def test_bn_back_op_count_eq35():
    """Eq. 35: Part-2 op count = (2 V1d + 5 V4d (mh mw mn)) mc."""
    hw = HT3
    layer = L.bn_back("bnb", 8, 8, 4, hw.K)
    t = make_simd_tiling(hw, layer)
    st = simulate_simd(hw, layer, t)
    total_ops = sum(st.ops.values())
    elems = layer.elems
    # Part-1: 5 ops / 4D elem (+4 per channel); Part-2: 5 ops / 4D elem
    # (+3 per channel after the scale/shift fold)
    assert total_ops >= 10 * elems


def test_single_buffered_stalls_positive():
    hw = HT3.replace(bw_v=32)
    layer = L.tensor_add("add", 56, 56, 8, 256)
    st = simulate_simd(hw, layer)
    assert st.stall_cycles > 0
    hi = simulate_simd(HT3.replace(bw_v=4096), layer)
    assert hi.stall_cycles < st.stall_cycles


def test_pool_and_backward():
    fwd = L.pool("p", 28, 28, 4, 128, r=3, s=2)
    bwd = L.pool_back("pb", 28, 28, 4, 128, r=3, s=2, mode="max")
    sf = simulate_simd(HT3, fwd)
    sb = simulate_simd(HT3, bwd)
    assert sf.total_cycles > 0 and sb.total_cycles > 0
    # backward writes the (larger) input-sized gradient
    assert sb.dram_total_bits > sf.dram_total_bits / 2


def test_param_update_cost_scales_with_numel():
    small = simulate_simd(HT3, L.param_update("u1", 10_000, 4))
    big = simulate_simd(HT3, L.param_update("u2", 1_000_000, 4))
    assert big.total_cycles > small.total_cycles
    assert big.ops["mul"] >= 1_000_000
