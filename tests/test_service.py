"""repro.serve: the DSE-as-a-service subsystem.

The load-bearing contract is equivalence: every result a ``DSEService``
hands back — through any amount of micro-batching, grouping, dedup, and
degraded serial retry — is bit-identical to a direct synchronous
``Study.search`` of the same request.  On top of that this file pins the
service-specific behaviors: coalescing actually saves table builds over
sequential cold queries, identical in-flight requests share one pricing,
admission control bounds the queue, a poisoned request fails alone with
a structured error while its batchmates complete, and the
``service_batch_exc``/``service_request_hang`` fault points degrade a
grouped dispatch to per-request serial evaluation instead of dropping
the batch."""
import threading

import numpy as np
import pytest

from repro.core import INFER_PRESETS, Study, Workload, faultinject
from repro.core.dse import clear_table_caches, table_cache_stats
from repro.core.layers import ConvLayer, batch_norm, fc, pool, relu
from repro.core.store import TableStore, clear_default_store
from repro.serve import (AdmissionError, DSEClient, DSERequest, DSEService,
                         InvalidRequest, RequestFailed, RequestTimeout,
                         ServiceError)

HW16 = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        fc("fc", 1, 2048, 100),
    ]


def tiny_train_net():
    return [
        _conv("c1", has_bias=False),
        batch_norm("c1.bn", 16, 16, 1, 32),
        relu("c1.relu", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32),
        fc("fc", 1, 2048, 10),
    ]


@pytest.fixture(autouse=True)
def _clean():
    faultinject.reset()
    clear_default_store()
    clear_table_caches()
    yield
    faultinject.reset()
    clear_default_store()
    clear_table_caches()


def _study(**kw):
    kw.setdefault("store", None)
    return Study(HW16, sizes=GRID, bws=GRID, tol=0.5, **kw)


def _same_result(a, b):
    """Bit-identity between two grid DSEResults: same optimum AND the
    same full cost surface (not just the argmin)."""
    assert a.best == b.best
    assert a.worst == b.worst
    assert np.array_equal(a.grid.costs, b.grid.costs)


# ---- acceptance: concurrent mixed burst ------------------------------------

def test_concurrent_burst_bit_identical_coalesced_clean_store(tmp_path):
    """The PR's acceptance scenario: 8 mixed queries (2+ networks x 2
    budgets x 3 objectives, inference AND training) submitted from 4
    client threads, served coalesced off a shared store — every response
    bit-identical to a fresh synchronous ``Study.search``, measured
    coalescing ratio > 1, and zero quarantine debris in the store."""
    store_root = tmp_path / "store"
    train_wl = Workload(net=tuple(tiny_train_net()), training=True,
                        name="tiny-train")
    reqs = [
        DSERequest("resnet18", 512, 256, objective="cycles"),
        DSERequest("resnet18", 256, 256, objective="edp"),
        DSERequest("alexnet", 512, 256, objective="edp"),
        DSERequest("alexnet", 256, 256, objective="cycles"),
        DSERequest(train_wl, 512, 256, objective="cycles"),
        DSERequest(train_wl, 256, 256, objective="edp"),
        DSERequest("resnet18", 512, 256, objective="energy"),
        DSERequest("alexnet", 512, 256, objective="cycles"),
    ]
    svc = DSEService(_study(store=str(store_root)), autostart=False,
                     max_batch=len(reqs))
    client = DSEClient(svc)
    tickets = [None] * len(reqs)
    barrier = threading.Barrier(4)

    def submitter(tid):
        barrier.wait()
        for i in range(tid, len(reqs), 4):
            tickets[i] = client.submit(reqs[i])

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.start()                       # whole burst lands in one drain
    results = [t.result(timeout=600) for t in tickets]
    svc.close()

    st = svc.stats()
    assert st.submitted == len(reqs) and st.completed == len(reqs)
    assert st.failed == 0 and st.degraded_batches == 0
    # grouping happened: 5 distinct (budget, objective) groups priced 8
    # requests, so strictly fewer searches than requests
    assert st.searches < len(reqs)
    assert st.coalescing_ratio > 1.0
    assert st.batch_occupancy > 1.0

    # every answer == a direct synchronous search on a fresh Study over
    # the same store (bit-identical, not approximately equal)
    ref = _study(store=str(store_root))
    for req, res in zip(reqs, results):
        _same_result(res, ref.search(req.workload, req.size_budget_kb,
                                     req.bw_budget,
                                     objective=req.objective))

    # the shared store ended clean: entries present, nothing quarantined
    store = TableStore(store_root)
    assert len(list(store.entries())) > 0
    assert not (store.quarantine_dir.exists()
                and list(store.quarantine_dir.iterdir()))
    assert not list(store_root.glob(".tmp-*"))


def test_coalescing_builds_fewer_tables_than_sequential_cold():
    """The economic claim behind the service: a coalesced burst builds
    strictly fewer cost tables than the same queries issued as isolated
    cold searches (no store, caches cleared between sequential runs)."""
    wl = Workload(net=tuple(tiny_net()), name="tiny")
    reqs = [DSERequest(wl, 512, 256, objective="cycles"),
            DSERequest("alexnet", 512, 256, objective="cycles"),
            DSERequest(wl, 512, 256, objective="edp"),
            DSERequest("alexnet", 256, 256, objective="cycles")]

    def builds():
        s = table_cache_stats()
        return sum(int(s[f"{k}_builds"]) for k in ("conv", "simd", "gemm"))

    sequential = 0
    for r in reqs:
        clear_table_caches()
        _study().search(r.workload, r.size_budget_kb, r.bw_budget,
                        objective=r.objective)
        sequential += builds()

    clear_table_caches()
    with DSEService(_study(), autostart=False,
                    max_batch=len(reqs)) as svc:
        tickets = DSEClient(svc).submit_burst(reqs)
        svc.start()
        for t in tickets:
            t.result(timeout=600)
    coalesced = builds()
    assert coalesced < sequential, (coalesced, sequential)


# ---- dedup / admission ------------------------------------------------------

def test_identical_inflight_requests_share_one_result():
    wl = Workload(net=tuple(tiny_net()), name="tiny")
    svc = DSEService(_study(), autostart=False)
    a = svc.submit(wl, 512, 256)
    b = svc.submit(wl, 512, 256)                   # dedup: rides a's future
    c = svc.submit(wl, 256, 256)                   # different budget: new
    svc.start()
    ra, rb, rc = (t.result(timeout=600) for t in (a, b, c))
    svc.close()
    assert ra is rb                                # the SAME object, shared
    assert rc is not ra
    st = svc.stats()
    assert st.dedup_hits == 1
    assert st.submitted == 3 and st.completed == 2
    assert st.priced_requests == 2


def test_admission_control_bounds_pending_and_rejects_after_close():
    wl = Workload(net=tuple(tiny_net()), name="tiny")
    svc = DSEService(_study(), autostart=False, max_pending=2)
    svc.submit(wl, 512, 256)
    svc.submit(wl, 256, 256)
    with pytest.raises(AdmissionError) as exc:
        svc.submit(wl, 128, 256)
    assert exc.value.kind == "rejected"
    assert svc.stats().rejected == 1
    svc.close(drain=False)
    with pytest.raises(AdmissionError):
        svc.submit(wl, 512, 256)


# ---- graceful degradation ---------------------------------------------------

def test_poisoned_request_fails_alone():
    """An unresolvable workload and an infeasible budget each fail with
    a structured error on their own future; healthy batchmates complete
    with results bit-identical to a direct search."""
    svc = DSEService(_study(), autostart=False)
    client = DSEClient(svc)
    bad_net = client.submit("no_such_net", 512, 256)
    # far below the smallest lattice point: the grid front-end raises
    bad_budget = client.submit(Workload(net=tuple(tiny_net())), 1, 256)
    good = client.submit("alexnet", 512, 256)
    svc.start()
    res = good.result(timeout=600)
    e_net = bad_net.exception(timeout=600)
    e_budget = bad_budget.exception(timeout=600)
    svc.close()
    assert isinstance(e_net, InvalidRequest) and e_net.kind == "invalid"
    assert "no_such_net" in str(e_net)
    assert isinstance(e_budget, ServiceError)
    assert e_budget.kind in ("error",) and e_budget.__cause__ is not None
    _same_result(res, _study().search("alexnet", 512, 256))
    st = svc.stats()
    assert st.completed == 1 and st.failed == 2 and st.timeouts == 0


def test_batch_exception_degrades_to_serial_not_dropped():
    """An injected dispatcher batch exception (``service_batch_exc``)
    must degrade the group to per-request serial pricing: every request
    still completes, bit-identical, and the fault is accounted."""
    faultinject.arm("service_batch_exc", times=1)
    wl = Workload(net=tuple(tiny_net()), name="tiny")
    svc = DSEService(_study(), autostart=False)
    tickets = DSEClient(svc).submit_burst(
        [DSERequest(wl, 512, 256), DSERequest("alexnet", 512, 256)])
    svc.start()
    results = [t.result(timeout=600) for t in tickets]
    svc.close()
    assert faultinject.fired("service_batch_exc") == 1
    st = svc.stats()
    assert st.degraded_batches == 1
    assert st.completed == 2 and st.failed == 0
    ref = _study()
    _same_result(results[0], ref.search(wl, 512, 256))
    _same_result(results[1], ref.search("alexnet", 512, 256))


def test_hang_watchdog_isolates_the_hung_request():
    """``service_request_hang`` armed twice: the grouped dispatch hangs
    (watchdog trips -> degraded serial), then the first serial pricing
    hangs too and times out ALONE — its batchmate still completes."""
    faultinject.arm("service_request_hang", times=2, arg=30)
    wl = Workload(net=tuple(tiny_net()), name="tiny")
    svc = DSEService(_study(), autostart=False, batch_timeout_s=0.5)
    tickets = DSEClient(svc).submit_burst(
        [DSERequest(wl, 512, 256, tag="hangs"),
         DSERequest("alexnet", 512, 256, tag="survives")])
    svc.start()
    err = tickets[0].exception(timeout=600)
    res = tickets[1].result(timeout=600)
    svc.close()
    assert isinstance(err, RequestTimeout) and err.kind == "timeout"
    assert err.request.tag == "hangs"
    st = svc.stats()
    assert st.degraded_batches == 1
    assert st.timeouts == 1 and st.completed == 1
    _same_result(res, _study().search("alexnet", 512, 256))


def test_expired_in_queue_times_out_without_pricing():
    wl = Workload(net=tuple(tiny_net()), name="tiny")
    svc = DSEService(_study(), autostart=False)
    t = svc.submit(wl, 512, 256, timeout_s=0.01)
    import time
    time.sleep(0.05)                  # deadline passes while queued
    svc.start()
    err = t.exception(timeout=60)
    svc.close()
    assert isinstance(err, RequestTimeout)
    st = svc.stats()
    assert st.timeouts == 1 and st.searches == 0


# ---- client surface ---------------------------------------------------------

def test_query_burst_returns_errors_in_place():
    wl = Workload(net=tuple(tiny_net()), name="tiny")
    with DSEService(_study(), coalesce_window_s=0.05) as svc:
        out = DSEClient(svc).query_burst(
            [DSERequest(wl, 512, 256),
             DSERequest("no_such_net", 512, 256)],
            return_errors=True)
    assert not isinstance(out[0], ServiceError)
    assert isinstance(out[1], InvalidRequest)
    _same_result(out[0], _study().search(wl, 512, 256))


def test_sync_query_matches_direct_search():
    with DSEService(_study()) as svc:
        res = DSEClient(svc).query("alexnet", 512, 256, objective="edp")
    _same_result(res, _study().search("alexnet", 512, 256,
                                      objective="edp"))
