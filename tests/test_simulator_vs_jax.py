"""Cross-validation: SimDIT's analytic op counts equal the *actual* FLOPs
of the same layers executed by JAX (counted by the jaxpr walker) — the
simulator's arithmetic model is grounded in the real framework."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.layers import ConvLayer, fc
from repro.launch.costmodel import jaxpr_cost


@pytest.mark.parametrize("n,ic,hw_in,oc,k,s", [
    (2, 16, 32, 24, 3, 1),
    (1, 3, 224, 64, 7, 2),
    (4, 64, 14, 128, 1, 1),
])
def test_conv_macs_match_jax(n, ic, hw_in, oc, k, s):
    oh = (hw_in - k) // s + 1
    layer = ConvLayer(name="c", n=n, ic=ic, ih=hw_in, iw=hw_in, oc=oc,
                      oh=oh, ow=oh, kh=k, kw=k, s=s, has_bias=False)
    x = jax.ShapeDtypeStruct((n, ic, hw_in, hw_in), jnp.float32)
    w = jax.ShapeDtypeStruct((oc, ic, k, k), jnp.float32)

    def conv(x, w):
        return jax.lax.conv_general_dilated(x, w, (s, s), "VALID")

    c = jaxpr_cost(conv, x, w)
    assert c.flops == 2 * layer.macs


def test_fc_macs_match_jax():
    layer = fc("f", 8, 512, 1000, has_bias=False)
    x = jax.ShapeDtypeStruct((8, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 1000), jnp.float32)
    c = jaxpr_cost(lambda x, w: x @ w, x, w)
    assert c.flops == 2 * layer.macs


def test_backward_conv_macs_match_autodiff():
    """The Table V-transformed backward convs' MAC counts equal the real
    gradient computation's dot FLOPs (within the transformation's
    zero-padding overcount: dilation/padding zeros are multiplied by the
    systolic array but not by XLA's direct grad conv)."""
    from repro.core.backward import dw_conv, dx_conv

    n, ic, hw_in, oc, k = 2, 8, 16, 12, 3
    oh = hw_in - k + 1
    f = ConvLayer(name="f", n=n, ic=ic, ih=hw_in, iw=hw_in, oc=oc, oh=oh,
                  ow=oh, kh=k, kw=k, s=1, has_bias=False)
    x = jax.ShapeDtypeStruct((n, ic, hw_in, hw_in), jnp.float32)
    w = jax.ShapeDtypeStruct((oc, ic, k, k), jnp.float32)

    def loss(x, w):
        return jax.lax.conv_general_dilated(x, w, (1, 1), "VALID").sum()

    g = jaxpr_cost(jax.grad(loss, argnums=(0, 1)), x, w)
    # jax.grad linearizes: primal forward + dX conv + dW conv — the exact
    # identity against the Table V-transformed layers (stride 1: the
    # transformation introduces no dilation zeros)
    analytic = 2 * (f.macs + dx_conv(f).macs + dw_conv(f).macs)
    # exact on the conv dots; the walker additionally counts the sum's
    # cotangent broadcast (a few K elementwise flops)
    assert abs(g.flops - analytic) / analytic < 0.005
