"""The crash-safe persistent table store (``repro.core.store``).

Pins the durability contract: content-addressed atomic writes round-trip
bit-identically, corruption/truncation quarantines and rebuilds (never a
crash), the store is inert unless explicitly enabled, bad configuration
warns instead of silently disabling, eviction respects the size cap, and
a warm store lets a *fresh process* run a full sweep rebuilding zero
tables with results bit-identical to the no-store path."""
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core import INFER_PRESETS
from repro.core.dse import clear_table_caches, table_cache_stats
from repro.core.layers import ConvLayer, fc, pool, relu
from repro.core.store import (TableStore, active_store, clear_default_store,
                              reset_store_stats, set_default_store,
                              store_context, store_stats)
from repro.core.study import Study, Workload

HW = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        fc("fc", 1, 2048, 100),
    ]


@pytest.fixture(autouse=True)
def _clean_store_state():
    clear_default_store()
    clear_table_caches()
    yield
    clear_default_store()
    clear_table_caches()


def _study(**kw):
    return Study(HW, sizes=GRID, bws=GRID, tol=0.5, **kw)


def _sweep(**kw):
    return _study(**kw).search(Workload(net=tuple(tiny_net())), 256, 256)


# ---- raw store semantics ---------------------------------------------------

def test_roundtrip_bit_identical(tmp_path):
    store = TableStore(tmp_path)
    key = (("hw", 1, 2), (("layer", 3), "fwd"))
    obj = {"a": np.arange(7, dtype=np.int64), "b": (1, 2.5, "x")}
    store.save("conv", key, obj)
    assert store.contains("conv", key)
    back = store.load("conv", key, dict)
    assert back["b"] == obj["b"]
    assert (back["a"] == obj["a"]).all()
    assert back["a"].dtype == obj["a"].dtype


def test_miss_and_type_guard(tmp_path):
    store = TableStore(tmp_path)
    reset_store_stats()
    assert store.load("conv", ("nope",)) is None
    assert store_stats()["store_misses"] == 1
    store.save("conv", ("k",), [1, 2])
    # wrong expected type quarantines rather than returning garbage
    assert store.load("conv", ("k",), dict) is None
    assert store_stats()["store_corrupt"] == 1


@pytest.mark.parametrize("damage", ["flip", "truncate", "empty"])
def test_corruption_quarantines_not_crashes(tmp_path, damage):
    store = TableStore(tmp_path)
    key = (("hw",), ("l1",))
    store.save("conv", key, list(range(100)))
    path = store.entry_path("conv", key)
    blob = path.read_bytes()
    if damage == "flip":
        i = len(blob) // 2
        path.write_bytes(blob[:i] + bytes([blob[i] ^ 0xFF]) + blob[i + 1:])
    elif damage == "truncate":
        path.write_bytes(blob[:len(blob) // 2])
    else:
        path.write_bytes(b"")
    reset_store_stats()
    assert store.load("conv", key, list) is None
    assert store_stats()["store_corrupt"] == 1
    assert not path.exists()                       # quarantined away
    assert list(store.quarantine_dir.iterdir())
    # a rebuild + save restores service
    store.save("conv", key, list(range(100)))
    assert store.load("conv", key, list) == list(range(100))


def test_key_mismatch_is_corruption(tmp_path):
    """A file renamed onto another key's address must not be served."""
    store = TableStore(tmp_path)
    store.save("conv", ("k1",), "v1")
    store.save("conv", ("k2",), "v2")
    os.replace(store.entry_path("conv", ("k1",)),
               store.entry_path("conv", ("k2",)))
    assert store.load("conv", ("k2",), str) is None
    assert store_stats()["store_corrupt"] >= 1


def test_eviction_respects_cap(tmp_path):
    store = TableStore(tmp_path, cap_bytes=1)       # everything over cap
    reset_store_stats()
    for i in range(5):
        store.save("conv", (f"k{i}",), b"x" * 256)
    assert store.total_bytes() <= 1                 # cap enforced
    assert store_stats()["store_evicted"] == 5


def test_lru_evicts_oldest_first(tmp_path):
    store = TableStore(tmp_path, cap_bytes=10 ** 9)
    for i in range(4):
        store.save("conv", (f"k{i}",), b"x" * 100)
        os.utime(store.entry_path("conv", (f"k{i}",)), (i, i))
    store.load("conv", ("k0",), bytes)        # refresh k0's recency
    store.cap_bytes = 250                      # room for ~2 entries
    store._evict_to_cap()
    assert store.contains("conv", ("k0",))     # recently used: kept
    assert not store.contains("conv", ("k1",))  # oldest untouched: evicted


# ---- activation rules ------------------------------------------------------

def test_disabled_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_TABLE_STORE", raising=False)
    assert active_store() is None


def test_env_and_override_precedence(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TABLE_STORE", str(tmp_path / "env"))
    assert active_store() is not None
    assert active_store().root == tmp_path / "env"
    with store_context(None):                  # explicit off beats env
        assert active_store() is None
    override = TableStore(tmp_path / "override")
    set_default_store(override)
    assert active_store() is override
    clear_default_store()
    assert active_store().root == tmp_path / "env"


def test_bad_env_path_warns_once(tmp_path, monkeypatch):
    bad = tmp_path / "file-not-dir"
    bad.write_text("not a directory")
    monkeypatch.setenv("REPRO_TABLE_STORE", str(bad))
    with pytest.warns(RuntimeWarning, match="REPRO_TABLE_STORE"):
        assert active_store() is None
    with warnings.catch_warnings():            # second resolution: silent
        warnings.simplefilter("error")
        assert active_store() is None


def test_bad_cap_env_warns(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TABLE_STORE_CAP_MB", "huge")
    with pytest.warns(RuntimeWarning, match="REPRO_TABLE_STORE_CAP_MB"):
        store = TableStore(tmp_path)
    assert store.cap_bytes == 2048 * 1024 * 1024


# ---- end-to-end through the DSE engine -------------------------------------

def test_store_sweep_bit_identical_and_warm(tmp_path):
    baseline = _sweep()                         # no store

    clear_table_caches()
    cold = _sweep(store=tmp_path / "store")
    st = table_cache_stats()
    assert (cold.grid.costs == baseline.grid.costs).all()
    assert cold.best == baseline.best
    assert st["store_hits"] == 0
    assert st["store_writes"] == st["conv_builds"] + st["simd_builds"] > 0

    clear_table_caches()                        # drop L1, keep the store
    warm = _sweep(store=tmp_path / "store")
    st = table_cache_stats()
    assert (warm.grid.costs == baseline.grid.costs).all()
    assert warm.best == baseline.best
    assert st["conv_builds"] == 0 and st["simd_builds"] == 0
    assert st["store_misses"] == 0 and st["store_hits"] > 0


def test_legacy_counters_identical_with_store(tmp_path):
    """The L1 counter stream (conv_hits/conv_misses/...) is the pinned
    public story; seeding L1 from the store must not change it."""
    _sweep()
    plain = {k: v for k, v in table_cache_stats().items()
             if k in ("conv_hits", "conv_misses", "simd_hits",
                      "simd_misses", "conv_tilings_derived")}
    clear_table_caches()
    _sweep(store=tmp_path / "s")
    clear_table_caches()
    _sweep(store=tmp_path / "s")                # warm: loads, not builds
    stored = {k: v for k, v in table_cache_stats().items() if k in plain}
    assert stored == plain


def test_corrupt_store_entry_recovers_through_sweep(tmp_path):
    store = TableStore(tmp_path)
    _sweep(store=store)
    victim = sorted(store.entries())[0]
    blob = victim.read_bytes()
    victim.write_bytes(blob[:40] + b"\x00garbage\x00" + blob[48:])
    clear_table_caches()
    res = _sweep(store=store)
    st = table_cache_stats()
    assert st["store_corrupt"] == 1
    baseline = _sweep()                        # fresh no-store reference
    assert (res.grid.costs == baseline.grid.costs).all()
    # the rebuilt entry was re-persisted: next run is fully warm again
    clear_table_caches()
    _sweep(store=store)
    st = table_cache_stats()
    assert st["store_corrupt"] == 0 and st["conv_builds"] == 0


def test_warm_store_fresh_process_rebuilds_zero(tmp_path):
    """Acceptance pin: a Table VIII style sweep in a *fresh process* over
    a warm store rebuilds zero tables and matches bit-identically."""
    res = _sweep(store=tmp_path / "store")
    want = [int(res.best.cycles), res.grid.costs.sum().item()]

    code = f"""
import json, sys
from repro.core import INFER_PRESETS
from repro.core.study import Study, Workload
from repro.core.dse import table_cache_stats
from repro.core.layers import ConvLayer, fc, pool, relu

def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)

net = [_conv("c1"), relu("r1", 16, 16, 1, 32),
       _conv("c2", ic=32, oc=32, has_bias=False),
       pool("p1", 8, 8, 1, 32, 2, 2), fc("fc", 1, 2048, 100)]
res = Study(INFER_PRESETS[16], sizes=(32, 64, 128, 256),
            bws=(32, 64, 128, 256), tol=0.5,
            store={str(tmp_path / "store")!r}) \\
    .search(Workload(net=tuple(net)), 256, 256)
st = table_cache_stats()
assert st["conv_builds"] == 0 and st["simd_builds"] == 0, st
assert st["store_misses"] == 0 and st["store_hits"] > 0, st
print(json.dumps([int(res.best.cycles), res.grid.costs.sum().item()]))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={**os.environ, "PYTHONPATH": "src"},
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr
    import json
    assert json.loads(out.stdout.strip().splitlines()[-1]) == want
