"""The objective-first Study/Workload API.

Pins the redesign's contracts: the legacy ``search``/``search_many``
shims warn and stay bit-identical to the ``Study`` path on the Table VIII
fixtures; ``objective="energy"`` with ``method="refine"`` is never worse
than the exhaustive power-of-two grid optimum on every Table VIII budget
(inference and training — the energy mirror of the PR 3 cycles
guarantee); the 2-D (cycles, energy) Pareto frontier contains both
single-metric optima; parallel table builds are bit-identical to serial;
and a cross-objective sweep rebuilds no tables."""
import warnings

import pytest

from repro.core import INFER_PRESETS, TRAIN_PRESETS, Study, Workload
from repro.core.backward import expand_training_graph
from repro.core.dse import (clear_table_caches, search, search_many,
                            table_cache_stats)
from repro.core.layers import (ConvLayer, batch_norm, fc, pool, relu,
                               tensor_add)
from repro.core.networks import NETWORKS, resnet50
from repro.core.study import as_workload, default_workers

BUDGETS = {16: 512, 32: 1024, 64: 2048, 128: 4096}   # Table VIII
HW16 = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 100),
    ]


def tiny_train_net():
    return [
        _conv("c1", has_bias=False),
        batch_norm("c1.bn", 16, 16, 1, 32),
        relu("c1.relu", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 10),
    ]


def _hw(presets, jk):
    return presets.get(jk, presets[64]).replace(J=jk, K=jk)


def _assert_same_result(a, b):
    assert a.best == b.best
    assert a.worst == b.worst
    assert a.objective == b.objective
    assert a.points == b.points
    if a.refine is not None or b.refine is not None:
        assert a.refine.trajectory == b.refine.trajectory
        assert a.archive == b.archive


# ---------------------------------------------------------------------------
# Acceptance: energy-objective refine never worse than the exhaustive
# power-of-two grid optimum, every Table VIII budget, inference + training
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def table8_energy():
    """Grid + refine energy results for every Table VIII budget,
    ResNet-50 inference and training."""
    out = {}
    for mode, presets, training in (("inference", INFER_PRESETS, False),
                                    ("training", TRAIN_PRESETS, True)):
        wl = Workload("resnet50", training=training)
        for jk, budget in BUDGETS.items():
            study = Study(_hw(presets, jk))
            g = study.search(wl, budget, budget, objective="energy")
            r = study.search(wl, budget, budget, objective="energy",
                             method="refine")
            out[(mode, jk)] = (budget, g, r)
    return out


@pytest.mark.parametrize("mode", ["inference", "training"])
@pytest.mark.parametrize("jk", [16, 32, 64, 128])
def test_energy_refine_never_worse_than_grid(table8_energy, mode, jk):
    budget, g, r = table8_energy[(mode, jk)]
    assert r.objective == g.objective == "energy"
    assert r.best_score <= g.best_score
    assert r.refine.eval_saving >= 10.0
    lo, hi = budget * 0.85, budget * 1.15
    assert lo <= r.best.total_size_kb <= hi
    assert lo <= r.best.total_bw <= hi


def test_energy_refine_beats_lattice_somewhere(table8_energy):
    """The off-lattice granularity must pay for energy too."""
    assert any(r.best_score < g.best_score
               for _, g, r in table8_energy.values())


def test_energy_optimum_differs_from_cycles_optimum(table8_energy):
    """The new metric axis is not a relabeling: on at least one Table VIII
    fixture the min-energy allocation is a different configuration than
    the min-cycles one (SRAM access cost pulls toward smaller buffers)."""
    diffs = 0
    for (mode, jk), (budget, g, _) in table8_energy.items():
        presets = INFER_PRESETS if mode == "inference" else TRAIN_PRESETS
        wl = Workload("resnet50", training=(mode == "training"))
        c = Study(_hw(presets, jk)).search(wl, budget, budget)
        assert g.best.cycles >= c.best.cycles   # cycles at min-energy point
        assert c.energy_of(c.best) >= g.best_score
        if (g.best.sizes_kb, g.best.bws) != (c.best.sizes_kb, c.best.bws):
            diffs += 1
    assert diffs > 0


def test_pareto_contains_both_optima(table8_energy):
    """Acceptance: the 2-D (cycles, energy) Pareto frontier on ResNet-50
    inference contains the min-cycles and the min-energy grid points."""
    budget, g_energy, _ = table8_energy[("inference", 16)]
    study = Study(_hw(INFER_PRESETS, 16))
    res = study.search(Workload("resnet50"), budget, budget)
    front = res.pareto()
    assert res.best in front                       # min-cycles point
    assert g_energy.best in front                  # min-energy point
    # frontier points are mutually non-dominated
    pairs = [(p.cycles, res.energy_of(p)) for p in front]
    for i, (c1, e1) in enumerate(pairs):
        for j, (c2, e2) in enumerate(pairs):
            if i != j:
                assert not (c2 <= c1 and e2 <= e1
                            and (c2 < c1 or e2 < e1))


# ---------------------------------------------------------------------------
# Deprecation shims: warn + bit-identical to the Study path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("training", [False, True])
@pytest.mark.parametrize("method", ["grid", "refine"])
def test_search_shim_warns_and_matches_study(training, method):
    """The old ``search(hw, net, training=..., method=...)`` signature on
    the Table VIII 16x16 fixtures: DeprecationWarning + results
    bit-identical to the explicit Study/Workload path."""
    presets = TRAIN_PRESETS if training else INFER_PRESETS
    hw = _hw(presets, 16)
    net = resnet50(32, bn=True) if training else resnet50(1, bn=False)
    with pytest.warns(DeprecationWarning, match="Study"):
        old = search(hw, net, 512, 512, training=training, method=method)
    new = Study(hw).search(Workload(net=tuple(net), training=training),
                           512, 512, method=method)
    _assert_same_result(old, new)


def test_search_many_shim_warns_and_matches_study():
    nets = {"a": tiny_net(), "b": tiny_train_net()}
    with pytest.warns(DeprecationWarning):
        old = search_many(HW16, nets, 256, 256, sizes=GRID, bws=GRID,
                          tol=0.5)
    new = Study(HW16, sizes=GRID, bws=GRID, tol=0.5).search_many(
        {k: Workload(net=tuple(v)) for k, v in nets.items()}, 256, 256)
    for key in nets:
        _assert_same_result(old[key], new[key])


# ---------------------------------------------------------------------------
# Workload semantics
# ---------------------------------------------------------------------------

def test_workload_named_network_defaults():
    """Named networks follow simulate()'s conventions: inference batch 1
    BN-folded, training batch 32 with BN + Table I expansion."""
    inf = Workload("resnet50").layers()
    assert inf == resnet50(1, bn=False)
    trn = Workload("resnet50", training=True).layers()
    assert trn == expand_training_graph(resnet50(32, bn=True))
    b4 = Workload("resnet18", batch=4).layers()
    assert b4 == NETWORKS["resnet18"](4, bn=False)


def test_workload_layer_list_and_coercions():
    net = tiny_net()
    wl = Workload(net=net)          # list coerced to tuple, hashable
    assert wl.net == tuple(net)
    assert hash(wl) == hash(Workload(net=tuple(net)))
    assert wl.layers() == net
    assert Workload(net=net, training=True).layers() \
        == expand_training_graph(net)
    with pytest.raises(ValueError, match="batch"):
        Workload(net=net, batch=8)
    assert as_workload(wl) is wl
    assert as_workload("resnet50") == Workload("resnet50")
    assert as_workload(net).net == tuple(net)
    with pytest.raises(TypeError):
        as_workload(42)
    assert Workload("resnet50", training=True).label == "resnet50:train"
    assert Workload(net=net, name="mine").label == "mine"


# ---------------------------------------------------------------------------
# Study ownership: workers, caches, method registry
# ---------------------------------------------------------------------------

def test_workers_bit_identical():
    """Fanned-out table builds must not change a single bit of the result
    (grid and refine), and the parallel builds are accounted."""
    net = tiny_net()
    wl = Workload(net=tuple(net))
    clear_table_caches()
    serial = Study(HW16, sizes=GRID, bws=GRID, tol=0.5, workers=1)
    parallel = Study(HW16, sizes=GRID, bws=GRID, tol=0.5, workers=2)
    g0 = serial.search(wl, 256, 256)
    clear_table_caches()
    g1 = parallel.search(wl, 256, 256)
    assert (g0.grid.costs == g1.grid.costs).all()
    assert g0.best == g1.best and g0.worst == g1.worst
    assert table_cache_stats()["conv_parallel_builds"] > 0
    clear_table_caches()
    r0 = serial.search(wl, 256, 256, method="refine")
    clear_table_caches()
    r1 = parallel.search(wl, 256, 256, method="refine")
    assert r0.best == r1.best and r0.archive == r1.archive
    assert r0.refine.trajectory == r1.refine.trajectory


def test_default_workers_env(monkeypatch):
    monkeypatch.setenv("REPRO_DSE_WORKERS", "3")
    assert default_workers() == 3
    assert Study(HW16).workers == 3
    monkeypatch.setenv("REPRO_DSE_WORKERS", "junk")
    with pytest.warns(RuntimeWarning, match="REPRO_DSE_WORKERS.*junk"):
        assert default_workers() == 0
    monkeypatch.delenv("REPRO_DSE_WORKERS")
    assert Study(HW16, workers=5).workers == 5


def test_default_selfcheck_env(monkeypatch):
    from repro.core.study import default_selfcheck
    assert default_selfcheck() == 0          # off unless asked for
    monkeypatch.setenv("REPRO_DSE_SELFCHECK", "4")
    assert default_selfcheck() == 4
    assert Study(HW16).selfcheck == 4
    monkeypatch.setenv("REPRO_DSE_SELFCHECK", "many")
    with pytest.warns(RuntimeWarning, match="REPRO_DSE_SELFCHECK.*many"):
        assert default_selfcheck() == 0
    monkeypatch.delenv("REPRO_DSE_SELFCHECK")
    assert Study(HW16, selfcheck=2).selfcheck == 2


def test_cross_objective_sweep_rebuilds_nothing():
    """Energy tensors live inside the cached tables, so a cycles sweep
    followed by an energy (then EDP) sweep over the same budgets builds
    zero new tables."""
    clear_table_caches()
    st = Study(HW16, sizes=GRID, bws=GRID, tol=0.5)
    wl = Workload(net=tuple(tiny_net()))
    st.search(wl, 256, 256, objective="cycles")
    after_cycles = table_cache_stats()
    st.search(wl, 256, 256, objective="energy")
    st.search(wl, 256, 256, objective="edp")
    after_energy = table_cache_stats()
    assert after_energy["conv_misses"] == after_cycles["conv_misses"]
    assert after_energy["simd_misses"] == after_cycles["simd_misses"]
    assert after_energy["conv_hits"] > after_cycles["conv_hits"]
    by_kind = after_energy["by_kind"]
    assert by_kind["conv"]["misses"] == after_energy["conv_misses"]
    assert by_kind["simd"]["entries"] == after_energy["simd_entries"]


def test_study_method_registry_is_local():
    st = Study(HW16, sizes=GRID, bws=GRID, tol=0.5)
    calls = []

    def fake(hw, nets, *a, **kw):
        calls.append(sorted(nets))
        return {name: st.search(Workload(net=nets[name]), *a[:2])
                for name in nets}

    st.register_method("fake", fake)
    res = st.search(Workload(net=tuple(tiny_net()), name="x"), 256, 256,
                    method="fake")
    assert calls == [["x"]] and res.best.cycles > 0
    with pytest.raises(ValueError, match="unknown search method"):
        st.search(Workload(net=tuple(tiny_net())), 256, 256,
                  method="anneal")
    # the instance-local method never leaked into the global registry
    with pytest.raises(ValueError, match="unknown search method"):
        Study(HW16, sizes=GRID, bws=GRID, tol=0.5).search(
            Workload(net=tuple(tiny_net())), 256, 256, method="fake")


def test_objective_scored_frontier_and_economic():
    """points/within/economic_min_* operate in the result's objective."""
    st = Study(HW16, sizes=GRID, bws=GRID, tol=0.5)
    res = st.search(Workload(net=tuple(tiny_net())), 256, 256,
                    objective="energy")
    limit = res.best_score * 1.15
    assert res.points == res.within(0.15)
    assert res.best in res.points
    for p in res.points:
        assert res.score_of(p) <= limit
    eco = res.economic_min_sram()
    assert eco.total_size_kb <= res.best.total_size_kb
    assert res.phase_breakdown(res.best).total == res.best.cycles
