"""Deterministic fault injection and the recovery paths it exercises.

Every injected fault — worker exception, hard worker crash
(``BrokenProcessPool``), build hang past the per-attempt timeout, store
corruption/truncation at write time, held advisory lock — must be
recovered without a crash or hang and yield bit-identical DSE results to
the fault-free run."""
import numpy as np
import pytest

from repro.core import INFER_PRESETS
from repro.core import faultinject
from repro.core.dse import clear_table_caches, table_cache_stats
from repro.core.layers import ConvLayer, fc, pool, relu
from repro.core.store import TableStore, clear_default_store
from repro.core.study import Study, Workload

HW = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        fc("fc", 1, 2048, 100),
    ]


@pytest.fixture(autouse=True)
def _clean():
    faultinject.reset()
    clear_default_store()
    clear_table_caches()
    yield
    faultinject.reset()
    clear_default_store()
    clear_table_caches()


def _sweep(**kw):
    return Study(HW, sizes=GRID, bws=GRID, tol=0.5, **kw).search(
        Workload(net=tuple(tiny_net())), 256, 256)


# ---- the harness itself ----------------------------------------------------

def test_arm_fire_consume():
    assert faultinject.fire("conv_worker_exc") is None      # inert unarmed
    faultinject.arm("conv_worker_exc", times=2)
    assert faultinject.armed("conv_worker_exc")
    assert faultinject.fire("conv_worker_exc") is not None
    assert faultinject.fire("conv_worker_exc") is not None
    assert faultinject.fire("conv_worker_exc") is None      # exhausted
    assert faultinject.fired("conv_worker_exc") == 2


def test_always_fire_and_arg():
    faultinject.arm("conv_worker_hang", times=-1, arg=7.5)
    for _ in range(5):
        f = faultinject.fire("conv_worker_hang")
        assert f is not None and f.arg == 7.5
    assert faultinject.fired("conv_worker_hang") == 5


def test_fire_is_atomic_under_contention():
    """N threads racing ``fire`` on a ``times=K`` fault must consume
    exactly K firings between them — the unlocked registry lost updates
    on the ``times -= 1`` / ``_FIRED[point] += 1`` read-modify-writes."""
    import threading

    n_threads, k = 8, 64
    attempts_per_thread = 200
    faultinject.arm("conv_worker_exc", times=k)
    barrier = threading.Barrier(n_threads)
    hits = [0] * n_threads

    def worker(slot):
        barrier.wait()
        for _ in range(attempts_per_thread):
            if faultinject.fire("conv_worker_exc") is not None:
                hits[slot] += 1

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(hits) == k
    assert faultinject.fired("conv_worker_exc") == k
    assert not faultinject.armed("conv_worker_exc")


def test_arm_warns_on_unregistered_point():
    with pytest.warns(RuntimeWarning, match="unknown fault point"):
        faultinject.arm("store_corupt")  # analysis: allow[FP001]
    faultinject.disarm("store_corupt")   # analysis: allow[FP001]


def test_load_env_parses_spec():
    faultinject.load_env("conv_worker_crash:2,store_corrupt,"
                         "conv_worker_hang:1:30")
    assert faultinject.armed("conv_worker_crash")
    assert faultinject.armed("store_corrupt")
    f = faultinject.fire("conv_worker_hang")
    assert f is not None and f.arg == 30.0


def test_load_env_warns_on_malformed():
    with pytest.warns(RuntimeWarning, match="REPRO_FAULTS.*bogus:xx"):
        faultinject.load_env("bogus:xx,store_corrupt:1")
    assert not faultinject.armed("bogus")  # analysis: allow[FP001]
    assert faultinject.armed("store_corrupt")       # good items still arm


# ---- parallel-build recovery ----------------------------------------------

@pytest.mark.parametrize("point", ["conv_worker_exc", "conv_worker_crash"])
def test_worker_failure_recovers_bit_identical(point):
    serial = _sweep(workers=0)
    n_tables = table_cache_stats()["conv_builds"]
    clear_table_caches()
    faultinject.arm(point, times=2)
    res = _sweep(workers=2)
    assert faultinject.fired(point) == 2
    assert (res.grid.costs == serial.grid.costs).all()
    assert res.best == serial.best
    # the cache ended consistent: every table built exactly once, across
    # the surviving parallel attempts plus the salvage/fallback path
    assert table_cache_stats()["conv_builds"] == n_tables


def test_worker_hang_trips_timeout_and_recovers(monkeypatch):
    serial = _sweep(workers=0)
    clear_table_caches()
    monkeypatch.setenv("REPRO_DSE_BUILD_TIMEOUT", "2.0")
    faultinject.arm("conv_worker_hang", times=1, arg=60)
    res = _sweep(workers=2)
    assert faultinject.fired("conv_worker_hang") == 1
    assert (res.grid.costs == serial.grid.costs).all()
    assert res.best == serial.best


def test_worker_failure_then_serial_fallback_exhausts_retries():
    """With a fault armed on *every* parallel task, all retries burn out
    and the guaranteed serial fallback still completes the sweep."""
    serial = _sweep(workers=0)
    clear_table_caches()
    faultinject.arm("conv_worker_exc", times=-1)
    res = _sweep(workers=2)
    assert (res.grid.costs == serial.grid.costs).all()
    st = table_cache_stats()
    assert st["conv_parallel_builds"] == 0          # nothing survived
    assert st["conv_builds"] > 0                    # serial built them all


# ---- store-fault recovery --------------------------------------------------

@pytest.mark.parametrize("point", ["store_corrupt", "store_truncate"])
def test_store_damage_at_write_recovers(tmp_path, point):
    baseline = _sweep()
    clear_table_caches()
    store = TableStore(tmp_path)
    faultinject.arm(point, times=1)
    cold = _sweep(store=store)                      # one entry damaged
    assert faultinject.fired(point) == 1
    assert (cold.grid.costs == baseline.grid.costs).all()
    clear_table_caches()
    warm = _sweep(store=store)                      # damage found on load
    st = table_cache_stats()
    assert st["store_corrupt"] == 1
    assert (warm.grid.costs == baseline.grid.costs).all()
    clear_table_caches()
    _sweep(store=store)                             # rebuilt entry persisted
    assert table_cache_stats()["store_corrupt"] == 0


def test_lock_hold_degrades_without_deadlock(tmp_path):
    """A writer sitting on the advisory lock delays other writers at
    most ``lock_timeout_s``; they proceed unlocked and stay correct."""
    import threading
    import time
    slow = TableStore(tmp_path, lock_timeout_s=0.2)
    fast = TableStore(tmp_path, lock_timeout_s=0.2)
    faultinject.arm("store_lock_hold", times=1, arg=1.0)

    t = threading.Thread(
        target=lambda: slow.save("conv", ("slow",), b"x" * 64))
    t.start()
    time.sleep(0.3)                                 # let it take the lock
    t0 = time.monotonic()
    fast.save("conv", ("fast",), b"y" * 64)
    elapsed = time.monotonic() - t0
    t.join(timeout=5)
    assert not t.is_alive()
    assert elapsed < 0.8                            # bounded, no deadlock
    from repro.core.store import store_stats
    assert store_stats()["store_lock_timeouts"] >= 1
    assert fast.load("conv", ("fast",), bytes) == b"y" * 64
    assert slow.load("conv", ("slow",), bytes) == b"x" * 64
