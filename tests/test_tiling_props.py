"""Hypothesis property tests on the tiling generator and cost-model
invariants (the system's load-bearing contracts)."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI; optional locally)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import HardwareSpec
from repro.core import layers as L
from repro.core.conv_model import conv_dram_bits, conv_multipliers, \
    simulate_conv
from repro.core.layers import ConvLayer
from repro.core.simd_model import simulate_simd
from repro.core.tiling import (conv_tile_fits, make_conv_tiling,
                               make_simd_tiling, simd_tile_fits)

KB = 1024

hw_strategy = st.builds(
    lambda jk, wb, ib, ob, vm, bw: HardwareSpec(
        J=jk, K=jk, wbuf=wb * KB, ibuf=ib * KB, obuf=ob * KB, vmem=vm * KB,
        bbuf=16 * KB, bw_w=bw, bw_i=bw, bw_o=bw, bw_v=bw),
    jk=st.sampled_from([8, 16, 32, 64]),
    wb=st.sampled_from([32, 128, 512, 1024]),
    ib=st.sampled_from([32, 128, 512]),
    ob=st.sampled_from([64, 256, 1024]),
    vm=st.sampled_from([64, 256, 1024]),
    bw=st.sampled_from([64, 256, 1024]))

conv_strategy = st.builds(
    lambda n, c_in, c_out, hw_sz, k, s: ConvLayer(
        name="x", n=n, ic=c_in,
        ih=(hw_sz - 1) * s + k, iw=(hw_sz - 1) * s + k,
        oc=c_out, oh=hw_sz, ow=hw_sz, kh=k, kw=k, s=s, has_bias=True),
    n=st.integers(1, 32), c_in=st.sampled_from([3, 16, 64, 256]),
    c_out=st.sampled_from([16, 64, 512]),
    hw_sz=st.sampled_from([1, 7, 28, 112]),
    k=st.sampled_from([1, 3, 7, 56]), s=st.sampled_from([1, 2]))


@settings(max_examples=60, deadline=None)
@given(hw=hw_strategy, layer=conv_strategy)
def test_conv_tiling_always_valid(hw, layer):
    t = make_conv_tiling(hw, layer)
    assert conv_tile_fits(hw, layer, t)


@settings(max_examples=60, deadline=None)
@given(hw=hw_strategy, layer=conv_strategy)
def test_conv_dram_lower_bounds(hw, layer):
    """Compulsory traffic: every tensor must cross DRAM at least once."""
    t = make_conv_tiling(hw, layer)
    m = conv_multipliers(layer, t)
    dram = conv_dram_bits(hw, layer, t, m)
    assert dram["weight"] >= layer.weight_elems * hw.b_w
    if layer.s <= layer.kh:
        # dense input coverage: every ifmap element is read at least once
        # (with stride > kernel some pixels are never touched — found by
        # hypothesis, the model is correct to skip them)
        assert dram["ifmap"] >= layer.ifmap_elems * hw.b_i
    assert dram["psum"] >= layer.ofmap_elems * hw.b_p
    if layer.has_bias:
        assert dram["bias"] >= layer.oc * hw.b_b


@settings(max_examples=40, deadline=None)
@given(hw=hw_strategy, layer=conv_strategy)
def test_conv_costs_nonnegative_and_consistent(hw, layer):
    st_ = simulate_conv(hw, layer)
    assert st_.compute_cycles > 0
    assert st_.stall_cycles >= 0
    assert st_.total_cycles == st_.compute_cycles + st_.stall_cycles
    assert st_.ops["mac"] == layer.macs


simd_strategy = st.builds(
    lambda h, w, n, c: L.tensor_add("t", h, w, n, c),
    h=st.integers(1, 64), w=st.integers(1, 64),
    n=st.integers(1, 32), c=st.integers(1, 2048))


@settings(max_examples=60, deadline=None)
@given(hw=hw_strategy, layer=simd_strategy)
def test_simd_tiling_always_valid(hw, layer):
    t = make_simd_tiling(hw, layer)
    assert simd_tile_fits(hw, layer, t)


@settings(max_examples=40, deadline=None)
@given(hw=hw_strategy, layer=simd_strategy)
def test_simd_dram_lower_bound(hw, layer):
    st_ = simulate_simd(hw, layer)
    assert st_.dram_total_bits >= layer.elems * (2 * hw.b_in + hw.b_out)


@settings(max_examples=30, deadline=None)
@given(layer=conv_strategy,
       bw_lo=st.sampled_from([32, 64]), bw_hi=st.sampled_from([512, 2048]))
def test_stall_monotone_in_bandwidth(layer, bw_lo, bw_hi):
    hw_lo = HardwareSpec(bw_w=bw_lo, bw_i=bw_lo, bw_o=bw_lo, bw_v=bw_lo)
    hw_hi = HardwareSpec(bw_w=bw_hi, bw_i=bw_hi, bw_o=bw_hi, bw_v=bw_hi)
    assert simulate_conv(hw_hi, layer).stall_cycles \
        <= simulate_conv(hw_lo, layer).stall_cycles
