"""Hypothesis property tests for the ``method="refine"`` optimizer.

Two load-bearing invariants over randomized networks, budgets, grids,
tolerances, and seeds:

  * every point the optimizer returns (and every point it ever costs)
    satisfies the SRAM/bandwidth budget constraints, and
  * the refined optimum is never worse than the exhaustive power-of-two
    grid optimum on the same budget (the local search may leave the
    lattice only to *improve* on it).
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI; optional locally)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import HardwareSpec
from repro.core import layers as L
from repro.core.dse import search
from repro.core.layers import ConvLayer
from repro.core.optimize import RefineConfig


def _conv_layer(i, n, ic, oc, hw_sz, k, bias):
    return ConvLayer(name=f"c{i}", n=n, ic=ic, ih=hw_sz + k - 1,
                     iw=hw_sz + k - 1, oc=oc, oh=hw_sz, ow=hw_sz,
                     kh=k, kw=k, s=1, has_bias=bias)


conv_strategy = st.builds(
    _conv_layer, i=st.integers(0, 3), n=st.sampled_from([1, 4]),
    ic=st.sampled_from([8, 16, 32]), oc=st.sampled_from([16, 32, 64]),
    hw_sz=st.sampled_from([8, 14, 16, 28]), k=st.sampled_from([1, 3, 5]),
    bias=st.booleans())

simd_strategy = st.builds(
    lambda kind, i, h, c: {
        "relu": L.relu, "add": L.tensor_add, "bn": L.batch_norm,
    }[kind](f"s{i}", h, h, 1, c) if kind != "pool"
    else L.pool(f"s{i}", h, h, 1, c, 2, 2),
    kind=st.sampled_from(["relu", "add", "bn", "pool"]),
    i=st.integers(0, 3), h=st.sampled_from([8, 14, 16]),
    c=st.sampled_from([16, 32, 64]))

net_strategy = st.builds(
    lambda convs, simds: convs + simds,
    convs=st.lists(conv_strategy, min_size=1, max_size=2),
    simds=st.lists(simd_strategy, min_size=1, max_size=2))

case_strategy = st.fixed_dictionaries({
    "net": net_strategy,
    "jk": st.sampled_from([8, 16, 32]),
    "grid": st.sampled_from([(32, 64, 128, 256), (64, 128, 256, 512)]),
    "budget_mult": st.integers(2, 5),     # budget = mult * min(grid) * 2
    "tol": st.sampled_from([0.15, 0.3, 0.5]),
    "training": st.booleans(),
    "seed": st.integers(0, 2**31 - 1),
})


def _run(case):
    hw = HardwareSpec(J=case["jk"], K=case["jk"])
    grid_vals = case["grid"]
    budget = case["budget_mult"] * min(grid_vals) * 2
    kw = dict(sizes=grid_vals, bws=grid_vals, tol=case["tol"],
              training=case["training"])
    g = search(hw, case["net"], budget, budget, **kw)
    # Grant refine up to the grid's own candidate count: the default
    # evaluation cap is tuned for the paper's +-15% band and can starve
    # the descent on the wide tolerance bands generated here, and the
    # never-worse invariant is about the SRAM/BW budget, not the
    # evaluation budget.  (The optimizer still converges far below the
    # grant — typically a few percent of the grid.)
    r = search(hw, case["net"], budget, budget, method="refine",
               refine=RefineConfig(seed=case["seed"],
                                   max_evals=g.n_candidates), **kw)
    return grid_vals, budget, case["tol"], g, r


@settings(max_examples=20, deadline=None, derandomize=True)
@given(case=case_strategy)
def test_refine_respects_budget_constraints(case):
    grid_vals, budget, tol, _, r = _run(case)
    lo, hi = budget * (1 - tol), budget * (1 + tol)
    vmin, vmax = min(grid_vals), max(grid_vals)
    for p in [r.best, r.worst] + r.archive:
        assert lo <= p.total_size_kb <= hi
        assert lo <= p.total_bw <= hi
        assert all(vmin <= v <= vmax for v in p.sizes_kb + p.bws)


@settings(max_examples=20, deadline=None, derandomize=True)
@given(case=case_strategy)
def test_refine_never_worse_than_grid(case):
    # Empirical invariant, not a structural guarantee: a multi-start
    # descent could in principle strand every start in one basin.  It
    # held over 180 randomized cases at these strategy bounds;
    # derandomize keeps the CI example set fixed so a failure here means
    # the optimizer changed, not that hypothesis rolled a new seed.
    _, _, _, g, r = _run(case)
    assert r.best.cycles <= g.best.cycles
