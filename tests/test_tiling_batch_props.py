"""Hypothesis property tests: the batched tiling derivation is
elementwise bit-identical to the scalar greedy reference over random
layer shapes and random — emphatically non-power-of-two — buffer
capacities.  (CI installs hypothesis; locally these importorskip, and
``test_tiling_batch.py`` carries a seeded random twin of the same
property so the invariant is still exercised without it.)"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI; optional locally)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import layers as L
from repro.core.hardware import KB, HardwareSpec
from repro.core.layers import ConvLayer
from repro.core.tiling import (conv_tile_fits, derive_conv_tiling_reference,
                               derive_conv_tilings_batch,
                               derive_simd_tiling_reference,
                               derive_simd_tilings_batch, simd_tile_fits)

hw_strategy = st.builds(
    lambda jk, bw, bi, bb: HardwareSpec(J=jk, K=jk, b_w=bw, b_i=bi,
                                        bbuf=bb * KB),
    jk=st.sampled_from([8, 16, 32, 64]),
    bw=st.sampled_from([8, 16]), bi=st.sampled_from([8, 16]),
    bb=st.sampled_from([8, 16, 64]))

# arbitrary byte counts, NOT power-of-two aligned
triple_strategy = st.tuples(st.integers(2 * KB, 3000 * KB),
                            st.integers(2 * KB, 3000 * KB),
                            st.integers(2 * KB, 3000 * KB))

conv_strategy = st.builds(
    lambda n, c_in, c_out, hw_sz, k, s, bias: ConvLayer(
        name="x", n=n, ic=c_in,
        ih=(hw_sz - 1) * s + k, iw=(hw_sz - 1) * s + k,
        oc=c_out, oh=hw_sz, ow=hw_sz, kh=k, kw=k, s=s, has_bias=bias),
    n=st.integers(1, 32), c_in=st.sampled_from([3, 16, 64, 256, 513]),
    c_out=st.sampled_from([10, 16, 64, 512]),
    hw_sz=st.sampled_from([1, 7, 28, 112]),
    k=st.sampled_from([1, 3, 7, 56, 223]), s=st.sampled_from([1, 2]),
    bias=st.booleans())


@settings(max_examples=60, deadline=None)
@given(hw=hw_strategy, layer=conv_strategy,
       triples=st.lists(triple_strategy, min_size=1, max_size=12))
def test_conv_batch_elementwise_equals_scalar(hw, layer, triples):
    batch = derive_conv_tilings_batch(hw, triples, layer)
    for tri, bt in zip(triples, batch):
        hw_t = hw.replace(wbuf=tri[0], ibuf=tri[1], obuf=tri[2])
        ref = derive_conv_tiling_reference(hw_t, layer)
        assert bt == ref
        assert conv_tile_fits(hw_t, layer, bt)


simd_strategy = st.builds(
    lambda h, w, n, c, kind: {
        "add": L.tensor_add("t", h, w, n, c),
        "relu": L.relu("t", h, w, n, c),
        "pool": L.pool("t", h, w, n, c, 2, 2),
        "bn": L.batch_norm("t", h, w, n, c),
    }[kind],
    h=st.integers(1, 64), w=st.integers(1, 64),
    n=st.integers(1, 32), c=st.integers(1, 2048),
    kind=st.sampled_from(["add", "relu", "pool", "bn"]))


@settings(max_examples=60, deadline=None)
@given(hw=hw_strategy, layer=simd_strategy,
       vmems=st.lists(st.integers(1 * KB, 3000 * KB),
                      min_size=1, max_size=12))
def test_simd_batch_elementwise_equals_scalar(hw, layer, vmems):
    batch = derive_simd_tilings_batch(hw, vmems, layer)
    for vm, bt in zip(vmems, batch):
        hw_v = hw.replace(vmem=vm)
        ref = derive_simd_tiling_reference(hw_v, layer)
        assert bt == ref
        assert simd_tile_fits(hw_v, layer, bt)
