"""Equivalence of the tensorized DSE against the retained brute-force
reference, plus the supporting caches (tilings, NetworkReport aggregates).

The tensorized ``search()`` must be *bit-identical* to the scalar double
loop: same best/worst points, same within-frac frontier (contents and
order), same economic picks."""
import numpy as np
import pytest

from repro.core import INFER_PRESETS, TRAIN_PRESETS
from repro.core.backward import expand_training_graph
from repro.core.dse import (BWS, SIZES_KB, ConvTable, SimdTable,
                            clear_table_caches, phase_profile, search,
                            search_many, search_reference, table_cache_stats)
from repro.core.layers import ConvLayer, SimdLayer, fc, pool, relu, tensor_add
from repro.core.simulator import simulate_network
from repro.core.tiling import make_conv_tiling, make_simd_tiling

HW = INFER_PRESETS[16]
GRID_SIZES = (32, 64, 128, 256)
GRID_BWS = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    """A few conv + non-conv layers, with a repeated shape under a
    different name to exercise the shape-dedup path."""
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        _conv("c2_dup", ic=32, oc=32, has_bias=False),   # same shape as c2
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 100),
    ]


def tiny_net2():
    return [
        _conv("d1", ic=8, oc=16, kh=5, kw=5),
        relu("r1", 16, 16, 1, 16),
        fc("fc", 1, 512, 10),
    ]


def _assert_equivalent(res, ref):
    assert res.best == ref.best
    assert res.worst == ref.worst
    assert res.improvement == ref.improvement
    for frac in (0.05, 0.15, 0.5):
        assert res.within(frac) == ref.within(frac)
    assert res.economic_min_sram() == ref.economic_min_sram()
    assert res.economic_min_bw() == ref.economic_min_bw()


@pytest.mark.parametrize("lower_bound", [True, False])
def test_search_matches_bruteforce(lower_bound):
    net = tiny_net()
    ref = search_reference(HW, net, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS,
                           tol=0.5, lower_bound=lower_bound)
    res = search(HW, net, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS,
                 tol=0.5, lower_bound=lower_bound)
    _assert_equivalent(res, ref)
    # the frontier, not the full grid, is what gets materialized
    assert len(res.points) < res.n_candidates
    assert res.points == res.within(0.15)


def test_search_many_matches_individual_searches():
    nets = {"a": tiny_net(), "b": tiny_net2()}
    many = search_many(HW, nets, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS,
                       tol=0.5)
    for name, net in nets.items():
        single = search(HW, net, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS,
                        tol=0.5)
        assert many[name].best == single.best
        assert many[name].worst == single.worst
        ref = search_reference(HW, net, 256, 256, sizes=GRID_SIZES,
                               bws=GRID_BWS, tol=0.5)
        _assert_equivalent(many[name], ref)


def test_conv_table_batch_matches_scalar():
    layers = [l for l in tiny_net() if isinstance(l, ConvLayer)]
    table = ConvTable(HW, layers)
    bws = [(32, 64, 128), (256, 32, 64), (128, 128, 128)]
    batch = table.cycles_batch([b[0] for b in bws], [b[1] for b in bws],
                               [b[2] for b in bws])
    for k, (w, i, o) in enumerate(bws):
        assert int(batch[k]) == table.cycles(w, i, o)


def test_simd_table_batch_matches_scalar():
    layers = [l for l in tiny_net() if isinstance(l, SimdLayer)]
    table = SimdTable(HW, layers)
    batch = table.cycles_batch([32, 128, 256])
    for k, bw in enumerate((32, 128, 256)):
        assert int(batch[k]) == table.cycles(bw)


def test_grid_cost_matrix_matches_pointwise_engine():
    """Every entry of the tensorized cost grid equals a scalar evaluation."""
    from repro.core.dse import _Engine
    net = tiny_net()
    res = search(HW, net, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS, tol=0.5)
    eng = _Engine(HW, net)
    rng = np.random.default_rng(0)
    n_sz = len(res.grid.size_tuples)
    n_bw = len(res.grid.bw_tuples)
    for si, bi in zip(rng.integers(0, n_sz, 25), rng.integers(0, n_bw, 25)):
        sz = res.grid.size_tuples[si]
        bw = res.grid.bw_tuples[bi]
        assert int(res.grid.costs[si, bi]) == eng.cycles(sz, bw)


def test_tiling_cache_ignores_bandwidth_and_names():
    layer = _conv("x1")
    t1 = make_conv_tiling(HW, layer)
    # bandwidth-only change: cache hit, same object
    assert make_conv_tiling(HW.replace(bw_w=64, bw_i=64, bw_o=64), layer) is t1
    # same shape, different name/phase: same entry
    assert make_conv_tiling(HW, _conv("x2", phase="bwd_dx")) is t1

    sl = relu("s1", 16, 16, 1, 32)
    s1 = make_simd_tiling(HW, sl)
    assert make_simd_tiling(HW.replace(bw_v=64), sl) is s1
    assert make_simd_tiling(HW, relu("s2", 16, 16, 1, 32)) is s1


def test_network_report_aggregates_cached_and_invalidated():
    net = tiny_net()
    rep = simulate_network(HW, net)
    manual_total = sum(r.stats.total_cycles for r in rep.layers)
    assert rep.total_cycles == manual_total
    assert rep.cycles("sa") + rep.cycles("simd") == rep.total_cycles
    assert rep.dram_bits("sa") + rep.dram_bits("simd") == rep.dram_bits()
    # appending a layer invalidates the cached aggregates
    extra = simulate_network(HW, [_conv("extra")]).layers[0]
    rep.layers.append(extra)
    assert rep.total_cycles == manual_total + extra.stats.total_cycles
    assert rep.ops()["mac"] == sum(r.stats.ops.get("mac", 0)
                                   for r in rep.layers)


def tiny_train_net():
    """Small graph with every training-relevant layer family: biased and
    unbiased convs, BN, ReLU, pool, residual add, FC."""
    import repro.core.layers as L
    return [
        _conv("c1", has_bias=False),
        L.batch_norm("c1.bn", 16, 16, 1, 32),
        relu("c1.relu", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 10),
    ]


def test_training_search_matches_bruteforce():
    """``search(training=True)`` must be bit-identical to the scalar
    reference walked over the pre-expanded graph."""
    net = tiny_train_net()
    res = search(HW, net, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS,
                 tol=0.5, training=True)
    ref = search_reference(HW, expand_training_graph(net), 256, 256,
                           sizes=GRID_SIZES, bws=GRID_BWS, tol=0.5)
    _assert_equivalent(res, ref)


def test_phase_breakdown_partitions_total():
    """Per-phase cycles must sum *exactly* to the point's total for best,
    worst, and every frontier point, and carry all five training phases."""
    res = search(HW, tiny_train_net(), 256, 256, sizes=GRID_SIZES,
                 bws=GRID_BWS, tol=0.5, training=True)
    for p in [res.best, res.worst] + res.points:
        pb = res.phase_breakdown(p)
        assert pb.total == p.cycles
        assert pb.conv_cycles + pb.nonconv_cycles == p.cycles
        assert pb.fwd_cycles + pb.bwd_cycles == p.cycles
    pb = res.phase_breakdown()          # defaults to best
    assert set(pb.as_dict()) == {"conv:fwd", "conv:bwd_dx", "conv:bwd_dw",
                                 "simd:fwd", "simd:bwd"}
    assert pb.nonconv_cycles > 0 and pb.bwd_cycles > 0


def test_inference_phase_breakdown_is_all_fwd():
    res = search(HW, tiny_net(), 256, 256, sizes=GRID_SIZES, bws=GRID_BWS,
                 tol=0.5)
    pb = res.phase_breakdown()
    assert set(pb.as_dict()) == {"conv:fwd", "simd:fwd"}
    assert pb.bwd_cycles == 0
    assert pb.total == res.best.cycles


def test_phase_profile_matches_simulator():
    """The single-configuration table-path attribution must equal the
    scalar simulator's per-phase aggregates cycle for cycle."""
    hw = TRAIN_PRESETS[16]
    net = tiny_train_net()
    prof = phase_profile(hw, net, training=True)
    rep = simulate_network(hw, expand_training_graph(net))
    assert prof.as_dict() == rep.cycles_by_phase()
    assert prof.total == rep.total_cycles
    assert prof.nonconv_share == rep.nonconv_fraction("cycles")


def test_table_phase_cycles_partition_totals():
    """The per-table phase reductions must partition cycles_batch exactly,
    with real (un-normalized) phases."""
    net = expand_training_graph(tiny_train_net())
    convs = [l for l in net if isinstance(l, ConvLayer)]
    simds = [l for l in net if isinstance(l, SimdLayer)]
    ct = ConvTable(HW, convs)
    bw = ([32, 256, 128], [64, 32, 128], [128, 64, 128])
    per_phase = ct.phase_cycles_batch(*bw)
    assert set(per_phase) == {"fwd", "bwd_dx", "bwd_dw"}
    assert (sum(per_phase.values()) == ct.cycles_batch(*bw)).all()
    st = SimdTable(HW, simds)
    per_phase = st.phase_cycles_batch([32, 128, 256])
    assert set(per_phase) == {"fwd", "bwd"}
    assert (sum(per_phase.values()) == st.cycles_batch([32, 128, 256])).all()


def test_simd_table_cache_key_covers_lat_and_bout():
    """Specs differing only in ALU latencies or b_out must not alias to
    one cached SimdTable."""
    from repro.core.dse import get_simd_table
    clear_table_caches()
    layers = [relu("r", 16, 16, 1, 32)]
    base = get_simd_table(HW, layers)
    slow = get_simd_table(
        HW.replace(lat={**HW.lat, "max": 4}), layers)
    assert slow is not base and slow.compute > base.compute
    wide = get_simd_table(HW.replace(b_out=64), layers)
    assert wide is not base and wide.b4[0] > base.b4[0]


def test_cross_call_table_cache_two_budget_sweep():
    """A second budget sweep re-uses every ConvTable whose size triple its
    budget window shares with the first sweep's."""
    clear_table_caches()
    net = tiny_net()
    search(HW, net, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS, tol=0.5)
    first = table_cache_stats()
    assert first["conv_misses"] > 0 and first["conv_hits"] == 0
    # same budget again: all tables cached
    search(HW, net, 256, 256, sizes=GRID_SIZES, bws=GRID_BWS, tol=0.5)
    second = table_cache_stats()
    assert second["conv_misses"] == first["conv_misses"]
    assert second["conv_hits"] == first["conv_misses"]
    assert second["simd_hits"] >= first["simd_misses"]
    # overlapping (wider) budget window: hits for the shared size triples
    search(HW, net, 192, 192, sizes=GRID_SIZES, bws=GRID_BWS, tol=0.5)
    third = table_cache_stats()
    assert third["conv_hits"] > second["conv_hits"]


def test_full_default_grid_small_budget():
    """End-to-end on the real SIZES_KB/BWS grids at the smallest Table VIII
    budget, against brute force."""
    net = tiny_net()
    ref = search_reference(HW, net, 512, 512, sizes=SIZES_KB, bws=BWS)
    res = search(HW, net, 512, 512, sizes=SIZES_KB, bws=BWS)
    _assert_equivalent(res, ref)
