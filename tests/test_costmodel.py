"""The scan-aware jaxpr cost walker and the HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.costmodel import jaxpr_cost
from repro.launch.roofline import collective_bytes


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    c = jaxpr_cost(lambda x, y: x @ y, a, b)
    assert c.flops == 2 * 64 * 48 * 32


def test_scan_multiplies_body():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=17)
        return y

    c = jaxpr_cost(f, x, w)
    assert c.flops >= 17 * 2 * 64 * 64 * 64
    assert c.flops < 18 * 2 * 64 * 64 * 64


def test_remat_counts_recompute():
    """The differentiated jaxpr of a checkpointed fn includes the forward
    recompute — flops(grad w/ remat) > flops(grad w/o remat)."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f_plain(x, w):
        return jnp.sum(jnp.tanh(x @ w) @ w)

    def f_remat(x, w):
        return jnp.sum(jax.checkpoint(
            lambda x: jnp.tanh(x @ w) @ w)(x))

    g_plain = jaxpr_cost(jax.grad(f_plain, argnums=1), x, w)
    g_remat = jaxpr_cost(jax.grad(f_remat, argnums=1), x, w)
    assert g_remat.flops > g_plain.flops


def test_bytes_reasonable_for_matmul():
    m = n = k = 256
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    c = jaxpr_cost(lambda x, y: x @ y, a, b)
    io = (m * k + k * n + m * n) * 4
    assert io <= c.bytes <= 3 * io


# ---------------------------------------------------------------------------
# collective parser
# ---------------------------------------------------------------------------

HLO = """
HloModule test

%body.1 (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %ag = f32[64,128]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  ROOT %t = (s32[], f32[64,128]) tuple(%i, %ag)
}

%cond.2 (p: (s32[], f32[64,128])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[64,128]) -> f32[] {
  %w = (s32[], f32[64,128]) while(%init), condition=%cond.2, body=%body.1
  ROOT %ar = f32[] all-reduce(%s), channel_id=9, replica_groups={}, to_apply=%add
}
"""


def test_collective_parser_multiplies_trips():
    total, kinds = collective_bytes(HLO)
    body_bytes = 64 * 128 * 4
    assert kinds["all-gather"] == body_bytes * 12
    assert kinds["all-reduce"] == 4
    assert total == body_bytes * 12 + 4


def test_collective_parser_tuple_output():
    txt = """
ENTRY %main (a: f32[8]) -> f32[8] {
  %ar = (f32[8]{0}, f32[16]{0}) all-reduce-start(%a, %b), channel_id=1
  %d = (f32[8]{0}, f32[16]{0}) all-reduce-done(%ar)
}
"""
    total, kinds = collective_bytes(txt)
    assert total == (8 + 16) * 4      # -start counted once, -done skipped
