"""Pallas kernel correctness: shape/dtype sweeps against the pure-jnp
oracles in ``repro.kernels.ref`` (interpret=True executes the kernel body
on CPU; the BlockSpecs/grids are the TPU-target configuration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI; optional locally)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("m,n,k", [(64, 64, 64), (200, 90, 130),
                                   (128, 256, 512), (33, 17, 65), (1, 128, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, n, k, dtype):
    k1, k2 = jax.random.split(KEY)
    a = jax.random.normal(k1, (m, k), dtype)
    b = jax.random.normal(k2, (k, n), dtype)
    out = ops.matmul(a, b, bm=64, bn=64, bk=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.matmul_ref(a, b), np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("m,n,k", [(0, 8, 8), (8, 0, 8), (8, 8, 0),
                                   (0, 0, 0), (1, 1, 0)])
@pytest.mark.parametrize("explicit_blocks", [False, True])
def test_matmul_zero_dim(m, n, k, explicit_blocks):
    """Degenerate GEMMs must not divide by a zero block count (the old
    grid computation raised ZeroDivisionError): an empty reduction axis
    contracts to zeros, an empty m or n yields the empty matrix."""
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    blocks = dict(bm=64, bn=64, bk=64) if explicit_blocks else {}
    out = ops.matmul(a, b, **blocks)
    assert out.shape == (m, n)
    assert out.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.matmul_ref(a, b)))


def test_matmul_zero_k_contracts_to_zeros():
    # nonzero m,n with k == 0: the contraction is an empty sum -> exact 0
    a = jnp.zeros((5, 0), jnp.float32)
    b = jnp.zeros((0, 7), jnp.float32)
    out = ops.matmul(a, b)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((5, 7)))


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 200), k=st.integers(1, 300),
       bm=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 128]))
def test_matmul_property(m, n, k, bm, bk):
    k1, k2 = jax.random.split(KEY)
    a = jax.random.normal(k1, (m, k), jnp.float32)
    b = jax.random.normal(k2, (k, n), jnp.float32)
    out = ops.matmul(a, b, bm=bm, bn=64, bk=bk)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("heads,kv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16),
                                           (False, 0)])
@pytest.mark.parametrize("s", [64, 96])
def test_flash_attention_sweep(heads, kv, causal, window, s):
    b, d = 2, 16
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b * heads, s, d), jnp.float32)
    k = jax.random.normal(k2, (b * kv, s, d), jnp.float32)
    v = jax.random.normal(k3, (b * kv, s, d), jnp.float32)
    out = ops.flash_attention(q, k, v, heads, kv, causal=causal,
                              window=window, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, heads, kv, causal=causal,
                                   window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    b, h, kv, s, d = 1, 4, 2, 64, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b * h, s, d), dtype)
    k = jax.random.normal(ks[1], (b * kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b * kv, s, d), dtype)
    out = ops.flash_attention(q, k, v, h, kv, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, h, kv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("rows,d", [(100, 64), (256, 128), (7, 96)])
def test_fused_addnorm(rows, d):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (rows, d), jnp.float32)
    r = jax.random.normal(ks[1], (rows, d), jnp.float32)
    s = jax.random.normal(ks[2], (d,), jnp.float32)
    y, res = ops.fused_add_rmsnorm(x, r, s, block_rows=64)
    yr, resr = ref.fused_add_rmsnorm_ref(x, r, s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(res), np.asarray(resr), atol=1e-6)


@pytest.mark.parametrize("n,c", [(300, 70), (256, 128), (64, 33)])
def test_bn_forward_backward(n, c):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (n, c), jnp.float32)
    g = jax.random.normal(ks[1], (c,), jnp.float32)
    b = jax.random.normal(ks[2], (c,), jnp.float32)
    y, mu, psi = ops.bn_forward(x, g, b, block_rows=64, block_c=32)
    yr, mur, psir = ref.bn_forward_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mur), atol=1e-5)
    np.testing.assert_allclose(np.asarray(psi), np.asarray(psir), atol=1e-4)

    dy = jax.random.normal(ks[0], (n, c), jnp.float32)
    dx, dg, db = ops.bn_backward(x, dy, g, mu, psi, block_rows=64,
                                 block_c=32)
    dxr, dgr, dbr = ref.bn_backward_ref(x, dy, g, mu, psi)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dxr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dg), np.asarray(dgr),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(db), np.asarray(dbr),
                               atol=1e-3, rtol=1e-3)


def test_bn_backward_matches_autodiff():
    """Eq. 28 == jax.grad of the BN forward (the ground truth)."""
    n, c = 128, 16
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (n, c), jnp.float32)
    g = jax.random.normal(ks[1], (c,), jnp.float32) + 1.0
    b = jnp.zeros((c,))
    dy = jax.random.normal(ks[2], (n, c), jnp.float32)

    def fwd(x, g, b):
        y, _, _ = ref.bn_forward_ref(x, g, b)
        return jnp.sum(y * dy)

    dx_ad, dg_ad, db_ad = jax.grad(fwd, argnums=(0, 1, 2))(x, g, b)
    _, mu, psi = ref.bn_forward_ref(x, g, b)
    dx, dg, db = ops.bn_backward(x, dy, g, mu, psi, block_rows=64,
                                 block_c=16)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ad),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(dg), np.asarray(dg_ad),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_ad),
                               atol=1e-3, rtol=1e-3)
