"""The first-class GEMM layer type and the LLM workload front-end.

Pins the load-bearing identity — a GEMM ``m x n x k`` prices
bit-identically to the ``fc`` ConvLayer it specializes (k -> ic on the J
rows, n -> oc on the K columns, m streamed) — plus the closed-form
ceil-div utilization model, ``count`` linearity, batched == scalar
tiling derivation, the Table I GEMM training expansion, the transformer
config lowering, and the two blind-spot regressions this front-end
exposed: zero-conv networks must flow through the engine without
touching the conv table machinery, and ``Workload`` must reject unknown
net names with a listing of what *is* registered.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import INFER_PRESETS, TRAIN_PRESETS
from repro.core.backward import dx_gemm, dw_gemm, expand_training_graph
from repro.core.conv_model import simulate_conv
from repro.core.dse import (batch_build_conv_tables, clear_table_caches,
                            prefetch_conv_tables, search_many,
                            search_reference, table_cache_stats)
from repro.core.gemm_model import simulate_gemm
from repro.core.hardware import KB
from repro.core.layers import GemmLayer, SimdLayer, fc, gemm, rmsnorm, softmax
from repro.core.study import Study, Workload, as_workload
from repro.core.tiling import (ceil_div, derive_gemm_tiling_reference,
                               make_conv_tiling, make_gemm_tiling,
                               _derive_gemm_tiling_arrays)

HW16 = INFER_PRESETS[16]
HWT16 = TRAIN_PRESETS[16]
GRID = (32, 64, 128, 256)
BWG = (8, 16, 32, 64)

SHAPES = [(512, 1024, 1024), (512, 3072, 1024), (77, 129, 65),
          (4096, 151936, 1024), (1, 128, 128)]


def attn_net():
    """A zero-conv GEMM + SIMD micro-workload (one attention block)."""
    return [
        rmsnorm("norm", 64, 1024),
        gemm("q", 64, 1024, 1024),
        gemm("scores", 64, 64, 64, count=16, param=False),
        softmax("sm", 16 * 64, 64),
        gemm("av", 64, 64, 64, count=16, param=False),
        gemm("o", 64, 1024, 1024),
    ]


# ---------------------------------------------------------------------------
# The GEMM == fc specialization (tiling and full cost model)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", [INFER_PRESETS[16], INFER_PRESETS[64],
                                    TRAIN_PRESETS[16], TRAIN_PRESETS[64]])
@pytest.mark.parametrize("shape", SHAPES)
def test_gemm_prices_identical_to_fc(preset, shape):
    m, n, k = shape
    g = gemm("g", m, n, k, has_bias=True)
    f = fc("f", n=m, fan_in=k, fan_out=n)
    tg = make_gemm_tiling(preset, g)
    tf = make_conv_tiling(preset, f)
    assert (tg.T_m, tg.T_k, tg.T_n) == (tf.T_n, tf.T_ic, tf.T_oc)
    assert (tg.t_k, tg.t_n) == (tf.t_ic, tf.t_oc)
    sg = simulate_gemm(preset, g)
    sf = simulate_conv(preset, f)
    assert sg.total_cycles == sf.total_cycles
    assert sum(sg.dram_bits.values()) == sum(sf.dram_bits.values())
    assert sum(sg.sram_bits.values()) == sum(sf.sram_bits.values())


@pytest.mark.parametrize("shape", [(64, 96, 48), (37, 65, 17), (1, 128, 128)])
def test_closed_form_ceil_div_utilization(shape):
    """With everything resident in one tile, the busy cycles are exactly
    the closed-form alignment model ``m * ceil(k/J) * ceil(n/K)`` plus
    the pipeline start overhead."""
    m, n, k = shape
    hw = HW16.replace(wbuf=64 * 1024 * KB, ibuf=64 * 1024 * KB,
                      obuf=64 * 1024 * KB)
    g = gemm("g", m, n, k)
    t = make_gemm_tiling(hw, g)
    assert (t.T_m, t.T_k, t.T_n) == (m, k, n)      # single tile
    s = simulate_gemm(hw, g, stall_model="no_stall")
    want = m * ceil_div(k, hw.J) * ceil_div(n, hw.K) + hw.pso_sa
    assert s.compute_cycles == want
    assert s.stall_cycles == 0


def test_count_scales_all_totals_linearly():
    base = gemm("h", 64, 64, 64, param=False)
    rep = dataclasses.replace(base, count=16)
    s1, s16 = simulate_gemm(HW16, base), simulate_gemm(HW16, rep)
    assert s16.total_cycles == 16 * s1.total_cycles
    for key in s1.dram_bits:
        assert s16.dram_bits[key] == 16 * s1.dram_bits[key]
    for key in s1.sram_bits:
        assert s16.sram_bits[key] == 16 * s1.sram_bits[key]
    assert rep.macs == 16 * base.macs
    # tiling is per-instance: the multiplicity must not change it
    assert make_gemm_tiling(HW16, rep) == make_gemm_tiling(HW16, base)


def test_batched_tiling_matches_scalar_reference():
    layer = gemm("g", 512, 3072, 1024, has_bias=True)
    triples = [(w * KB, i * KB, o * KB)
               for w in GRID for i in GRID for o in (32, 128)]
    T_m, T_k, T_n, t_k, t_n = _derive_gemm_tiling_arrays(
        HW16, triples, layer)
    for x, (wb, ib, ob) in enumerate(triples):
        ref = derive_gemm_tiling_reference(
            HW16.replace(wbuf=wb, ibuf=ib, obuf=ob), layer)
        assert (T_m[x], T_k[x], T_n[x], t_k[x], t_n[x]) \
            == (ref.T_m, ref.T_k, ref.T_n, ref.t_k, ref.t_n)


# ---------------------------------------------------------------------------
# Training expansion (Table I for GEMMs)
# ---------------------------------------------------------------------------

def test_dx_dw_gemm_shapes():
    f = gemm("p", 64, 256, 128, has_bias=True)
    dx, dw = dx_gemm(f), dw_gemm(f)
    assert (dx.m, dx.n, dx.k) == (64, 128, 256)    # dY . W^T
    assert (dw.m, dw.n, dw.k) == (128, 256, 64)    # X^T . dY
    assert dx.phase == "bwd_dx" and dw.phase == "bwd_dw"
    assert not dx.has_bias and not dw.has_bias


def test_training_expansion_gemm_and_updates():
    net = attn_net()
    tr = expand_training_graph(net)
    names = [l.name for l in tr]
    # every GEMM gets both operand gradients
    for g in ("q", "scores", "av", "o"):
        assert f"{g}.dX" in names and f"{g}.dW" in names
    # parameter GEMMs update weights; activation-activation GEMMs don't
    assert "q.upd_w" in names and "o.upd_w" in names
    assert "scores.upd_w" not in names and "av.upd_w" not in names
    # norm backward mirrors + gamma update; softmax mirrors, no params
    assert "norm.back" in names and "norm.upd_g" in names
    assert "sm.back" in names and "sm.upd_g" not in names


def test_dx_shape_dedup_shares_fwd_columns():
    """A square attention GEMM's dX has the same normalized shape as its
    forward twin, so the table union dedups them into one column."""
    from repro.core.dse import _GridEngine
    net = attn_net()
    tr = expand_training_graph(net)
    eng = _GridEngine(HWT16, {"net": tr})
    n_gemms = sum(1 for l in tr if isinstance(l, GemmLayer))
    assert len(eng._gemm_union) < n_gemms


# ---------------------------------------------------------------------------
# Zero-conv regressions (satellite 1)
# ---------------------------------------------------------------------------

def test_empty_conv_union_builders_are_noops():
    clear_table_caches()
    hws = [HW16.replace(wbuf=s * KB) for s in GRID]
    before = table_cache_stats()
    batch_build_conv_tables(hws, [])
    prefetch_conv_tables(hws, [], workers=4)   # must not spin up a pool
    after = table_cache_stats()
    for key in ("conv_builds", "conv_batch_builds", "conv_parallel_builds",
                "conv_misses", "conv_entries"):
        assert after[key] == before[key] == 0


def test_zero_conv_grid_matches_reference_and_partitions():
    clear_table_caches()
    net = attn_net()
    res = search_many(HW16, {"net": net}, 512, 64,
                      sizes=GRID, bws=BWG)["net"]
    ref = search_reference(HW16, net, 512, 64, sizes=GRID, bws=BWG)
    assert res.best == ref.best and res.worst == ref.worst
    assert res.within(0.15) == ref.within(0.15)
    pb = res.phase_breakdown()
    assert set(pb.as_dict()) == {"gemm:fwd", "simd:fwd"}
    assert pb.total == res.best.cycles          # exact partition
    assert pb.conv_cycles == pb.gemm_cycles     # no conv contribution
    stats = table_cache_stats()
    assert stats["conv_builds"] == 0 and stats["conv_misses"] == 0
    assert stats["gemm_batch_builds"] > 0


@pytest.mark.parametrize("training", [False, True])
def test_zero_conv_refine_matches_engine(training):
    net = expand_training_graph(attn_net()) if training else attn_net()
    hw = HWT16 if training else HW16
    study = Study(hw, sizes=GRID, bws=BWG)
    wl = Workload(net=tuple(net))
    g = study.search(wl, 512, 64)
    r = study.search(wl, 512, 64, method="refine")
    # the never-worse guarantee is pinned on the Table VIII fixtures
    # (test_refine.py); here the point is the GEMM evaluator plumbing —
    # the descent must land in the optimum's neighborhood, attribute
    # phases exactly, and price energy
    assert r.best.cycles <= int(g.best.cycles * 1.10)
    pb = r.phase_breakdown()
    assert pb.total == r.best.cycles
    assert pb.as_dict().get("conv:fwd", 0) == 0
    assert r.energy_of(r.best) > 0


# ---------------------------------------------------------------------------
# Workload front door (satellite 3 + LLM lowering)
# ---------------------------------------------------------------------------

def test_unknown_net_raises_value_error_with_listing():
    with pytest.raises(ValueError) as ei:
        Workload(net="not_a_net").layers()
    msg = str(ei.value)
    assert "resnet50" in msg            # CNN registry
    assert "qwen3_0_6b" in msg          # LLM configs, module alias
    assert "gemma3-27b" in msg          # ...and arch id


def test_llm_names_resolve_both_spellings():
    a = Workload(net="gemma3-27b", seq=64).layers()
    b = Workload(net="gemma3_27b", seq=64).layers()
    assert a == b
    assert any(isinstance(l, GemmLayer) for l in a)
    assert any(isinstance(l, SimdLayer) for l in a)


def test_seq_rejected_for_cnn_and_layer_lists():
    with pytest.raises(ValueError, match="seq applies"):
        Workload(net="resnet50", seq=128).layers()
    with pytest.raises(ValueError, match="seq applies"):
        Workload(net=tuple(attn_net()), seq=128)


def test_as_workload_accepts_gemm_layer_lists():
    wl = as_workload(attn_net())
    assert isinstance(wl, Workload)
    assert wl.layers() == attn_net()


def test_lowering_families():
    """Structural spot-checks of the per-family lowering."""
    def layers_of(name, **kw):
        return Workload(net=name, **kw).layers()

    # MoE: router + per-expert GEMMs carrying the expert multiplicity
    moe = layers_of("granite_moe_1b", seq=64)
    router = [l for l in moe if l.name == "blk0.moe.router"]
    experts = [l for l in moe if l.name == "blk0.moe.e_up"]
    assert router and experts and experts[0].count == 32
    # balanced top-8 dispatch: ceil(64 * 8 / 32) tokens per expert
    assert experts[0].m == 16

    # audio enc-dec: encoder stack + decoder cross-attention at S_enc
    wsp = layers_of("whisper_tiny", seq=64)
    assert any(l.name.startswith("enc0.") for l in wsp)
    xk = [l for l in wsp if l.name == "blk0.xattn.k"][0]
    assert xk.m == 1500                  # encoder_seq tokens
    xs = [l for l in wsp if l.name == "blk0.xattn.scores"][0]
    assert (xs.m, xs.n) == (64, 1500) and not xs.param

    # pure SSM: no MLP (d_ff=0), SSD GEMMs are per-head activations
    ssm = layers_of("mamba2_130m", seq=64)
    assert not any(".mlp." in l.name for l in ssm)
    ssd = [l for l in ssm if l.name == "blk0.ssd_state"][0]
    assert not ssd.param and ssd.count == 24  # B * n_heads

    # sliding-window attention clips the attended length
    gma = layers_of("gemma3_27b", seq=4096)
    local = [l for l in gma if l.name == "blk0.attn.scores"][0]
    glob = [l for l in gma if l.name == "blk5.attn.scores"][0]
    assert local.k == glob.k             # same head_dim reduction
    assert local.n == 1024 and glob.n == 4096

    # lm_head prices the vocab projection; embeddings are not modeled
    assert any(l.name == "lm_head" and l.n == 262144 for l in gma)


def test_training_lowering_expands_all_gemms():
    inf = Workload(net="qwen3_0_6b", seq=64).layers()
    trn = Workload(net="qwen3_0_6b", training=True, seq=64).layers()
    n_gemm_fwd = sum(1 for l in inf if isinstance(l, GemmLayer))
    n_dx = sum(1 for l in trn
               if isinstance(l, GemmLayer) and l.phase == "bwd_dx")
    n_dw = sum(1 for l in trn
               if isinstance(l, GemmLayer) and l.phase == "bwd_dw")
    assert n_dx == n_gemm_fwd and n_dw == n_gemm_fwd


# ---------------------------------------------------------------------------
# Acceptance: gemma3-27b training through grid and refine, all backends
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gemma_train():
    wl = Workload(net="gemma3_27b", training=True, seq=64)
    return wl, wl.layers()


def test_gemma3_grid_all_backends_match_reference(gemma_train):
    wl, layers = gemma_train
    ref = search_reference(HWT16, layers, 512, 64, sizes=GRID, bws=BWG)
    results = {}
    for backend in ("numpy", "jax", "jax-fused"):
        res = Study(HWT16, sizes=GRID, bws=BWG,
                    backend=backend).search(wl, 512, 64)
        assert res.best == ref.best
        assert res.worst == ref.worst
        assert res.within(0.15) == ref.within(0.15)
        results[backend] = res
    assert results["numpy"].pareto() == results["jax"].pareto()
    assert np.array_equal(results["numpy"].grid.costs,
                          results["jax"].grid.costs)
    pb = results["numpy"].phase_breakdown()
    assert pb.total == ref.best.cycles
    assert pb.gemm_cycles > 0 and pb.nonconv_cycles > 0
    assert pb.conv_cycles == pb.gemm_cycles       # zero-conv workload


def test_gemma3_refine_completes_and_never_worse(gemma_train):
    wl, _ = gemma_train
    study = Study(HWT16, sizes=GRID, bws=BWG)
    g = study.search(wl, 512, 64)
    r = study.search(wl, 512, 64, method="refine")
    assert r.best.cycles <= g.best.cycles
    assert r.phase_breakdown().total == r.best.cycles
    assert r.energy_of(r.best) > 0
