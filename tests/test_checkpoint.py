"""Checkpoint manager: atomic roundtrip, retention, resume semantics."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"m": {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))},
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(10, state, {"pipeline": {"step": 10}, "note": "x"})
    assert mgr.latest_step() == 10
    restored, extra = mgr.restore(10, jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state))
    assert extra["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state())
    assert not list(tmp_path.glob("tmp.*"))
    assert (tmp_path / "step_0000000005" / "manifest.json").exists()


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(99, _state())


def test_train_resume_continues(tmp_path):
    """Kill-and-resume: a resumed run continues from the checkpoint and
    produces the same losses as an uninterrupted run (determinism)."""
    from repro.launch.train import train_loop

    full = train_loop("smollm-360m", steps=6, batch=2, seq=16,
                      ckpt_dir=str(tmp_path / "a"), ckpt_every=3,
                      log=lambda *a: None)
    # same config, interrupted after 3 steps (preemption), then resumed
    part1 = train_loop("smollm-360m", steps=6, batch=2, seq=16,
                       ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                       stop_after=3, log=lambda *a: None)
    part2 = train_loop("smollm-360m", steps=6, batch=2, seq=16,
                       ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                       resume=True, log=lambda *a: None)
    np.testing.assert_allclose(full["losses"][3:], part2["losses"],
                               rtol=2e-4, atol=2e-4)
