"""Sharding-rule resolution: divisibility fallback and duplicate-axis
dropping (the two production behaviors the dry-run exposed)."""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (PROD_RULES, ParamDef, multipod,
                                 param_specs, spec)

SIZES = {"_axis_sizes": {"pod": 2, "data": 16, "model": 16}}


def rules(**extra):
    r = dict(PROD_RULES)
    r.update(SIZES)
    r.update(extra)
    return r


def test_divisibility_fallback():
    r = rules()
    # 5 kv heads cannot split a 16-way axis -> unsharded
    assert spec(r, "batch", "seq", "kv_heads", shape=(256, 128, 5)) \
        == P("data", None, None)
    # 16 kv heads can
    assert spec(r, "batch", "seq", "kv_heads", shape=(256, 128, 16)) \
        == P("data", None, "model")


def test_duplicate_axis_dropped():
    r = rules(cache_seq="model")
    # cache_seq and cache_heads both resolve to 'model': first dim wins
    s = spec(r, "batch", "cache_seq", "cache_heads", None,
             shape=(128, 32768, 16, 128))
    assert s == P("data", "model", None, None)


def test_tuple_axis_divisibility():
    r = multipod(rules())
    # batch = ('pod','data') needs divisibility by 32
    assert spec(r, "batch", shape=(256,)) == P(("pod", "data"))
    assert spec(r, "batch", shape=(24,)) == P(None)


def test_param_specs_respect_shape():
    defs = {"wk": ParamDef((960, 5, 64), ("embed", "kv_heads", None))}
    specs = param_specs(defs, rules())
    assert specs["wk"] == P("data", None, None)
    defs2 = {"wk": ParamDef((1024, 16, 64), ("embed", "kv_heads", None))}
    assert param_specs(defs2, rules())["wk"] == P("data", "model", None)


def test_no_rules_means_replicated():
    assert spec(None, "batch", "seq") == P()
    defs = {"w": ParamDef((8, 8), ("embed", "ff"))}
    assert param_specs(defs, None)["w"] == P()
