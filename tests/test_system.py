"""End-to-end behaviour tests: training reduces loss on the learnable
synthetic stream; serving generates; the simulator reproduces the paper's
headline claims in-band; DSE improves over worst case."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import INFER_PRESETS, TRAIN_PRESETS, simulate
from repro.launch.serve import serve_loop
from repro.launch.train import train_loop


def test_training_reduces_loss():
    out = train_loop("smollm-360m", steps=25, batch=8, seq=48, lr=3e-3,
                     log=lambda *a: None)
    first = np.mean(out["losses"][:3])
    last = np.mean(out["losses"][-3:])
    assert last < first * 0.8, (first, last)
    assert not out["stalled"]


def test_serving_generates():
    out = serve_loop("qwen3-0.6b", batch=2, prompt_len=8, gen=6,
                     log=lambda *a: None)
    assert out["generated"].shape == (2, 6)
    assert out["elapsed_s"] > 0


def test_paper_claim_nonconv_dominates_training():
    """Paper Table VI: non-Conv ops are a major, array-size-increasing
    share of ResNet-50 training runtime (paper: 41.9/56.6/59.5%)."""
    fr = []
    for jk in (16, 32, 64):
        rep = simulate(TRAIN_PRESETS[jk], "resnet50", mode="training")
        fr.append(rep.nonconv_fraction("cycles"))
    assert fr[0] < fr[1] < fr[2]
    assert 0.30 < fr[0] < 0.55
    assert 0.50 < fr[2] < 0.80


def test_paper_claim_inference_band():
    """Paper Table VI inference: 30.1/41.6/49.3%."""
    fr = [simulate(INFER_PRESETS[jk], "resnet50",
                   mode="inference").nonconv_fraction("cycles")
          for jk in (16, 32, 64)]
    assert fr[0] < fr[2]
    assert 0.20 < fr[0] < 0.45
    assert 0.35 < fr[2] < 0.70


def test_training_includes_inference_and_more():
    """Sec. V-A: inference is a subset of training — same hw, same batch,
    the training graph must cost strictly more."""
    hw = TRAIN_PRESETS[32]
    inf = simulate(hw, "resnet18", mode="inference", batch=32)
    trn = simulate(hw, "resnet18", mode="training", batch=32)
    assert trn.total_cycles > 2 * inf.total_cycles


def test_dse_improvement():
    from repro.core.dse import search
    from repro.core.networks import resnet18
    res = search(INFER_PRESETS[64], resnet18(1, bn=False), 2048, 2048)
    assert res.improvement > 3.0
    assert res.best.cycles <= res.worst.cycles
