"""The ``method="refine"`` local-search DSE front-end (``core.optimize``).

Pins the optimizer's contracts: restricted to the power-of-two lattice it
reproduces the exhaustive reference's best point bit-identically on the
Table VIII fixture; unrestricted it is never worse than the exhaustive
power-of-two optimum on any Table VIII budget — inference *and* training —
at >=10x fewer candidate evaluations; trajectories are seed-deterministic
across ``search`` and ``search_many``; and the phase attribution of
off-lattice points partitions their cycles exactly.
"""
import pytest

from repro.core import INFER_PRESETS, TRAIN_PRESETS
from repro.core.dse import (clear_table_caches, search, search_many,
                            search_reference, table_cache_stats)
from repro.core.layers import (ConvLayer, batch_norm, fc, pool, relu,
                               tensor_add)
from repro.core.networks import resnet50
from repro.core.optimize import RefineConfig

BUDGETS = {16: 512, 32: 1024, 64: 2048, 128: 4096}   # Table VIII
HW16 = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 100),
    ]


def tiny_train_net():
    return [
        _conv("c1", has_bias=False),
        batch_norm("c1.bn", 16, 16, 1, 32),
        relu("c1.relu", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 10),
    ]


def _hw(presets, jk):
    return presets.get(jk, presets[64]).replace(J=jk, K=jk)


@pytest.fixture(scope="module")
def table8():
    """Grid + refine results for every Table VIII budget, ResNet-50
    inference and training (the shared table cache makes the second
    front-end per fixture nearly free at the lattice level)."""
    out = {}
    for mode, presets, net, training in (
            ("inference", INFER_PRESETS, resnet50(1, bn=False), False),
            ("training", TRAIN_PRESETS, resnet50(32, bn=True), True)):
        for jk, budget in BUDGETS.items():
            hw = _hw(presets, jk)
            g = search(hw, net, budget, budget, training=training)
            r = search(hw, net, budget, budget, training=training,
                       method="refine")
            out[(mode, jk)] = (budget, g, r)
    return out


# ---------------------------------------------------------------------------
# Acceptance: never worse than the exhaustive power-of-two optimum at
# >=10x fewer candidate evaluations, on every Table VIII budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["inference", "training"])
@pytest.mark.parametrize("jk", [16, 32, 64, 128])
def test_refine_never_worse_and_10x_cheaper(table8, mode, jk):
    budget, g, r = table8[(mode, jk)]
    assert r.best.cycles <= g.best.cycles
    assert r.n_candidates * 10 <= g.n_candidates
    assert r.refine.eval_saving >= 10.0
    # and the result respects the budget band
    lo, hi = budget * 0.85, budget * 1.15
    assert lo <= r.best.total_size_kb <= hi
    assert lo <= r.best.total_bw <= hi


def test_refine_beats_lattice_somewhere(table8):
    """The finer-than-power-of-two granularity must actually pay: on the
    Table VIII fixtures the refined optimum is *strictly* below the
    exhaustive lattice optimum (every one of them does today; assert at
    least the inference 64x64 headline row plus a global any())."""
    _, g64, r64 = table8[("inference", 64)]
    assert r64.best.cycles < g64.best.cycles
    assert any(r.best.cycles < g.best.cycles
               for _, g, r in table8.values())


def test_refine_off_lattice_points_materialized(table8):
    """The archive materializes evaluated candidates as DSEPoints and
    the winning configuration sits off the power-of-two lattice."""
    from repro.core.dse import SIZES_KB, BWS
    _, g, r = table8[("inference", 64)]
    assert r.archive and r.n_candidates == len(r.archive)
    assert any(p == r.best for p in r.archive)
    assert any(v not in SIZES_KB for v in r.best.sizes_kb) \
        or any(v not in BWS for v in r.best.bws)


# ---------------------------------------------------------------------------
# Lattice-restricted equivalence with the exhaustive reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("jk", [16, 32, 64, 128])
def test_lattice_refine_reproduces_grid_best(table8, jk):
    """Restricted to the power-of-two lattice, refine lands on the
    tensorized grid's best point bit-identically (Table VIII inference
    fixture), with the >=10x evaluation saving intact."""
    budget, g, _ = table8[("inference", jk)]
    rl = search(_hw(INFER_PRESETS, jk), resnet50(1, bn=False),
                budget, budget, method="refine",
                refine=RefineConfig(lattice_only=True))
    assert rl.best == g.best
    assert rl.n_candidates * 10 <= g.n_candidates


def test_lattice_refine_reproduces_grid_best_training(table8):
    """Regression (joint size+bw blind spot): on the 16x16 *training*
    fixture the only in-band lattice point better than the coordinate
    descent's resting point needs IBuf grown two notches (paid by
    OBuf/VMem) *and* input bandwidth grown one notch (paid by
    weight/output bandwidth) in a single move — each axis alone is
    uphill.  The grow-and-repair joint move covers it; pinned here as
    bit-identical to the exhaustive grid optimum, with the evaluation
    saving intact."""
    budget, g, _ = table8[("training", 16)]
    rl = search(_hw(TRAIN_PRESETS, 16), resnet50(32, bn=True),
                budget, budget, training=True, method="refine",
                refine=RefineConfig(lattice_only=True))
    assert rl.best == g.best
    assert rl.n_candidates * 10 <= g.n_candidates


def test_lattice_refine_reproduces_search_reference():
    """...and bit-identically the scalar brute-force loop itself, on the
    smallest Table VIII budget (the two exhaustive paths are pinned equal
    to each other in test_dse_equivalence)."""
    hw = _hw(INFER_PRESETS, 16)
    net = resnet50(1, bn=False)
    ref = search_reference(hw, net, 512, 512, collect=False)
    rl = search(hw, net, 512, 512, method="refine",
                refine=RefineConfig(lattice_only=True))
    assert rl.best == ref.best
    # every lattice-restricted candidate cost matches the scalar engine's
    lo, hi = 512 * 0.85, 512 * 1.15
    for p in rl.archive[::97]:
        assert lo <= p.total_size_kb <= hi and lo <= p.total_bw <= hi


def test_lattice_refine_costs_bit_identical_to_grid():
    """Every candidate the lattice-restricted optimizer costs must equal
    the exhaustive grid's entry for the same tuples."""
    net = tiny_net()
    g = search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5)
    rl = search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
                method="refine", refine=RefineConfig(lattice_only=True))
    for p in rl.archive:
        si, bi = g.grid.locate(p)
        assert int(g.grid.costs[si, bi]) == p.cycles


# ---------------------------------------------------------------------------
# Determinism
# ---------------------------------------------------------------------------

def test_identical_seed_identical_trajectory_and_result():
    net = tiny_net()
    kw = dict(sizes=GRID, bws=GRID, tol=0.5, method="refine")
    r1 = search(HW16, net, 256, 256, refine=RefineConfig(seed=3), **kw)
    r2 = search(HW16, net, 256, 256, refine=RefineConfig(seed=3), **kw)
    assert r1.refine.trajectory == r2.refine.trajectory
    assert r1.best == r2.best and r1.worst == r2.worst
    assert r1.archive == r2.archive
    assert r1 == r2                     # dataclass eq: best/worst fields


def test_search_many_matches_search_trajectory():
    """The per-network descent must not depend on what else shares the
    evaluator: search and search_many produce identical trajectories and
    results for the same seed."""
    net, net2 = tiny_net(), tiny_train_net()
    kw = dict(sizes=GRID, bws=GRID, tol=0.5, method="refine")
    single = search(HW16, net, 256, 256, refine=RefineConfig(seed=5), **kw)
    many = search_many(HW16, {"a": net, "b": net2}, 256, 256,
                       refine=RefineConfig(seed=5), **kw)
    assert many["a"].refine.trajectory == single.refine.trajectory
    assert many["a"].best == single.best
    assert many["a"].archive == single.archive


# ---------------------------------------------------------------------------
# Off-lattice phase attribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("training", [False, True])
def test_phase_breakdown_partitions_off_lattice(training):
    """Phase cycles of refine results partition the point's total exactly
    for best, worst, and a spread of archived (off-lattice) candidates,
    for inference (fwd only) and training (conv fwd/dX/dW + SIMD
    fwd/bwd)."""
    net = tiny_train_net() if training else tiny_net()
    r = search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
               training=training, method="refine")
    for p in [r.best, r.worst] + r.archive[::41]:
        pb = r.phase_breakdown(p)
        assert pb.total == p.cycles
        assert pb.conv_cycles + pb.nonconv_cycles == p.cycles
        assert pb.fwd_cycles + pb.bwd_cycles == p.cycles
    keys = set(r.phase_breakdown().as_dict())
    if training:
        assert keys == {"conv:fwd", "conv:bwd_dx", "conv:bwd_dw",
                        "simd:fwd", "simd:bwd"}
    else:
        assert keys == {"conv:fwd", "simd:fwd"}
    # off-lattice evaluation really happened
    assert any(any(v not in GRID for v in p.sizes_kb + p.bws)
               for p in r.archive)


# ---------------------------------------------------------------------------
# Result API + table-cache reuse
# ---------------------------------------------------------------------------

def test_refine_result_frontier_and_economic_api():
    net = tiny_net()
    r = search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
               method="refine")
    assert r.points == r.within(0.15)
    assert all(p.cycles <= r.best.cycles * 1.15 for p in r.points)
    assert r.best in r.points
    eco = r.economic_min_sram()
    assert eco.total_size_kb <= r.best.total_size_kb
    assert r.n_candidates == r.refine.n_evals == len(r.archive)
    assert r.improvement >= 1.0


def test_single_engine_nets_supported():
    """Conv-only and SIMD-only networks run through refine (the other
    engine's cost is zero)."""
    conv_only = [_conv("c1"), fc("fc", 1, 2048, 100)]
    simd_only = [relu("r1", 16, 16, 1, 32), tensor_add("a1", 8, 8, 1, 32)]
    for net in (conv_only, simd_only):
        r = search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
                   method="refine")
        g = search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5)
        assert r.best.cycles <= g.best.cycles
        assert r.phase_breakdown().total == r.best.cycles


def test_refine_reuses_tables_across_front_ends_and_levels():
    """A lattice-restricted refine after a grid sweep of the same shapes
    builds *zero* new conv tables (pure cache hits), and the off-lattice
    levels add only off-lattice triples on top."""
    clear_table_caches()
    net = tiny_net()
    search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5)
    after_grid = table_cache_stats()
    search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
           method="refine", refine=RefineConfig(lattice_only=True))
    after_lattice = table_cache_stats()
    assert after_lattice["conv_misses"] == after_grid["conv_misses"]
    assert after_lattice["conv_hits"] > after_grid["conv_hits"]
    # seeded rerun of the full refine: every table it needs is now cached
    search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
           method="refine")
    mid = table_cache_stats()
    search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
           method="refine")
    final = table_cache_stats()
    assert final["conv_misses"] == mid["conv_misses"]
    assert final["simd_misses"] == mid["simd_misses"]


def test_unknown_method_and_misplaced_refine_config_raise():
    net = tiny_net()
    with pytest.raises(ValueError, match="unknown search method"):
        search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
               method="anneal")
    with pytest.raises(ValueError, match="refine config"):
        search(HW16, net, 256, 256, sizes=GRID, bws=GRID, tol=0.5,
               method="grid", refine=RefineConfig())
