"""Optimizers, schedules, clipping, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compression import dequantize, init_error, quantize
from repro.optim.optimizers import (AdamW, SGDM, clip_by_global_norm,
                                    constant_schedule, cosine_schedule,
                                    global_norm)


def _quadratic_losses(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["x"] - target) ** 2)

    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(grads, state, params)
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(AdamW(schedule=constant_schedule(0.1),
                                     weight_decay=0.0))
    assert losses[-1] < losses[0] * 0.01


def test_sgdm_converges():
    losses = _quadratic_losses(SGDM(schedule=constant_schedule(0.05)))
    assert losses[-1] < losses[0] * 0.05


def test_clip_caps_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) < 1.0001
    assert float(norm) > 100


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 1e-6
    assert float(sched(100)) < float(sched(50)) < float(sched(10))


def test_bf16_moments_roundtrip():
    opt = AdamW(schedule=constant_schedule(0.1), mv_dtype=jnp.bfloat16)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    assert state["m"]["x"].dtype == jnp.bfloat16
    g = {"x": jnp.ones(4)}
    params, state, _ = opt.update(g, state, params)
    assert bool(jnp.isfinite(params["x"]).all())


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_error_bound():
    rng = jax.random.PRNGKey(0)
    g = jax.random.normal(rng, (1000,)) * 3.0
    err0 = jnp.zeros_like(g)
    q, scale, err = quantize(g, err0)
    deq = dequantize(q, scale, g.shape, g.size)
    # per-block max / 127 quantization step bound
    step = float(scale.max())
    assert float(jnp.abs(g - deq).max()) <= step * 0.5001
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq),
                               atol=1e-6)


def test_error_feedback_reduces_bias():
    """With error feedback, the *cumulative* compressed sum tracks the true
    cumulative sum much better than independent rounding."""
    rng = jax.random.PRNGKey(1)
    g = jax.random.normal(rng, (512,)) * 1e-3 + 0.02
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, err = quantize(g, err)
        acc = acc + dequantize(q, scale, g.shape, g.size)
    true = g * 50
    assert float(jnp.abs(acc - true).max()) / float(jnp.abs(true).max()) < 0.02
