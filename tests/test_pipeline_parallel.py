"""Pipeline parallelism: correctness vs sequential execution, gradients
through the pipelined forward, and bubble accounting.  Runs in a
subprocess with 4 forced host devices so the main test process keeps the
default single-device view."""
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipeline_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    S, B, D, M = 4, 8, 16, 4
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    y_pipe = pipeline_apply(stage_fn, ws, x, n_micro=M, mesh=mesh)

    def sequential(ws, x):
        h = x
        for i in range(S):
            h = stage_fn(ws[i], h)
        return h

    y_seq = sequential(ws, x)
    err = float(jnp.abs(y_pipe - y_seq).max())
    assert err < 1e-5, f"forward mismatch {err}"

    # gradients flow through ppermute correctly
    def loss_pipe(ws):
        return jnp.sum(pipeline_apply(stage_fn, ws, x, n_micro=M,
                                      mesh=mesh) ** 2)
    def loss_seq(ws):
        return jnp.sum(sequential(ws, x) ** 2)

    g_pipe = jax.grad(loss_pipe)(ws)
    g_seq = jax.grad(loss_seq)(ws)
    gerr = float(jnp.abs(g_pipe - g_seq).max())
    assert gerr < 1e-4, f"grad mismatch {gerr}"
    print("PIPELINE_OK", err, gerr)
""")


def test_pipeline_matches_sequential():
    # JAX_PLATFORMS=cpu is load-bearing: the script forces 4 *host*
    # devices, and without the pin jax probes for accelerator plugins,
    # which can hang indefinitely in sandboxed containers.
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr


def test_bubble_fraction():
    assert bubble_fraction(n_micro=1, n_stages=4) == pytest.approx(0.75)
    assert bubble_fraction(n_micro=12, n_stages=4) == pytest.approx(3 / 15)
    assert bubble_fraction(n_micro=100, n_stages=1) == 0.0
