"""Concurrent-access stress: N processes hammering one store directory.

Several fresh processes run overlapping sweeps (shared + private layer
shapes) against the same store root at once.  The contract under
contention is: every process exits cleanly with bit-identical results,
the store ends with only valid entries (no stray tempfiles, nothing
quarantined by racing writers), and a follow-up warm run rebuilds
nothing."""
import json
import os
import subprocess
import sys

from repro.core import INFER_PRESETS
from repro.core.dse import clear_table_caches, table_cache_stats
from repro.core.layers import ConvLayer, fc, pool, relu
from repro.core.store import TableStore, clear_default_store
from repro.core.study import Study, Workload

N_PROCS = 4

WORKER = """
import json, sys
from repro.core import INFER_PRESETS
from repro.core.study import Study, Workload
from repro.core.layers import ConvLayer, fc, pool, relu

def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)

rank = int(sys.argv[1])
# shared prefix (every process races on these keys) + one private shape
net = [_conv("c1"), relu("r1", 16, 16, 1, 32),
       _conv("c2", ic=32, oc=32, has_bias=False),
       pool("p1", 8, 8, 1, 32, 2, 2),
       _conv("mine", oc=32 + 16 * (rank % 2)),
       fc("fc", 1, 2048, 100)]
res = Study(INFER_PRESETS[16], sizes=(32, 64, 128, 256),
            bws=(32, 64, 128, 256), tol=0.5, store=sys.argv[2]) \\
    .search(Workload(net=tuple(net)), 256, 256)
print(json.dumps([rank % 2, int(res.best.cycles),
                  res.grid.costs.sum().item()]))
"""


def test_concurrent_processes_share_one_store(tmp_path):
    root = tmp_path / "store"
    env = {**os.environ, "PYTHONPATH": "src"}
    cwd = os.path.dirname(os.path.dirname(__file__))
    procs = [subprocess.Popen([sys.executable, "-c", WORKER, str(i),
                               str(root)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True,
                              env=env, cwd=cwd)
             for i in range(N_PROCS)]
    results = {}
    for i, p in enumerate(procs):
        out, err = p.communicate(timeout=600)
        assert p.returncode == 0, f"rank {i}: {err}"
        variant, best, total = json.loads(out.strip().splitlines()[-1])
        # same-variant processes raced on identical keys: results must
        # agree bit for bit no matter who won each write
        assert results.setdefault(variant, (best, total)) == (best, total)
    assert set(results) == {0, 1}

    # the store ended clean: no temp debris, no quarantined files, and
    # every surviving entry validates
    store = TableStore(root)
    assert not list(root.glob(".tmp-*"))
    assert not (store.quarantine_dir.exists()
                and list(store.quarantine_dir.iterdir()))
    assert len(list(store.entries())) > 0

    # a warm in-process run over the shared shapes rebuilds nothing
    clear_default_store()
    clear_table_caches()

    def _conv(name, **kw):
        base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16,
                    ow=16, kh=3, kw=3, s=1, has_bias=True)
        base.update(kw)
        return ConvLayer(**base)

    net = [_conv("c1"), relu("r1", 16, 16, 1, 32),
           _conv("c2", ic=32, oc=32, has_bias=False),
           pool("p1", 8, 8, 1, 32, 2, 2), _conv("mine", oc=32),
           fc("fc", 1, 2048, 100)]
    res = Study(INFER_PRESETS[16], sizes=(32, 64, 128, 256),
                bws=(32, 64, 128, 256), tol=0.5, store=store) \
        .search(Workload(net=tuple(net)), 256, 256)
    st = table_cache_stats()
    assert st["conv_builds"] == 0 and st["simd_builds"] == 0, st
    assert st["store_corrupt"] == 0
    assert (int(res.best.cycles), res.grid.costs.sum().item()) \
        == tuple(results[0])
    clear_table_caches()
