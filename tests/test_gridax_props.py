"""Property tests for the device DSE reductions (hypothesis, CI-only).

Random duplicate-laden integer grids pin the parts of the bit-identity
contract that example tests can only sample: first-occurrence tie-break
of every argmin/argmax path (XLA, vmapped, fused Pallas), and the
within/Pareto masks against the retained sequential numpy walks."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (installed in CI; optional locally)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import gridax
from repro.core.dse import _pareto_mask


def _case(seed, ns, nb, s, b, scale_bits):
    """Matrices quantized to few distinct values -> many exact ties."""
    rng = np.random.default_rng(seed)
    lo, hi = 2 ** scale_bits, 2 ** (scale_bits + 2)
    conv = rng.integers(lo, hi, size=(s, b), dtype=np.int64)
    simd = rng.integers(lo // 4, hi // 4, size=(s, b), dtype=np.int64)
    q = 2 ** scale_bits
    conv, simd = (conv // q) * q, (simd // (q // 4)) * (q // 4)
    return (conv, simd, rng.integers(0, s, size=ns),
            rng.integers(0, b, size=nb), rng.integers(0, s, size=ns),
            rng.integers(0, b, size=nb))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10 ** 6), ns=st.integers(1, 24),
       nb=st.integers(1, 24), s=st.integers(1, 12), b=st.integers(1, 12),
       scale_bits=st.sampled_from([8, 31, 39]))
def test_reduce_first_occurrence(seed, ns, nb, s, b, scale_bits):
    conv, simd, *proj = _case(seed, ns, nb, s, b, scale_bits)
    flat = (conv[np.ix_(proj[0], proj[1])]
            + simd[np.ix_(proj[2], proj[3])]).ravel()
    [(costs, bi, wi, fm)] = gridax.reduce_cycles_many(
        [conv], [simd], *proj, frontier_mult=1.15)
    assert np.array_equal(costs.ravel(), flat)
    assert bi == int(flat.argmin())            # numpy argmin: first occurrence
    assert wi == int(flat.argmax())
    assert np.array_equal(fm, flat <= flat[flat.argmin()] * 1.15)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6), ns=st.integers(1, 12),
       nb=st.integers(1, 12), s=st.integers(1, 8), b=st.integers(1, 8),
       scale_bits=st.sampled_from([8, 39]))
def test_fused_first_occurrence(seed, ns, nb, s, b, scale_bits):
    conv, simd, *proj = _case(seed, ns, nb, s, b, scale_bits)
    flat = (conv[np.ix_(proj[0], proj[1])]
            + simd[np.ix_(proj[2], proj[3])]).ravel()
    bi, wi = gridax.fused_minmax(conv, simd, *proj, interpret=True)
    assert bi == int(flat.argmin())
    assert wi == int(flat.argmax())


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(1, 200),
       k=st.integers(1, 8))
def test_pareto_mask_equivalence(seed, n, k):
    # few distinct values (k) per axis -> dense duplicate fronts
    rng = np.random.default_rng(seed)
    cycles = rng.integers(1, k + 1, size=n).astype(np.int64) * 2 ** 30
    energy = rng.integers(1, k + 1, size=n).astype(float)
    assert np.array_equal(gridax.pareto_mask(cycles, energy),
                          _pareto_mask(cycles, energy))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(1, 100),
       frac=st.sampled_from([0.0, 0.05, 0.15, 0.5]))
def test_within_mask_equivalence(seed, n, frac):
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 5, size=n).astype(np.int64) * 2 ** 38
    limit = float(vals.min()) * (1.0 + frac)
    assert np.array_equal(gridax.within_mask(vals, limit), vals <= limit)
