"""Table V identities: the two backward ops are plain convolutions whose
dimensions follow the published transformation formulas; Table I: the
training expansion emits exactly the per-layer-type operation lists."""
from collections import Counter

import pytest

from repro.core import layers as L
from repro.core.backward import dw_conv, dx_conv, expand_training_graph
from repro.core.layers import ConvLayer, fc
from repro.core.networks import resnet50


def _f(s=2, kh=7, kw=7, oh=112, ow=112, ih=224, iw=224, ic=3, oc=64, n=32):
    return ConvLayer(name="f", n=n, ic=ic, ih=ih, iw=iw, oc=oc, oh=oh,
                     ow=ow, kh=kh, kw=kw, s=s, has_bias=False)


def test_dx_formulas():
    f = _f()
    b = dx_conv(f)
    assert b.kh == f.kh and b.kw == f.kw            # K^B = K^F
    assert b.ic == f.oc and b.oc == f.ic            # channel swap
    assert b.s == 1
    assert b.oh == f.ih and b.ow == f.iw            # OH^B = IH^F
    assert b.ih == f.s * (f.oh - 1) + 1 + 2 * (f.kh - 1)
    assert b.n == f.n


def test_dw_formulas():
    f = _f()
    b = dw_conv(f)
    assert b.kh == f.s * (f.oh - 1) + 1             # huge kernel (223 here)
    assert b.kh == 223                              # the paper's example
    assert b.ic == f.n and b.n == f.ic              # batch <-> channel swap
    assert b.oh == f.kh and b.ow == f.kw            # ofmap = weight shape
    assert b.ih == f.ih and b.s == 1


def test_dx_output_matches_ifmap_volume():
    """dL/dX must have exactly the ifmap's geometry."""
    for f in (_f(), _f(s=1, kh=3, kw=3, oh=56, ow=56, ih=56, iw=56,
                   ic=64, oc=64)):
        b = dx_conv(f)
        assert b.ofmap_elems == f.ifmap_elems


def test_dw_output_matches_weight_volume():
    f = _f(s=1, kh=3, kw=3, oh=56, ow=56, ih=56, iw=56, ic=64, oc=256)
    b = dw_conv(f)
    assert b.ofmap_elems == f.weight_elems


@pytest.mark.parametrize("s,k,ih", [(1, 3, 56), (2, 3, 56), (2, 7, 224),
                                    (1, 1, 28), (4, 11, 224)])
def test_dx_dw_shape_algebra(s, k, ih):
    """Table V dimensional algebra across strides/kernels: dilated+padded
    ifmap extent, flipped-kernel channel swap, dW kernel = S(OH-1)+1."""
    oh = (ih - k) // s + 1
    f = _f(s=s, kh=k, kw=k, oh=oh, ow=oh, ih=ih, iw=ih, ic=16, oc=32, n=8)
    dx, dw = dx_conv(f), dw_conv(f)
    # dX: ifmap is dL/dX^{l+1} dilated by (S-1) and padded by (K-1)
    assert dx.ih == f.s * (f.oh - 1) + 1 + 2 * (f.kh - 1)
    assert dx.s == 1 and dx.phase == "bwd_dx"
    # dX: flipped kernel swaps the channel axes, keeps the window
    assert (dx.ic, dx.oc) == (f.oc, f.ic)
    assert (dx.kh, dx.kw) == (f.kh, f.kw)
    assert dx.ofmap_elems == f.ifmap_elems
    # dW: filter is the dilated output gradient -> kernel = S(OH-1)+1
    assert dw.kh == f.s * (f.oh - 1) + 1
    assert dw.kw == f.s * (f.ow - 1) + 1
    assert (dw.ic, dw.n) == (f.n, f.ic)       # batch <-> channel swap
    assert (dw.oh, dw.ow) == (f.kh, f.kw)     # ofmap = weight geometry
    assert dw.ofmap_elems == f.weight_elems
    assert dw.phase == "bwd_dw"
    # neither backward conv carries a bias
    assert not dx.has_bias and not dw.has_bias


def _ops_added_by(net):
    """Count of op types the expansion appends beyond the forward graph."""
    full = expand_training_graph(net)
    added = full[len(net):]
    return Counter(getattr(l, "op", f"conv.{l.phase}") for l in added)


def test_table1_biased_conv_ops():
    """Biased (non-input) Conv: dX + dW + bias-grad + 4D and 1D updates."""
    stem = _f(s=1, kh=3, kw=3, oh=8, ow=8, ih=8, iw=8, ic=4, oc=4, n=2)
    conv = ConvLayer(name="c", n=2, ic=4, ih=8, iw=8, oc=8, oh=8, ow=8,
                     kh=3, kw=3, s=1, has_bias=True)
    ops = _ops_added_by([stem, conv])
    assert ops["conv.bwd_dx"] == 1            # only the non-input conv
    assert ops["conv.bwd_dw"] == 2
    assert ops["bias_grad"] == 1
    assert ops["update_4d"] == 2
    assert ops["update_1d"] == 1


def test_table1_input_conv_has_no_dx():
    stem = _f()
    ops = _ops_added_by([stem])
    assert ops["conv.bwd_dx"] == 0
    assert ops["conv.bwd_dw"] == 1


def test_table1_bn_ops():
    """BN: BN_back (Algorithm 1) + scale and shift updates."""
    ops = _ops_added_by([L.batch_norm("b", 8, 8, 2, 16)])
    assert ops["bn_back"] == 1
    assert ops["update_1d"] == 2
    assert sum(ops.values()) == 3


def test_table1_simd_backward_ops():
    net = [L.relu("r", 8, 8, 2, 16),
           L.pool("p", 4, 4, 2, 16, 2, 2),
           L.pool("pa", 2, 2, 2, 16, 2, 2, mode="avg"),
           L.tensor_add("a", 2, 2, 2, 16),
           L.global_avg_pool("g", 2, 2, 2, 16)]
    ops = _ops_added_by(net)
    assert ops["relu_back"] == 1
    assert ops["pool_max_back"] == 1
    assert ops["pool_avg_back"] == 1
    assert ops["tensor_add"] == 1             # gradient junction
    assert ops["gap_back"] == 1
    assert sum(ops.values()) == 5


def test_table1_fc_ops():
    """FC = 1x1 conv: biased FC gets dX + dW + bias grad + both updates."""
    stem = _f()
    ops = _ops_added_by([stem, fc("fc", 32, 64, 10)])
    assert ops["conv.bwd_dx"] == 1
    assert ops["conv.bwd_dw"] == 2            # stem's dW + fc's dW
    assert ops["bias_grad"] == 1
    assert ops["update_4d"] == 2
    assert ops["update_1d"] == 1


def test_backward_layers_tagged_backward():
    full = expand_training_graph(resnet50(2))
    n_fwd = len(resnet50(2))
    assert all(not l.is_backward for l in full[:n_fwd])
    assert all(l.is_backward for l in full[n_fwd:])


def test_expansion_is_positional_not_identity():
    """A reused (shape-identical, same-object) conv later in the graph must
    still get a dX; only the *first slot* is the input layer."""
    conv = _f(s=1, kh=3, kw=3, oh=8, ow=8, ih=8, iw=8, ic=4, oc=4, n=2)
    ops = _ops_added_by([conv, conv])
    assert ops["conv.bwd_dx"] == 1
    assert ops["conv.bwd_dw"] == 2


def test_training_graph_contents():
    net = resnet50(32)
    full = expand_training_graph(net)
    names = [l.name for l in full]
    ops = [getattr(l, "op", "conv") for l in full]
    assert len(full) > len(net) * 2
    assert any(n.endswith(".dX") for n in names)
    assert any(n.endswith(".dW") for n in names)
    assert "bn_back" in ops
    assert "relu_back" in ops
    assert any(o.startswith("update_") for o in ops)
    # first conv has no dX
    assert not any(n == "stem.conv.dX" for n in names)
    assert any(n == "stem.conv.dW" for n in names)
