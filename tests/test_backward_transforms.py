"""Table V identities: the two backward ops are plain convolutions whose
dimensions follow the published transformation formulas."""
import pytest

from repro.core.backward import dw_conv, dx_conv, expand_training_graph
from repro.core.layers import ConvLayer
from repro.core.networks import resnet50


def _f(s=2, kh=7, kw=7, oh=112, ow=112, ih=224, iw=224, ic=3, oc=64, n=32):
    return ConvLayer(name="f", n=n, ic=ic, ih=ih, iw=iw, oc=oc, oh=oh,
                     ow=ow, kh=kh, kw=kw, s=s, has_bias=False)


def test_dx_formulas():
    f = _f()
    b = dx_conv(f)
    assert b.kh == f.kh and b.kw == f.kw            # K^B = K^F
    assert b.ic == f.oc and b.oc == f.ic            # channel swap
    assert b.s == 1
    assert b.oh == f.ih and b.ow == f.iw            # OH^B = IH^F
    assert b.ih == f.s * (f.oh - 1) + 1 + 2 * (f.kh - 1)
    assert b.n == f.n


def test_dw_formulas():
    f = _f()
    b = dw_conv(f)
    assert b.kh == f.s * (f.oh - 1) + 1             # huge kernel (223 here)
    assert b.kh == 223                              # the paper's example
    assert b.ic == f.n and b.n == f.ic              # batch <-> channel swap
    assert b.oh == f.kh and b.ow == f.kw            # ofmap = weight shape
    assert b.ih == f.ih and b.s == 1


def test_dx_output_matches_ifmap_volume():
    """dL/dX must have exactly the ifmap's geometry."""
    for f in (_f(), _f(s=1, kh=3, kw=3, oh=56, ow=56, ih=56, iw=56,
                   ic=64, oc=64)):
        b = dx_conv(f)
        assert b.ofmap_elems == f.ifmap_elems


def test_dw_output_matches_weight_volume():
    f = _f(s=1, kh=3, kw=3, oh=56, ow=56, ih=56, iw=56, ic=64, oc=256)
    b = dw_conv(f)
    assert b.ofmap_elems == f.weight_elems


def test_training_graph_contents():
    net = resnet50(32)
    full = expand_training_graph(net)
    names = [l.name for l in full]
    ops = [getattr(l, "op", "conv") for l in full]
    assert len(full) > len(net) * 2
    assert any(n.endswith(".dX") for n in names)
    assert any(n.endswith(".dW") for n in names)
    assert "bn_back" in ops
    assert "relu_back" in ops
    assert any(o.startswith("update_") for o in ops)
    # first conv has no dX
    assert not any(n == "stem.conv.dX" for n in names)
    assert any(n == "stem.conv.dW" for n in names)
