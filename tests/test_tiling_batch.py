"""Batched tiling derivation vs the scalar greedy reference.

``derive_conv_tilings_batch``/``derive_simd_tilings_batch`` are the
production kernels (``make_conv_tiling``/``make_simd_tiling`` are
one-candidate slices of them); ``derive_*_tiling_reference`` retain the
original scalar walks.  These tests pin the batch bit-identical to the
reference over the full Table VIII candidate lattice — ResNet-50
inference AND training layer sets — plus seeded random off-lattice
shapes and capacities, and cover the two greedy defects fixed alongside
the vectorization (stranded WBuf capacity after an IBuf-forced T_ic
shrink; the quadratic remainder-fill scan)."""
import random

import numpy as np
import pytest

from repro.core import INFER_PRESETS, TRAIN_PRESETS
from repro.core import layers as L
from repro.core.backward import expand_training_graph
from repro.core.dse import (BWS, SIZES_KB, ConvTable, _GridEngine,
                            _conv_table_key, _CONV_TABLE_CACHE,
                            _project, _tuples, batch_build_conv_tables,
                            clear_table_caches, table_cache_stats)
from repro.core.hardware import KB, HardwareSpec
from repro.core.layers import ConvLayer
from repro.core.networks import resnet50
from repro.core.tiling import (ceil_div, clear_tiling_caches,
                               conv_tile_fits, derive_conv_tiling_reference,
                               derive_conv_tilings_batch,
                               derive_simd_tiling_reference,
                               derive_simd_tilings_batch, make_conv_tiling,
                               make_simd_tiling, _fill_dim, _max_fit)


def _table8_size_triples():
    """Unique (wbuf, ibuf, obuf) byte triples across every Table VIII
    budget window (512/1024/2048/4096 kB, +-15%, lower-bounded)."""
    triples = []
    for budget in (512, 1024, 2048, 4096):
        tuples = _tuples(SIZES_KB, 4, budget * 0.85, budget * 1.15)
        s3s, _ = _project(tuples, lambda t: t[:3])
        triples.extend(s3s)
    return [(wb * KB, ib * KB, ob * KB)
            for wb, ib, ob in dict.fromkeys(triples)]


def _table8_vmems():
    vmems = []
    for budget in (512, 1024, 2048, 4096):
        tuples = _tuples(SIZES_KB, 4, budget * 0.85, budget * 1.15)
        vs, _ = _project(tuples, lambda t: t[3])
        vmems.extend(vs)
    return [v * KB for v in dict.fromkeys(vmems)]


def _unions(hw, training):
    net = resnet50(32 if training else 1, bn=training)
    if training:
        net = expand_training_graph(net)
    eng = _GridEngine(hw, {"net": net})
    return eng._conv_union, eng._simd_union


@pytest.mark.parametrize("training", [False, True])
def test_batch_conv_matches_reference_over_table8_lattice(training):
    """Bit-identical per candidate over the entire Table VIII size-triple
    lattice, for every unique ResNet-50 conv shape of the workload."""
    hw = (TRAIN_PRESETS if training else INFER_PRESETS)[64]
    triples = _table8_size_triples()
    convs, _ = _unions(hw, training)
    assert len(convs) >= 20 and len(triples) >= 100
    for layer in convs:
        batch = derive_conv_tilings_batch(hw, triples, layer)
        for tri, bt in zip(triples, batch):
            hw_t = hw.replace(wbuf=tri[0], ibuf=tri[1], obuf=tri[2])
            assert bt == derive_conv_tiling_reference(hw_t, layer)
            assert conv_tile_fits(hw_t, layer, bt)


@pytest.mark.parametrize("training", [False, True])
def test_batch_simd_matches_reference_over_table8_lattice(training):
    hw = (TRAIN_PRESETS if training else INFER_PRESETS)[64]
    vmems = _table8_vmems()
    _, simds = _unions(hw, training)
    assert len(simds) >= 10
    for layer in simds:
        batch = derive_simd_tilings_batch(hw, vmems, layer)
        for vm, bt in zip(vmems, batch):
            assert bt == derive_simd_tiling_reference(
                hw.replace(vmem=vm), layer)


def test_batch_matches_reference_random_offlattice():
    """Seeded sweep over random layer shapes and *non-power-of-two*
    buffer capacities (the local validation twin of the hypothesis
    property test in ``test_tiling_batch_props.py``)."""
    rng = random.Random(20260801)
    for _ in range(25):
        jk = rng.choice([8, 16, 32, 64])
        hw = HardwareSpec(J=jk, K=jk, b_w=rng.choice([8, 16]),
                          b_i=rng.choice([8, 16]),
                          bbuf=rng.choice([8, 16, 64]) * KB)
        triples = [(rng.randrange(2 * KB, 3000 * KB),
                    rng.randrange(2 * KB, 3000 * KB),
                    rng.randrange(2 * KB, 3000 * KB))
                   for _ in range(rng.randrange(1, 16))]
        k = rng.choice([1, 3, 7, 56, 223])
        s = rng.choice([1, 2])
        o = rng.choice([1, 7, 28, 112])
        layer = ConvLayer(name="x", n=rng.choice([1, 3, 32]),
                          ic=rng.choice([3, 64, 513]),
                          ih=(o - 1) * s + k, iw=(o - 1) * s + k,
                          oc=rng.choice([10, 64, 512]), oh=o, ow=o,
                          kh=k, kw=k, s=s, has_bias=rng.random() < 0.5)
        batch = derive_conv_tilings_batch(hw, triples, layer)
        for tri, bt in zip(triples, batch):
            hw_t = hw.replace(wbuf=tri[0], ibuf=tri[1], obuf=tri[2])
            assert bt == derive_conv_tiling_reference(hw_t, layer)

        vmems = [rng.randrange(1 * KB, 3000 * KB)
                 for _ in range(rng.randrange(1, 12))]
        sl = rng.choice([
            L.tensor_add("t", o, o, 4, 37),
            L.pool("t", 28, 28, 2, 96, 3, 2),
            L.batch_norm("t", 14, 14, 8, 130),
            L.relu("t", 56, 56, 1, 64),
        ])
        sbatch = derive_simd_tilings_batch(hw, vmems, sl)
        for vm, bt in zip(vmems, sbatch):
            assert bt == derive_simd_tiling_reference(
                hw.replace(vmem=vm), sl)


def test_scalar_wrappers_route_through_batch_kernel():
    """``make_conv_tiling``/``make_simd_tiling`` are one-candidate slices
    of the batch kernels — including at arbitrary (non-power-of-two)
    buffer sizes, where the remainder fill produces distinct tilings."""
    hw = INFER_PRESETS[64].replace(wbuf=213 * KB, ibuf=97 * KB,
                                   obuf=311 * KB, vmem=157 * KB)
    layer = ConvLayer(name="c", n=4, ic=96, ih=30, iw=30, oc=160,
                      oh=28, ow=28, kh=3, kw=3, s=1, has_bias=True)
    assert make_conv_tiling(hw, layer) \
        == derive_conv_tiling_reference(hw, layer)
    sl = L.tensor_add("a", 28, 28, 4, 160)
    assert make_simd_tiling(hw, sl) \
        == derive_simd_tiling_reference(hw, sl)


def test_cache_aware_batch_accessors_seed_and_reuse():
    """``conv_tilings_for_triples``/``prefill_conv_tilings`` derive only
    uncached triples, return order-aligned reference-identical tilings,
    and seed the cache ``make_conv_tiling`` then hits (same objects)."""
    from repro.core.tiling import (conv_tilings_for_triples,
                                   prefill_conv_tilings)
    hw = INFER_PRESETS[64]
    layer = ConvLayer(name="c", n=2, ic=64, ih=16, iw=16, oc=128,
                      oh=14, ow=14, kh=3, kw=3, s=1, has_bias=True)
    triples = [(96 * KB, 64 * KB, 200 * KB), (64 * KB, 64 * KB, 64 * KB)]
    clear_tiling_caches()
    got = conv_tilings_for_triples(hw, triples, layer)
    assert derive_conv_tilings_batch(hw, [], layer) == []   # empty is ok
    for tri, t in zip(triples, got):
        hw_t = hw.replace(wbuf=tri[0], ibuf=tri[1], obuf=tri[2])
        assert t == derive_conv_tiling_reference(hw_t, layer)
        assert make_conv_tiling(hw_t, layer) is t           # cache seeded
    prefill_conv_tilings(hw, triples, [layer])              # full no-op
    assert conv_tilings_for_triples(hw, triples, layer) == got
    clear_tiling_caches()


def test_stranded_wbuf_capacity_regrow_regression():
    """When the IBuf guard halves T_ic, the freed WBuf capacity must be
    re-offered to T_oc: a 2 MB WBuf with a 32 kB IBuf used to keep the
    T_oc derived against the pre-shrink T_ic (stranding ~75% of WBuf)."""
    hw = HardwareSpec(J=16, K=16, b_w=16, b_i=16,
                      wbuf=2048 * KB, ibuf=32 * KB, obuf=1024 * KB)
    layer = ConvLayer(name="big", n=1, ic=1024, ih=13, iw=13, oc=512,
                      oh=7, ow=7, kh=7, kw=7, s=1, has_bias=False)
    wcap = hw.wbuf // 2 * 8 // hw.b_w
    icap = hw.ibuf // 2 * 8 // hw.b_i
    t = make_conv_tiling(hw, layer)
    # the guard fired: a full-window T_ic slice would overflow IBuf
    assert t.T_kh == 7 and t.T_kw == 7
    assert t.T_kh * t.T_kw * t.T_ic <= icap < t.T_kh * t.T_kw * 2 * t.T_ic
    # post-fix invariant: T_oc saturates the post-shrink WBuf capacity
    # (K-aligned); the pre-fix greedy left it at 16 here
    cap_oc = wcap // (t.T_kh * t.T_kw * t.T_ic)
    assert t.T_oc == min(layer.oc, cap_oc // hw.K * hw.K)
    assert t.T_oc == 64
    assert t == derive_conv_tiling_reference(hw, layer)
    assert conv_tile_fits(hw, layer, t)


def test_fill_dim_matches_exhaustive_scan():
    """The O(sqrt(dim)) distinct-quotient fill must be byte-identical to
    the original O(dim) scan over every tile count."""
    def fill_dim_exhaustive(cur, dim, fits):
        if cur >= dim:
            return cur
        hi = _max_fit(cur, dim, fits)
        best_t, best_ext = cur, ceil_div(dim, cur) * cur
        for m in range(1, ceil_div(dim, cur) + 1):
            t = ceil_div(dim, m)
            if t < cur:
                break
            if t > hi:
                continue
            ext = m * t
            if ext < best_ext or (ext == best_ext and t > best_t):
                best_t, best_ext = t, ext
        return best_t

    rng = random.Random(7)
    for _ in range(600):
        dim = rng.randrange(1, 3000)
        cur = rng.randrange(1, dim + 1)
        cap = rng.randrange(cur, 2 * dim + 1)
        fits = lambda v, cap=cap: v <= cap
        assert _fill_dim(cur, dim, fits) \
            == fill_dim_exhaustive(cur, dim, fits)
    # degenerate corners
    for cur, dim, cap in ((1, 1, 5), (5, 5, 5), (3, 7, 3), (1, 2048, 2048)):
        fits = lambda v, cap=cap: v <= cap
        assert _fill_dim(cur, dim, fits) \
            == fill_dim_exhaustive(cur, dim, fits)


def test_batch_built_tables_identical_to_scalar_build():
    """``batch_build_conv_tables`` must seed tables whose every field is
    bit-identical to the scalar ``ConvTable`` constructor's, and account
    them as misses on first retrieval (like the fork-pool prefetch)."""
    hw0 = INFER_PRESETS[64]
    convs, _ = _unions(hw0, training=False)
    triples = [(64, 128, 256), (96, 96, 96), (512, 32, 1024)]
    hws = [hw0.replace(wbuf=a * KB, ibuf=b * KB, obuf=c * KB)
           for a, b, c in triples]

    clear_tiling_caches()
    clear_table_caches()
    scalar = [ConvTable(hw, convs) for hw in hws]

    clear_tiling_caches()
    clear_table_caches()
    batch_build_conv_tables(hws, convs)
    stats = table_cache_stats()
    assert stats["conv_batch_builds"] == len(hws)
    assert stats["by_kind"]["conv"]["batch_builds"] == len(hws)
    assert stats["conv_misses"] == 0        # accounted on first retrieval
    for hw, ref in zip(hws, scalar):
        got = _CONV_TABLE_CACHE[_conv_table_key(hw, convs)]
        assert got.phases == ref.phases
        for f in ("c_tile", "o1", "o2", "o4", "o5", "w_bits", "wb_bits",
                  "i_bits", "ps_bits", "pls_bits", "busy", "dram"):
            a, b = getattr(got, f), getattr(ref, f)
            assert a.dtype == b.dtype and np.array_equal(a, b), f
        for buf in ref.sram:
            assert np.array_equal(got.sram[buf], ref.sram[buf]), buf
    clear_tiling_caches()
    clear_table_caches()
