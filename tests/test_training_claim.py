"""Regression pin for the paper's headline training claim (abstract /
Table VI): on a 64x64 processing array, non-convolution operations
constitute 59.5% of total ResNet-50 training runtime.

The model's phase-resolved attribution brackets that figure on the 64x64
baseline: the *static* HT3 allocation yields 67.9% and the DSE-optimal
allocation at the Table VIII 64x64 budget (2048 kB / 2048 bits-per-cycle)
yields 55.4% — the paper's 59.5% lies strictly inside that band (their
hand allocation sits between our static preset and our optimizer's pick;
at 16x16 and 32x32 the same model matches the paper within ~2pp, see
``benchmarks/table6_resnet50.py``).  Both endpoints are pinned at +/-1pp
so any cost-model drift that would move the claim is caught, and the
bracket itself is asserted.  (The endpoints moved from 68.6%/56.1% when
the tiling generator gained the exact padding-aware remainder fill —
better buffer utilization trims SIMD stalls and closes 0.7pp of the
static-allocation gap vs the paper's 59.5%.)
"""
import pytest

from repro.core import TRAIN_PRESETS
from repro.core.dse import phase_profile, search
from repro.core.networks import resnet50

PAPER_SHARE = 0.595          # abstract: 59.5% on a 64x64 array
STATIC_SHARE = 0.679         # this model, static HT3 allocation
OPT_SHARE = 0.554            # this model, DSE-best at the (2048, 2048) budget
TOL = 0.01                   # one percentage point


@pytest.fixture(scope="module")
def hw64():
    return TRAIN_PRESETS[64]


@pytest.fixture(scope="module")
def static_profile(hw64):
    return phase_profile(hw64, resnet50(32, bn=True), training=True)


@pytest.fixture(scope="module")
def opt_result(hw64):
    return search(hw64, resnet50(32, bn=True), 2048, 2048, training=True)


def test_static_share_pinned(static_profile):
    assert abs(static_profile.nonconv_share - STATIC_SHARE) < TOL


def test_optimal_share_pinned(opt_result):
    pb = opt_result.phase_breakdown()
    assert abs(pb.nonconv_share - OPT_SHARE) < TOL


def test_paper_claim_bracketed(static_profile, opt_result):
    """The paper's 59.5% falls between the DSE-optimal and the static
    allocation's non-conv shares on the 64x64 array."""
    opt = opt_result.phase_breakdown().nonconv_share
    assert opt < PAPER_SHARE < static_profile.nonconv_share


def test_nonconv_dominates_and_backward_dominates(static_profile):
    """Qualitative halves of the claim: non-conv ops are the majority of
    training runtime, and the backward+update phases dominate the
    forward pass (the training graph is ~2x the inference work per
    direction plus updates)."""
    assert static_profile.nonconv_share > 0.5
    assert static_profile.bwd_share > 0.5
    d = static_profile.as_dict()
    # dW convs (huge S(OH-1)+1 kernels) are the costliest conv phase
    assert d["conv:bwd_dw"] > d["conv:fwd"]
    assert d["conv:bwd_dw"] > d["conv:bwd_dx"]
