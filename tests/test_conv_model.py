"""Unit tests for the systolic Conv model (paper Secs. IV-C/IV-D)."""
import pytest

from repro.core import HI3, HT3, HardwareSpec
from repro.core.conv_model import (conv_dram_bits, conv_multipliers,
                                   conv_stall_cycles, simulate_conv)
from repro.core.layers import ConvLayer, fc
from repro.core.tiling import conv_tile_fits, make_conv_tiling


def _layer(**kw):
    base = dict(name="l", n=1, ic=64, ih=56, iw=56, oc=64, oh=56, ow=56,
                kh=3, kw=3, s=1, has_bias=False)
    base.update(kw)
    return ConvLayer(**base)


def test_tiling_is_valid():
    for hw in (HI3, HT3):
        for layer in (_layer(), _layer(ic=3, ih=224, oh=112, kh=7, kw=7, s=2),
                      fc("fc", 1, 2048, 1000), _layer(n=32),
                      _layer(kh=223, kw=223, ih=224, iw=224, oh=2, ow=2)):
            t = make_conv_tiling(hw, layer)
            assert conv_tile_fits(hw, layer, t), (hw.name, layer.name)


def test_weight_dram_maximal_reuse():
    """Eq. 4: each weight element is loaded exactly once (ceil-padded)."""
    hw = HI3
    layer = _layer()
    t = make_conv_tiling(hw, layer)
    m = conv_multipliers(layer, t)
    dram = conv_dram_bits(hw, layer, t, m)
    padded_weight = (t.T_kh * m.m_kh) * (t.T_kw * m.m_kw) \
        * (t.T_ic * m.m_ic) * (t.T_oc * m.m_oc)
    assert dram["weight"] == padded_weight * hw.b_w
    assert dram["weight"] >= layer.weight_elems * hw.b_w


def test_psum_no_spill_when_accumulation_fits():
    """With m_kh = m_kw = m_ic = 1, Eq. 9 degenerates to one store per
    ofmap element (no DRAM psum round trips)."""
    hw = HI3
    layer = _layer(ic=64, oc=64)
    t = make_conv_tiling(hw, layer)
    m = conv_multipliers(layer, t)
    if m.m_accum == 1:
        dram = conv_dram_bits(hw, layer, t, m)
        padded_out = m.m_spatial * m.m_oc * t.psum_tile_elems()
        assert dram["psum"] == padded_out * hw.b_p


def test_case_occurrences_partition_tiles():
    hw = HT3
    layer = _layer(n=32, ic=256, oc=512, kh=7, kw=7)
    t = make_conv_tiling(hw, layer)
    m = conv_multipliers(layer, t)
    o5 = m.m_oc
    o4 = m.m_w_tile - m.m_oc
    o1 = m.m_oc * (m.m_spatial - 1)
    o2 = (m.m_outer - m.m_spatial * m.m_oc) - o4
    assert o1 >= 0 and o2 >= 0 and o4 >= 0 and o5 > 0
    assert o1 + o2 + o4 + o5 == m.m_outer


def test_stall_models_ordering():
    """no_stall <= simplified <= simdit (total cycles)."""
    for hw in (HI3, HT3):
        for layer in (_layer(), _layer(n=32, kh=7, kw=7),
                      fc("fc", 32, 4096, 4096)):
            full = simulate_conv(hw, layer).total_cycles
            simpl = simulate_conv(hw, layer,
                                  stall_model="simplified").total_cycles
            nostall = simulate_conv(hw, layer,
                                    stall_model="no_stall").total_cycles
            assert nostall <= simpl <= full


def test_bandwidth_monotonicity():
    layer = _layer(n=32)
    lo = HT3.replace(bw_w=64, bw_i=64, bw_o=64)
    hi = HT3.replace(bw_w=1024, bw_i=1024, bw_o=1024)
    assert simulate_conv(hi, layer).total_cycles \
        <= simulate_conv(lo, layer).total_cycles


def test_mac_count_exact():
    layer = _layer(n=4)
    st = simulate_conv(HT3, layer)
    assert st.ops["mac"] == 4 * 56 * 56 * 64 * 3 * 3 * 64


def test_compute_cycles_lower_bound():
    """Compute cycles >= MACs / (J*K) (array can't beat its peak)."""
    for layer in (_layer(), _layer(ic=3), fc("fc", 1, 512, 1000)):
        st = simulate_conv(HI3, layer)
        assert st.compute_cycles >= layer.macs // (HI3.J * HI3.K)
