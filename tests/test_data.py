"""Data pipeline: determinism, host sharding, checkpointable state."""
import numpy as np

from repro.data.pipeline import PipelineState, TokenPipeline


def test_deterministic():
    p1 = TokenPipeline(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    p2 = TokenPipeline(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    np.testing.assert_array_equal(p1.batch_at(5)["tokens"],
                                  p2.batch_at(5)["tokens"])
    assert not np.array_equal(p1.batch_at(5)["tokens"],
                              p1.batch_at(6)["tokens"])


def test_host_shards_differ():
    a = TokenPipeline(vocab_size=100, seq_len=32, global_batch=8,
                      host_index=0, host_count=2)
    b = TokenPipeline(vocab_size=100, seq_len=32, global_batch=8,
                      host_index=1, host_count=2)
    assert a.local_batch == b.local_batch == 4
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              b.batch_at(0)["tokens"])


def test_state_resume_identical_stream():
    p = TokenPipeline(vocab_size=50, seq_len=16, global_batch=2)
    it = p.iter_from(PipelineState())
    seen = []
    state = PipelineState()
    for _ in range(4):
        state, batch = next(it)
        seen.append(batch["tokens"])
    it2 = p.iter_from(PipelineState(step=2))
    _, b2 = next(it2)
    np.testing.assert_array_equal(seen[2], b2["tokens"])


def test_learnable_structure():
    """The stream is repeat-biased — copy-previous predicts >50%."""
    p = TokenPipeline(vocab_size=97, seq_len=64, global_batch=4)
    t = p.batch_at(0)["tokens"]
    agree = (t[:, :-1] == t[:, 1:]).mean()
    assert agree > 0.5
