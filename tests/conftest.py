"""Shared fixtures. NOTE: no XLA_FLAGS / device-count forcing here — smoke
tests and benches must see the real (single-CPU) device; only the dry-run
subprocesses force 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.core import faultinject


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _disarm_faults():
    """A test that arms a fault and fails before consuming it must not
    leak the armed state into every later test in the process."""
    yield
    faultinject.reset()
