"""Watchdog: fires on stalls, stays silent while beats arrive."""
import time

from repro.distributed.fault import Watchdog


def test_fires_on_stall():
    fired = []
    wd = Watchdog(timeout_s=0.2, on_stall=lambda idle: fired.append(idle))
    with wd:
        time.sleep(0.5)
    assert fired and fired[0] >= 0.2


def test_silent_with_beats():
    fired = []
    wd = Watchdog(timeout_s=0.3, on_stall=lambda idle: fired.append(idle))
    with wd:
        for _ in range(5):
            time.sleep(0.1)
            wd.beat()
    assert not fired


def test_fires_once():
    fired = []
    wd = Watchdog(timeout_s=0.1, on_stall=lambda idle: fired.append(idle))
    with wd:
        time.sleep(0.45)
    assert len(fired) == 1


def test_beat_rearms_for_second_stall():
    """A beat after a stall re-arms the latch: a later second stall in
    the same run fires again instead of being silently absorbed."""
    fired = []
    wd = Watchdog(timeout_s=0.1, on_stall=lambda idle: fired.append(idle))
    with wd:
        time.sleep(0.3)              # first stall
        assert len(fired) == 1
        wd.beat()                    # recovery heartbeat
        time.sleep(0.3)              # second stall
    assert len(fired) == 2


def test_no_fire_after_stop():
    """stop() closes the race with _run: once stopped, the callback can
    never fire even if the run was mid-stall."""
    fired = []
    wd = Watchdog(timeout_s=0.05, on_stall=lambda idle: fired.append(idle))
    wd.start()
    wd.stop()
    n = len(fired)
    time.sleep(0.3)
    assert len(fired) == n
