"""Watchdog: fires on stalls, stays silent while beats arrive."""
import time

from repro.distributed.fault import Watchdog


def test_fires_on_stall():
    fired = []
    wd = Watchdog(timeout_s=0.2, on_stall=lambda idle: fired.append(idle))
    with wd:
        time.sleep(0.5)
    assert fired and fired[0] >= 0.2


def test_silent_with_beats():
    fired = []
    wd = Watchdog(timeout_s=0.3, on_stall=lambda idle: fired.append(idle))
    with wd:
        for _ in range(5):
            time.sleep(0.1)
            wd.beat()
    assert not fired


def test_fires_once():
    fired = []
    wd = Watchdog(timeout_s=0.1, on_stall=lambda idle: fired.append(idle))
    with wd:
        time.sleep(0.45)
    assert len(fired) == 1
