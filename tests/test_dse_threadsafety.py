"""Thread-safety of the process-lifetime table caches (core.dse).

The serving subsystem (``repro.serve``) drives ``get_conv_table`` /
``get_simd_table`` / ``get_gemm_table`` and ``table_cache_stats()`` from
a dispatcher thread plus arbitrary client threads.  Before the cache
lock landed, two threads racing the same uncached key could both observe
the miss and both build (wasted work AND two distinct table objects in
flight), and the bare ``+=`` on the stat counters could lose updates.
These tests pin the repaired contract: concurrent identical gets build
exactly once and return the same object, counters are exact under
contention, and fully concurrent end-to-end searches stay bit-identical
to serial ones."""
import threading

import numpy as np
import pytest

from repro.core import INFER_PRESETS, Study, Workload
from repro.core.dse import (clear_table_caches, get_conv_table,
                            get_gemm_table, get_simd_table,
                            table_cache_stats)
from repro.core.layers import ConvLayer, GemmLayer, relu

HW16 = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


@pytest.fixture(autouse=True)
def _clean():
    clear_table_caches()
    yield
    clear_table_caches()


def _race(n_threads, fn):
    """Run ``fn(tid)`` on ``n_threads`` barrier-synchronized threads and
    return the per-thread results; re-raise the first worker exception."""
    barrier = threading.Barrier(n_threads)
    out = [None] * n_threads
    errs = []

    def work(tid):
        try:
            barrier.wait()
            out[tid] = fn(tid)
        except BaseException as exc:                 # noqa: BLE001
            errs.append(exc)

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    return out


# ---- the regression: barrier-synchronized double-submit --------------------

def test_double_submit_builds_conv_table_exactly_once():
    """Two threads released by a barrier onto the SAME uncached conv key
    must come back with the same table object and one recorded build —
    the unlocked cache double-built here."""
    layers = (_conv("c1"), _conv("c2", ic=32, oc=32))
    tables = _race(2, lambda tid: get_conv_table(HW16, layers))
    assert tables[0] is tables[1]
    st = table_cache_stats()
    assert st["conv_builds"] == 1, st
    # one thread took the miss+build, the other the hit (or, if it
    # arrived before the build finished, waited on the lock and hit)
    assert st["conv_misses"] == 1 and st["conv_hits"] == 1, st


def test_double_submit_simd_and_gemm_single_build():
    simd = (relu("r1", 16, 16, 1, 32),)
    gemm = (GemmLayer(name="g1", m=64, k=256, n=64),)

    simd_tables = _race(2, lambda tid: get_simd_table(HW16, simd))
    gemm_tables = _race(2, lambda tid: get_gemm_table(HW16, gemm))

    assert simd_tables[0] is simd_tables[1]
    assert gemm_tables[0] is gemm_tables[1]
    st = table_cache_stats()
    assert st["simd_builds"] == 1 and st["gemm_builds"] == 1, st


def test_many_threads_many_keys_build_each_key_once():
    """8 threads x 4 distinct conv keys, all racing: every key built
    exactly once, and every thread holds the same object per key."""
    keysets = [(_conv(f"k{i}", ic=16 + 16 * i),) for i in range(4)]

    def work(tid):
        return [get_conv_table(HW16, ks) for ks in keysets]

    results = _race(8, work)
    for per_key in zip(*results):
        assert all(t is per_key[0] for t in per_key)
    st = table_cache_stats()
    assert st["conv_builds"] == len(keysets), st


# ---- counter exactness under contention ------------------------------------

def test_hit_counters_exact_under_contention():
    """After one warm build, N threads x M lookups must record exactly
    N*M hits — the unlocked ``+=`` lost updates under contention."""
    layers = (_conv("c1"),)
    get_conv_table(HW16, layers)                     # warm: 1 miss, 1 build
    n_threads, m_hits = 8, 50

    def work(tid):
        for _ in range(m_hits):
            get_conv_table(HW16, layers)

    _race(n_threads, work)
    st = table_cache_stats()
    assert st["conv_hits"] == n_threads * m_hits, st
    assert st["conv_misses"] == 1 and st["conv_builds"] == 1, st


def test_stats_snapshot_is_consistent_while_mutating():
    """``table_cache_stats()`` snapshots under the cache lock: sampled
    mid-storm it must never show more builds than misses (a torn read of
    the counter dict could)."""
    stop = threading.Event()
    keys = [(_conv(f"s{i}", ic=16 + 16 * i),) for i in range(3)]

    def mutate(tid):
        i = 0
        while not stop.is_set():
            get_conv_table(HW16, keys[i % len(keys)])
            i += 1

    def sample(tid):
        for _ in range(200):
            st = table_cache_stats()
            assert st["conv_builds"] <= st["conv_misses"], st
        stop.set()

    threads = [threading.Thread(target=mutate, args=(t,)) for t in range(3)]
    threads.append(threading.Thread(target=sample, args=(3,)))
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    stop.set()
    assert not any(t.is_alive() for t in threads)


# ---- end-to-end: concurrent searches bit-identical -------------------------

def test_concurrent_searches_bit_identical_to_serial():
    """Four threads running full grid searches through one Study (shared
    caches, no store) must each match the serial answer bit-for-bit."""
    study = Study(HW16, sizes=GRID, bws=GRID, tol=0.5, store=None)
    wl = Workload(net=(_conv("c1"), relu("r1", 16, 16, 1, 32),
                       _conv("c2", ic=32, oc=32)), name="tiny")
    queries = [(wl, 512, 256), ("alexnet", 512, 256),
               (wl, 256, 256), ("alexnet", 256, 256)]

    results = _race(4, lambda tid: study.search(*queries[tid]))
    clear_table_caches()
    for (q, res) in zip(queries, results):
        ref = study.search(*q)
        assert res.best == ref.best
        assert np.array_equal(res.grid.costs, ref.grid.costs)
