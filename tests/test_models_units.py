"""Model-layer unit tests: rotary, norms, GQA, MoE routing, SSD scan vs
recurrence, RG-LRU scan vs loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.common import ModelConfig, init_params
from repro.models.layers import apply_norm, norm_defs, rope
from repro.models.moe import apply_moe, moe_defs

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=101,
                  dtype=jnp.float32)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = rope(x, pos, theta=1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))

    def dot_at(i, j):
        qi = rope(q, jnp.full((1, 1), i), 1e4)
        kj = rope(k, jnp.full((1, 1), j), 1e4)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(5, 5) - dot_at(0, 0)) < 1e-4


def test_partial_rope_leaves_tail():
    x = jnp.ones((1, 4, 1, 16))
    pos = jnp.broadcast_to(jnp.arange(4), (1, 4))
    y = rope(x, pos, 1e4, fraction=0.5)
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))
    assert not np.array_equal(np.asarray(y[..., :8]),
                              np.asarray(x[..., :8]))


def test_rmsnorm_scale_invariance():
    p = init_params(jax.random.PRNGKey(0), norm_defs(CFG, 32), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32))
    y1 = apply_norm(CFG, p, x)
    y2 = apply_norm(CFG, p, x * 100.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_layernorm_zero_mean():
    cfg = CFG.replace(norm_type="layernorm")
    p = init_params(jax.random.PRNGKey(0), norm_defs(cfg, 32), jnp.float32)
    p = {"scale": jnp.ones(32), "bias": jnp.zeros(32)}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32)) + 7.0
    y = apply_norm(cfg, p, x)
    assert float(jnp.abs(jnp.mean(y, -1)).max()) < 1e-5


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
                n_kv_heads=2, d_ff=32, vocab_size=11, n_experts=4, top_k=2,
                moe_block=32, dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


def test_moe_output_finite_and_aux_positive():
    cfg = _moe_cfg()
    p = init_params(jax.random.PRNGKey(0), moe_defs(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y, aux = apply_moe(cfg, p, x, None)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 1.0 - 1e-3    # Switch aux >= 1 (ideal balance)


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor some tokens must be dropped (zero
    contribution), with a huge one none are."""
    cfg_small = _moe_cfg(moe_capacity=0.10, top_k=1)
    cfg_big = _moe_cfg(moe_capacity=16.0, top_k=1)
    p = init_params(jax.random.PRNGKey(0), moe_defs(cfg_small), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))
    y_small, _ = apply_moe(cfg_small, p, x, None)
    y_big, _ = apply_moe(cfg_big, p, x, None)
    dropped_small = int((jnp.abs(y_small).sum(-1) == 0).sum())
    dropped_big = int((jnp.abs(y_big).sum(-1) == 0).sum())
    assert dropped_small > 0
    assert dropped_big == 0


def test_moe_scatter_equals_onehot():
    """The scatter dispatch (beyond-paper optimization) must be numerically
    identical to the one-hot GEMM dispatch baseline."""
    for top_k in (1, 2):
        cfg_oh = _moe_cfg(top_k=top_k, moe_capacity=4.0)
        cfg_sc = cfg_oh.replace(moe_dispatch="scatter")
        p = init_params(jax.random.PRNGKey(0), moe_defs(cfg_oh), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 16))
        y_oh, aux_oh = apply_moe(cfg_oh, p, x, None)
        y_sc, aux_sc = apply_moe(cfg_sc, p, x, None)
        np.testing.assert_allclose(np.asarray(y_oh), np.asarray(y_sc),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(float(aux_oh), float(aux_sc), atol=1e-6)


def test_moe_scatter_with_drops_equals_onehot():
    cfg_oh = _moe_cfg(top_k=2, moe_capacity=0.25)   # force drops
    cfg_sc = cfg_oh.replace(moe_dispatch="scatter")
    p = init_params(jax.random.PRNGKey(0), moe_defs(cfg_oh), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 16))
    y_oh, _ = apply_moe(cfg_oh, p, x, None)
    y_sc, _ = apply_moe(cfg_sc, p, x, None)
    np.testing.assert_allclose(np.asarray(y_oh), np.asarray(y_sc),
                               atol=2e-5, rtol=2e-5)


def test_moe_topk_mass_normalized():
    cfg = _moe_cfg(moe_capacity=16.0)
    p = init_params(jax.random.PRNGKey(0), moe_defs(cfg), jnp.float32)
    # identical tokens -> identical outputs (routing is deterministic)
    x = jnp.tile(jax.random.normal(jax.random.PRNGKey(2), (1, 1, 16)),
                 (1, 8, 1))
    y, _ = apply_moe(cfg, p, x, None)
    np.testing.assert_allclose(np.asarray(y[0, 0]), np.asarray(y[0, 7]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# SSD (mamba2) and RG-LRU
# ---------------------------------------------------------------------------

def test_ssd_chunked_equals_stepwise():
    """Chunked SSD == explicit per-step recurrence."""
    b, s, h, p, n = 2, 16, 3, 8, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.1
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))

    y_chunk, final = SSM.ssd_chunked(xh, dt, a_log, bb, cc, chunk=5)

    a = -jnp.exp(a_log)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * a)                      # (b,h)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], xh[:, t], bb[:, t])
        state = state * decay[..., None, None] + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", cc[:, t], state))
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               atol=1e-4, rtol=1e-4)


def test_rglru_scan_equals_loop():
    b, s, r = 2, 12, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (b, s, r))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, r)))
    hh, last = RG._rglru_scan(x, a, None)
    h = jnp.zeros((b, r))
    outs = []
    for t in range(s):
        h = a[:, t] * h + x[:, t]
        outs.append(h)
    want = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(hh), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(np.asarray(last), np.asarray(h), atol=1e-5)
