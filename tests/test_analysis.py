"""``repro.analysis`` — the static invariant checker checked.

Each pass gets a known-good / seeded-violation fixture pair asserting
the exact finding locations; the ratchet tests pin the
fingerprint-vs-baseline mechanics; and the self-run test pins the repo's
own ``src/`` clean against the committed ``analysis-baseline.json`` — a
regression anywhere in the annotated invariants fails here first.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Baseline, Manifest, collect_sources,
                            diff_against_baseline, fingerprints, run_passes)

REPO = Path(__file__).resolve().parent.parent


def _write(tmp_path: Path, rel: str, code: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    return p


def _run(tmp_path: Path, manifest: Manifest, *rels: str, only=()):
    files = collect_sources([tmp_path / r for r in rels], root=tmp_path)
    return run_passes(files, manifest, only=only)


def _by_code(findings):
    out = {}
    for f in findings:
        out.setdefault(f.code, []).append(f)
    return out


# ---- locks pass ------------------------------------------------------------

LOCK_MANIFEST = Manifest(lock_order=("mod.py:_LOCK", "other.py:_OTHER"))

LOCK_GOOD = '''
    import threading

    _LOCK = threading.Lock()
    _CACHE = {}          # guarded-by: _LOCK


    def get(key):
        with _LOCK:
            return _CACHE.get(key)


    def _get_locked(key):
        return _CACHE.get(key)


    def put(key, val):
        with _LOCK:
            _put_impl(key, val)


    def _put_impl(key, val):  # holds-lock: _LOCK
        _CACHE[key] = val
'''

LOCK_BAD = '''
    import threading

    _LOCK = threading.Lock()
    _CACHE = {}          # guarded-by: _LOCK


    def get(key):
        return _CACHE.get(key)          # line 8: unguarded read


    def helper():
        _get_locked(1)                  # line 13: no lock held


    def _get_locked(key):
        return _CACHE.get(key)
'''


def test_locks_clean_fixture(tmp_path):
    _write(tmp_path, "mod.py", LOCK_GOOD)
    assert _run(tmp_path, LOCK_MANIFEST, "mod.py", only=("locks",)) == []


def test_locks_flags_unguarded_access_and_bare_locked_call(tmp_path):
    _write(tmp_path, "mod.py", LOCK_BAD)
    by = _by_code(_run(tmp_path, LOCK_MANIFEST, "mod.py", only=("locks",)))
    assert [(f.line, f.symbol) for f in by["LOCK001"]] \
        == [(9, "mod.py:get:_CACHE")]
    assert [(f.line, f.symbol) for f in by["LOCK002"]] \
        == [(13, "mod.py:helper:_get_locked")]


def test_locks_flags_annotation_typo(tmp_path):
    _write(tmp_path, "mod.py", '''
        import threading
        _LOCK = threading.Lock()
        _CACHE = {}      # guarded-by: _LOKC

        def get(key):
            with _LOCK:
                return _CACHE.get(key)
    ''')
    by = _by_code(_run(tmp_path, LOCK_MANIFEST, "mod.py", only=("locks",)))
    assert len(by["LOCK004"]) == 1 and "_LOKC" in by["LOCK004"][0].message


def test_locks_flags_direct_order_inversion(tmp_path):
    _write(tmp_path, "mod.py", '''
        import threading
        _LOCK = threading.Lock()

        def fine():
            with _LOCK:
                pass
    ''')
    _write(tmp_path, "other.py", '''
        import threading
        from mod import _LOCK
        _OTHER = threading.Lock()

        def inverted():
            with _OTHER:
                with _LOCK:          # _OTHER is ordered after _LOCK
                    pass
    ''')
    findings = _run(tmp_path, LOCK_MANIFEST, "mod.py", "other.py",
                    only=("locks",))
    assert [f.code for f in findings] == ["LOCK003"]
    assert findings[0].line == 8


def test_locks_flags_interprocedural_order_inversion(tmp_path):
    # callee acquires _LOCK; caller calls it while holding _OTHER, which
    # the manifest orders *after* _LOCK — only the call graph sees it
    _write(tmp_path, "mod.py", '''
        import threading
        _LOCK = threading.Lock()

        def takes_lock():
            with _LOCK:
                return 1
    ''')
    _write(tmp_path, "other.py", '''
        import threading
        import mod
        _OTHER = threading.Lock()

        def caller():
            with _OTHER:
                return mod.takes_lock()
    ''')
    findings = _run(tmp_path, LOCK_MANIFEST, "mod.py", "other.py",
                    only=("locks",))
    assert [f.code for f in findings] == ["LOCK003"]
    assert findings[0].path == "other.py" and findings[0].line == 8


# ---- exactness pass --------------------------------------------------------

EXACT_MANIFEST = Manifest(exact_scope={"cycles.py": ("*",)})


def test_exact_clean_fixture(tmp_path):
    _write(tmp_path, "cycles.py", '''
        import numpy as np

        def folds(total, per):
            return int(np.ceil(total / per))    # sanctioned ceil-div

        def spans(total, per):
            return total // per + 2
    ''')
    assert _run(tmp_path, EXACT_MANIFEST, "cycles.py", only=("exact",)) == []


def test_exact_flags_div_banned_call_literal_and_float32(tmp_path):
    _write(tmp_path, "cycles.py", '''
        import numpy as np

        def bad_div(total, per):
            return total / per                  # line 5

        def bad_mean(xs):
            return np.mean(xs)                  # line 8

        def bad_literal(x):
            return x * 0.5                      # line 11

        def bad_dtype(xs):
            return np.asarray(xs, dtype=np.float32)   # line 14
    ''')
    by = _by_code(_run(tmp_path, EXACT_MANIFEST, "cycles.py",
                       only=("exact",)))
    assert [f.line for f in by["EX001"]] == [5]
    assert [f.line for f in by["EX002"]] == [8]
    assert [f.line for f in by["EX003"]] == [11]
    assert [f.line for f in by["EX004"]] == [14]


def test_exact_scope_expands_through_calls(tmp_path):
    # only `entry` is a root; `helper` is pulled in via the call closure
    manifest = Manifest(exact_scope={"cycles.py": ("entry",)})
    _write(tmp_path, "cycles.py", '''
        def entry(a, b):
            return helper(a, b)

        def helper(a, b):
            return a / b                        # line 6

        def unrelated(a, b):
            return a / b                        # not reachable from entry
    ''')
    findings = _run(tmp_path, manifest, "cycles.py", only=("exact",))
    assert [(f.code, f.line) for f in findings] == [("EX001", 6)]


# ---- x64 pass --------------------------------------------------------------

X64_MANIFEST = Manifest(x64_modules=("grid.py",))


def test_x64_clean_fixture(tmp_path):
    _write(tmp_path, "grid.py", '''
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        def _x64(fn):
            def wrapped(*a, **k):
                with enable_x64():
                    return fn(*a, **k)
            return wrapped

        _JIT = _x64(jax.jit(lambda x: x + 1))

        @_x64
        def decorated(x):
            return jnp.asarray(x)

        def with_guarded_body(x):
            """Docstring."""
            with enable_x64():
                return jnp.asarray(x)

        def only_calls_guarded(x):
            return _JIT(x)
    ''')
    assert _run(tmp_path, X64_MANIFEST, "grid.py", only=("x64",)) == []


def test_x64_flags_unguarded_entry_and_binding(tmp_path):
    _write(tmp_path, "grid.py", '''
        import jax
        import jax.numpy as jnp

        _JIT = jax.jit(lambda x: x + 1)         # line 5: unguarded binding

        def unguarded(x):
            return jnp.asarray(x)               # entry def at line 7
    ''')
    by = _by_code(_run(tmp_path, X64_MANIFEST, "grid.py", only=("x64",)))
    assert [(f.line, f.symbol) for f in by["X64002"]] == [(5, "_JIT")]
    assert [(f.line, f.symbol) for f in by["X64001"]] == [(7, "unguarded")]


# ---- faults pass -----------------------------------------------------------

FAULT_MANIFEST = Manifest(fault_module="faultinject.py")

FAULT_MODULE = '''
    FAULT_POINTS = {
        "worker_exc": "worker raises",
        "store_corrupt": "store corrupted",
    }

    def fire(point):
        return None

    def arm(point, times=1):
        pass
'''


def test_faults_clean_fixture(tmp_path):
    _write(tmp_path, "faultinject.py", FAULT_MODULE)
    _write(tmp_path, "worker.py", '''
        import faultinject

        def work():
            if faultinject.fire("worker_exc"):
                raise RuntimeError
            if faultinject.fire("store_corrupt"):
                raise IOError
    ''')
    _write(tmp_path, "tests/test_worker.py", '''
        import faultinject

        def test_worker_exc():
            faultinject.arm("worker_exc")

        def test_env_spec():
            spec = "store_corrupt:1"
    ''')
    assert _run(tmp_path, FAULT_MANIFEST, "faultinject.py", "worker.py",
                "tests", only=("faults",)) == []


def test_faults_flags_typo_dead_entry_and_uncovered(tmp_path):
    _write(tmp_path, "faultinject.py", FAULT_MODULE)
    _write(tmp_path, "worker.py", '''
        import faultinject

        def work():
            if faultinject.fire("worker_ecx"):  # line 5: typo'd point
                raise RuntimeError
    ''')
    _write(tmp_path, "tests/test_worker.py", '''
        import faultinject

        def test_worker_exc():
            faultinject.arm("worker_exc")
    ''')
    by = _by_code(_run(tmp_path, FAULT_MANIFEST, "faultinject.py",
                       "worker.py", "tests", only=("faults",)))
    assert [(f.path, f.line, f.symbol) for f in by["FP001"]] \
        == [("worker.py", 5, "worker_ecx")]
    # both registered points are never fired from src (typo broke one,
    # the other has no injection site); store_corrupt also has no test
    assert {f.symbol for f in by["FP002"]} \
        == {"worker_exc", "store_corrupt"}
    assert [f.symbol for f in by["FP003"]] == ["store_corrupt"]


def test_faults_missing_registry(tmp_path):
    _write(tmp_path, "faultinject.py", '''
        def fire(point):
            return None
    ''')
    findings = _run(tmp_path, FAULT_MANIFEST, "faultinject.py",
                    only=("faults",))
    assert [f.code for f in findings] == ["FP000"]


# ---- determinism pass ------------------------------------------------------

DET_MANIFEST = Manifest(determinism_modules=("pricing.py",))


def test_determinism_clean_fixture(tmp_path):
    _write(tmp_path, "pricing.py", '''
        import random
        import time
        import numpy as np

        def price(cfgs, seed):
            rng = np.random.default_rng(seed)
            salt = random.Random(seed).random()
            t0 = time.monotonic()               # timeouts are not priced
            return sorted({c.key for c in cfgs}), rng, salt, t0
    ''')
    assert _run(tmp_path, DET_MANIFEST, "pricing.py",
                only=("determinism",)) == []


def test_determinism_flags_clock_rng_set_iter_and_hash(tmp_path):
    _write(tmp_path, "pricing.py", '''
        import random
        import time
        import numpy as np

        def bad_clock():
            return time.time()                  # line 7

        def bad_rng():
            return np.random.default_rng()      # line 10

        def bad_global_rng():
            return random.random()              # line 13

        def bad_set_iter(cfgs):
            keys = {c.key for c in cfgs}
            return [k for k in list(keys)]      # line 17

        def bad_hash(key):
            return hash(key)                    # line 20
    ''')
    by = _by_code(_run(tmp_path, DET_MANIFEST, "pricing.py",
                       only=("determinism",)))
    assert [f.line for f in by["DT001"]] == [7]
    assert sorted(f.line for f in by["DT002"]) == [10, 13]
    assert [f.line for f in by["DT003"]] == [17]
    assert [f.line for f in by["DT004"]] == [20]


def test_determinism_set_vars_do_not_leak_across_functions(tmp_path):
    _write(tmp_path, "pricing.py", '''
        def makes_a_set(cfgs):
            out = {c.key for c in cfgs}
            return sorted(out)

        def reuses_the_name(tup):
            out = list(tup)
            return tuple(out)                   # a list, not a set
    ''')
    assert _run(tmp_path, DET_MANIFEST, "pricing.py",
                only=("determinism",)) == []


# ---- suppressions, fingerprints, ratchet -----------------------------------

def test_inline_allow_suppresses(tmp_path):
    _write(tmp_path, "pricing.py", '''
        def ok(key):
            return hash(key)  # analysis: allow[DT004]

        def still_bad(key):
            return hash(key)
    ''')
    findings = _run(tmp_path, DET_MANIFEST, "pricing.py",
                    only=("determinism",))
    assert [(f.code, f.line) for f in findings] == [("DT004", 6)]


def test_fingerprints_survive_line_drift(tmp_path):
    src = '''
        def bad(key):
            return hash(key)
    '''
    _write(tmp_path, "pricing.py", src)
    fp1 = set(fingerprints(_run(tmp_path, DET_MANIFEST, "pricing.py")))
    _write(tmp_path, "pricing.py", "# a comment pushing lines down\n"
           + "x = 1\n" + textwrap.dedent(src))
    fp2 = set(fingerprints(_run(tmp_path, DET_MANIFEST, "pricing.py")))
    assert fp1 == fp2


def test_baseline_ratchet_new_vs_stale(tmp_path):
    _write(tmp_path, "pricing.py", '''
        def bad(key):
            return hash(key)
    ''')
    old = _run(tmp_path, DET_MANIFEST, "pricing.py")
    baseline = Baseline.from_findings(old)
    # baselined finding: not new
    new, stale = diff_against_baseline(old, baseline)
    assert not new and not stale
    # a second violation is new; fixing the first leaves it stale
    _write(tmp_path, "pricing.py", '''
        def other(key):
            import time
            return time.time()
    ''')
    now = _run(tmp_path, DET_MANIFEST, "pricing.py")
    new, stale = diff_against_baseline(now, baseline)
    assert [f.code for f in new.values()] == ["DT001"]
    assert len(stale) == 1


# ---- the repo's own source is clean ----------------------------------------

def test_repo_src_is_clean_against_committed_baseline():
    """The committed baseline is empty: the repo's own invariants hold.
    Any new violation in src/ (or a fault point losing test coverage)
    fails here with the finding printed."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "--baseline", "analysis-baseline.json", "src"],
        cwd=REPO, capture_output=True, text=True,
        env={**__import__("os").environ,
             "PYTHONPATH": str(REPO / "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_repo_baseline_is_empty():
    data = json.loads((REPO / "analysis-baseline.json").read_text())
    assert data["findings"] == {}


def test_cli_json_report(tmp_path):
    # the path suffix must match a DEFAULT_MANIFEST determinism module
    _write(tmp_path, "repro/core/optimize.py", '''
        def bad(key):
            return hash(key)
    ''')
    out = tmp_path / "findings.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json", str(out),
         "--only", "determinism", str(tmp_path / "repro/core/optimize.py")],
        cwd=REPO, capture_output=True, text=True,
        env={**__import__("os").environ,
             "PYTHONPATH": str(REPO / "src")})
    assert proc.returncode == 1          # unbaselined finding
    report = json.loads(out.read_text())
    assert report["total"] == 1
    assert report["by_pass"] == {"determinism": 1}
    assert report["findings"][0]["code"] == "DT004"
