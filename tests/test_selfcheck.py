"""The opt-in DSE self-check mode (``REPRO_DSE_SELFCHECK`` /
``Study(selfcheck=n)``).

The batched cost tables and the scalar reference tiling+simulator walk
are pinned bit-identical, so the self-check must pass silently on clean
runs (grid and refine) and convert a deliberately perturbed cached table
— the repo's biggest silent-failure risk — into a structured, loud
``IntegrityError``."""
import pytest

from repro.core import INFER_PRESETS
from repro.core.dse import _CONV_TABLE_CACHE, clear_table_caches
from repro.core.layers import ConvLayer, fc, pool, relu
from repro.core.study import IntegrityError, Study, Workload

HW = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        fc("fc", 1, 2048, 100),
    ]


@pytest.fixture(autouse=True)
def _clean():
    clear_table_caches()
    yield
    clear_table_caches()


WL = Workload(net=tuple(tiny_net()))


def _study(**kw):
    return Study(HW, sizes=GRID, bws=GRID, tol=0.5, **kw)


def test_clean_grid_passes():
    res = _study(selfcheck=5).search(WL, 256, 256)
    assert res.best.cycles > 0                 # reached the result at all


def test_clean_refine_passes():
    res = _study(selfcheck=5).search(WL, 256, 256, method="refine")
    assert res.best.cycles > 0


def test_clean_training_grid_passes():
    from repro.core.layers import batch_norm
    net = [_conv("c1", has_bias=False), batch_norm("bn", 16, 16, 1, 32),
           relu("r", 16, 16, 1, 32), fc("fc", 1, 8192, 10)]
    res = _study(selfcheck=3).search(
        Workload(net=tuple(net), training=True), 256, 256)
    assert res.best.cycles > 0


def test_perturbed_table_raises_integrity_error():
    _study().search(WL, 256, 256)              # warm the table cache
    for t in _CONV_TABLE_CACHE.values():       # silent drift, every table
        t.o1[:] = t.o1 + 1000
    with pytest.raises(IntegrityError) as ei:
        _study(selfcheck=3).search(WL, 256, 256)
    err = ei.value
    assert err.workload == WL.label
    assert err.expected != err.actual
    assert len(err.point.sizes_kb) == 4 and len(err.point.bws) == 4
    assert str(err.expected) in str(err) and str(err.actual) in str(err)


def test_selfcheck_perturb_fault_trips_integrity_error():
    """The ``selfcheck_perturb`` fault point shifts the reference cycles
    by ``arg`` inside the comparison itself — proving the self-check
    would trip on a real one-cycle divergence, with no cache poking."""
    from repro.core import faultinject

    faultinject.arm("selfcheck_perturb", times=1, arg=7)
    with pytest.raises(IntegrityError) as ei:
        _study(selfcheck=3).search(WL, 256, 256)
    assert faultinject.fired("selfcheck_perturb") == 1
    assert ei.value.expected - ei.value.actual == 7


def test_selfcheck_off_by_default_misses_perturbation():
    """Documents the trade: without selfcheck the drift is silent —
    exactly why the mode exists."""
    _study().search(WL, 256, 256)
    for t in _CONV_TABLE_CACHE.values():
        t.o1[:] = t.o1 + 1000
    _study().search(WL, 256, 256)              # no raise


def test_sampling_is_deterministic():
    """Same workload + budgets -> same sampled candidates, so a failure
    reproduces run over run; exercised via two identical clean runs."""
    s = _study(selfcheck=4)
    r1 = s.search(WL, 256, 256)
    r2 = s.search(WL, 256, 256)
    assert r1.best == r2.best
