"""Bit-identity of the on-device (jax) DSE grid backends.

The ``backend="jax"`` / ``backend="jax-fused"`` engines must reproduce
the numpy grid engine — and the scalar ``search_reference`` ground
truth — *exactly*: same best/worst points, same within-frac frontiers
(contents and order), same Pareto sets, bitwise-equal cost and score
grids.  Pinned here on the paper's Table VIII setup (16x16 array,
full size/bandwidth lattice) for ResNet-50 inference and training
across the cycles/energy/EDP objectives, plus the regression tests for
the two bugs this backend work surfaced: the NaN-unmasked best-side
argmin in the scored numpy reduction, and int64 grids past 2**31
(which an x64-less jax path would silently truncate)."""
import warnings

import numpy as np
import pytest

from repro.core import INFER_PRESETS, TRAIN_PRESETS
from repro.core import gridax
from repro.core.dse import (BWS, SIZES_KB, DSE_BACKENDS, _pareto_mask,
                            resolve_backend, search_reference)
from repro.core.layers import ConvLayer, fc, pool, relu, tensor_add
from repro.core.objectives import Objective
from repro.core.study import Study, Workload

BUDGET_KB = 2048
BUDGET_BW = 2048
OBJECTIVES = ("cycles", "energy", "edp")
PHASES = ("inference", "training")


def _conv(name, **kw):
    base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16, ow=16,
                kh=3, kw=3, s=1, has_bias=True)
    base.update(kw)
    return ConvLayer(**base)


def tiny_net():
    return [
        _conv("c1"),
        relu("r1", 16, 16, 1, 32),
        _conv("c2", ic=32, oc=32, has_bias=False),
        pool("p1", 8, 8, 1, 32, 2, 2),
        tensor_add("a1", 8, 8, 1, 32),
        fc("fc", 1, 2048, 100),
    ]


def _phase_setup(phase):
    if phase == "training":
        return TRAIN_PRESETS[16], Workload("resnet50", training=True)
    return INFER_PRESETS[16], Workload("resnet50")


@pytest.fixture(scope="module")
def table8():
    """Table VIII searches on both backends, all objectives: the cost
    tables are cached per (hw, net), so each backend's reductions are
    the only per-call work."""
    out = {}
    for phase in PHASES:
        hw, wl = _phase_setup(phase)
        for backend in ("numpy", "jax"):
            study = Study(hw, backend=backend)
            for obj in OBJECTIVES:
                out[phase, backend, obj] = study.search(
                    wl, BUDGET_KB, BUDGET_BW, objective=obj)
    return out


@pytest.mark.parametrize("phase", PHASES)
@pytest.mark.parametrize("obj", OBJECTIVES)
def test_backend_bit_identity(table8, phase, obj):
    a = table8[phase, "numpy", obj]
    b = table8[phase, "jax", obj]
    assert a.best == b.best
    assert a.worst == b.worst
    assert a.improvement == b.improvement
    for frac in (0.05, 0.15, 0.5):
        assert a.within(frac) == b.within(frac)
    assert np.array_equal(a.grid.costs, b.grid.costs)
    if obj == "cycles":
        assert a.grid_scores is None and b.grid_scores is None
    else:
        assert np.array_equal(np.asarray(a.grid_scores, dtype=float),
                              np.asarray(b.grid_scores, dtype=float))
    assert a.pareto() == b.pareto()
    assert a.economic_min_sram() == b.economic_min_sram()
    assert a.economic_min_bw() == b.economic_min_bw()


@pytest.mark.parametrize("phase", PHASES)
def test_scalar_reference_ground_truth(table8, phase):
    hw, wl = _phase_setup(phase)
    ref = search_reference(hw, wl.layers(), BUDGET_KB, BUDGET_BW)
    res = table8[phase, "jax", "cycles"]
    assert res.best == ref.best
    assert res.worst == ref.worst
    assert res.within(0.15) == ref.within(0.15)


def test_training_grid_exceeds_int32(table8):
    """The training grid's cycle counts overflow int32 — the jax
    backend's x64 handling is what keeps them exact (outside
    ``enable_x64`` jnp would silently truncate to int32)."""
    res = table8["training", "jax", "cycles"]
    assert int(res.worst.cycles) > 2 ** 31
    assert res.grid.costs.dtype == np.int64


def test_fused_backend_matches(table8):
    hw, wl = _phase_setup("inference")
    rf = Study(hw, backend="jax-fused").search(wl, BUDGET_KB, BUDGET_BW)
    rn = table8["inference", "numpy", "cycles"]
    assert rf.best == rn.best
    assert rf.worst == rn.worst
    assert rf.within(0.15) == rn.within(0.15)
    assert np.array_equal(rf.grid.costs, rn.grid.costs)


# ---------------------------------------------------------------------------
# GEMM / transformer workloads: same bit-identity pins, LLM front-end
# ---------------------------------------------------------------------------

LLM_GRID = (32, 64, 128, 256)
LLM_BWS = (8, 16, 32, 64)
LLM_KB, LLM_BW = 512, 64


def _llm_setup(phase):
    if phase == "training":
        return TRAIN_PRESETS[16], Workload("qwen3_0_6b", training=True,
                                           seq=64)
    return INFER_PRESETS[16], Workload("qwen3_0_6b", seq=64)


@pytest.fixture(scope="module")
def llm_grid():
    """qwen3-0.6b lowered through the GEMM front-end, both phases, all
    objectives, numpy and jax backends on a reduced lattice."""
    out = {}
    for phase in PHASES:
        hw, wl = _llm_setup(phase)
        for backend in ("numpy", "jax"):
            study = Study(hw, sizes=LLM_GRID, bws=LLM_BWS, backend=backend)
            for obj in OBJECTIVES:
                out[phase, backend, obj] = study.search(
                    wl, LLM_KB, LLM_BW, objective=obj)
    return out


@pytest.mark.parametrize("phase", PHASES)
@pytest.mark.parametrize("obj", OBJECTIVES)
def test_llm_backend_bit_identity(llm_grid, phase, obj):
    a = llm_grid[phase, "numpy", obj]
    b = llm_grid[phase, "jax", obj]
    assert a.best == b.best
    assert a.worst == b.worst
    for frac in (0.05, 0.15, 0.5):
        assert a.within(frac) == b.within(frac)
    assert np.array_equal(a.grid.costs, b.grid.costs)
    if obj != "cycles":
        assert np.array_equal(np.asarray(a.grid_scores, dtype=float),
                              np.asarray(b.grid_scores, dtype=float))
    assert a.pareto() == b.pareto()


@pytest.mark.parametrize("phase", PHASES)
def test_llm_scalar_reference_ground_truth(llm_grid, phase):
    hw, wl = _llm_setup(phase)
    ref = search_reference(hw, wl.layers(), LLM_KB, LLM_BW,
                           sizes=LLM_GRID, bws=LLM_BWS)
    res = llm_grid[phase, "jax", "cycles"]
    assert res.best == ref.best
    assert res.worst == ref.worst
    assert res.within(0.15) == ref.within(0.15)


@pytest.mark.parametrize("phase", PHASES)
def test_llm_fused_backend_matches(llm_grid, phase):
    hw, wl = _llm_setup(phase)
    rf = Study(hw, sizes=LLM_GRID, bws=LLM_BWS,
               backend="jax-fused").search(wl, LLM_KB, LLM_BW)
    rn = llm_grid[phase, "numpy", "cycles"]
    assert rf.best == rn.best
    assert rf.worst == rn.worst
    assert rf.within(0.15) == rn.within(0.15)
    assert np.array_equal(rf.grid.costs, rn.grid.costs)


def test_llm_phase_breakdown_partitions(llm_grid):
    pb = llm_grid["training", "jax", "cycles"].phase_breakdown()
    res = llm_grid["training", "jax", "cycles"]
    assert pb.total == res.best.cycles
    d = pb.as_dict()
    assert d.get("gemm:fwd", 0) > 0 and d.get("gemm:bwd_dx", 0) > 0
    assert d.get("conv:fwd", 0) == 0           # zero-conv workload


# ---------------------------------------------------------------------------
# gridax unit-level identities (synthetic int64 grids past 2**31)
# ---------------------------------------------------------------------------

def _synthetic(seed=7, ns=23, nb=17, s=11, b=13):
    """Duplicate-laden int64 matrices with entries around 2**40, plus
    projection vectors with repeated rows/columns."""
    rng = np.random.default_rng(seed)
    conv = rng.integers(2 ** 39, 2 ** 41, size=(s, b), dtype=np.int64)
    simd = rng.integers(2 ** 33, 2 ** 35, size=(s, b), dtype=np.int64)
    # quantize to force many exact ties, exercising first-occurrence
    conv = (conv // 2 ** 37) * 2 ** 37
    simd = (simd // 2 ** 33) * 2 ** 33
    s3_of = rng.integers(0, s, size=ns)
    b3_of = rng.integers(0, b, size=nb)
    v_of = rng.integers(0, s, size=ns)
    w_of = rng.integers(0, b, size=nb)
    return conv, simd, s3_of, b3_of, v_of, w_of


def _numpy_grid(conv, simd, s3_of, b3_of, v_of, w_of):
    return conv[np.ix_(s3_of, b3_of)] + simd[np.ix_(v_of, w_of)]


def test_outer_add_int64_exact():
    conv, simd, *proj = _synthetic()
    want = _numpy_grid(conv, simd, *proj)
    got = gridax.outer_add(conv, simd, *proj)
    assert got.dtype == np.int64
    assert np.array_equal(got, want)
    assert int(want.max()) > 2 ** 31          # the test would be vacuous


def test_reduce_cycles_first_occurrence_and_frontier():
    conv, simd, *proj = _synthetic()
    want = _numpy_grid(conv, simd, *proj)
    flat = want.ravel()
    mult = 1.15
    [(costs, bi, wi, fm)] = gridax.reduce_cycles_many(
        [conv], [simd], *proj, frontier_mult=mult)
    assert np.array_equal(costs, want)
    assert bi == int(flat.argmin()) and wi == int(flat.argmax())
    assert np.array_equal(fm, flat <= flat[flat.argmin()] * mult)


def test_reduce_cycles_vmap_matches_per_net():
    conv, simd, *proj = _synthetic()
    conv2, simd2, *_ = _synthetic(seed=8)
    many = gridax.reduce_cycles_many([conv, conv2], [simd, simd2], *proj,
                                     frontier_mult=1.15)
    for (c, s), (costs, bi, wi, fm) in zip([(conv, simd), (conv2, simd2)],
                                           many):
        flat = _numpy_grid(c, s, *proj).ravel()
        assert bi == int(flat.argmin()) and wi == int(flat.argmax())
        assert np.array_equal(fm, flat <= flat[flat.argmin()] * 1.15)


def test_fused_minmax_matches_numpy():
    conv, simd, *proj = _synthetic()
    flat = _numpy_grid(conv, simd, *proj).ravel()
    bi, wi = gridax.fused_minmax(conv, simd, *proj, interpret=True)
    assert bi == int(flat.argmin())
    assert wi == int(flat.argmax())


def test_pareto_mask_matches_sequential():
    rng = np.random.default_rng(3)
    cycles = rng.integers(1, 50, size=400).astype(np.int64) * 2 ** 28
    energy = rng.integers(1, 50, size=400).astype(float)
    assert np.array_equal(gridax.pareto_mask(cycles, energy),
                          _pareto_mask(cycles, energy))


def test_within_mask_promotion():
    vals = np.array([2 ** 40, 2 ** 40 + 1, 2 ** 40 + 2], dtype=np.int64)
    limit = float(2 ** 40 + 1)
    assert np.array_equal(gridax.within_mask(vals, limit),
                          vals <= limit)


# ---------------------------------------------------------------------------
# NaN-masking regression (the scored-reduction bugfix)
# ---------------------------------------------------------------------------

class _NanBait(Objective):
    """Scores cycles but poisons the true-best candidate with NaN: the
    old numpy reduction left NaN unmasked on the best side, so argmin
    returned the NaN position instead of the best *feasible* one."""

    name = "nan_bait"
    needs_energy = False

    def score(self, m):
        s = np.asarray(m.cycles, dtype=float).copy()
        flat = s.ravel()
        flat[flat.argmin()] = np.nan
        flat[flat.argmax()] = np.nan       # nor may NaN win the worst side
        return flat.reshape(s.shape)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_nan_scores_never_win(backend):
    hw = INFER_PRESETS[16]
    res = Study(hw, sizes=(32, 64, 128, 256), bws=(32, 64, 128, 256),
                backend=backend).search(
        Workload(tiny_net()), 256, 256, objective=_NanBait())
    scores = np.asarray(res.grid_scores, dtype=float).ravel()
    n_bw = len(res.grid.bw_tuples)

    def flat(point):
        r, c = res.grid.locate(point)
        return r * n_bw + c

    assert np.isnan(scores).sum() >= 1
    assert np.isfinite(scores[flat(res.best)])
    assert np.isfinite(scores[flat(res.worst)])
    assert scores[flat(res.best)] == np.nanmin(scores)
    assert scores[flat(res.worst)] == np.nanmax(scores)


def test_nan_scores_identical_across_backends():
    hw = INFER_PRESETS[16]
    kw = dict(sizes=(32, 64, 128, 256), bws=(32, 64, 128, 256))
    rn = Study(hw, backend="numpy", **kw).search(
        Workload(tiny_net()), 256, 256, objective=_NanBait())
    rj = Study(hw, backend="jax", **kw).search(
        Workload(tiny_net()), 256, 256, objective=_NanBait())
    assert rn.best == rj.best and rn.worst == rj.worst


# ---------------------------------------------------------------------------
# backend selection plumbing
# ---------------------------------------------------------------------------

def test_resolve_backend_explicit():
    assert resolve_backend("numpy") == "numpy"
    assert resolve_backend("jax") == "jax"
    assert resolve_backend("jax-fused") == "jax-fused"
    with pytest.raises(ValueError, match="unknown DSE backend"):
        resolve_backend("nope")
    assert set(DSE_BACKENDS) == {"numpy", "jax", "jax-fused"}


def test_backend_env_var(monkeypatch):
    monkeypatch.setenv("REPRO_DSE_BACKEND", "jax")
    assert resolve_backend(None) == "jax"
    assert Study(INFER_PRESETS[16]).backend == "jax"
    # explicit argument beats the environment
    assert Study(INFER_PRESETS[16], backend="numpy").backend == "numpy"


def test_backend_env_var_garbage_warns(monkeypatch):
    monkeypatch.setenv("REPRO_DSE_BACKEND", "warp-drive")
    with pytest.warns(RuntimeWarning, match="REPRO_DSE_BACKEND"):
        assert resolve_backend(None) == "numpy"


def test_refine_front_end_tolerates_backend():
    """A Study with a device backend still runs method="refine" — the
    local search declares (and ignores) the forwarded backend."""
    hw = INFER_PRESETS[16]
    res = Study(hw, sizes=(32, 64, 128, 256), bws=(32, 64, 128, 256),
                backend="jax").search(Workload(tiny_net()), 256, 256,
                                      method="refine")
    assert res.best.cycles > 0
