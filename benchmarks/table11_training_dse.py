"""Training-workload DSE (the sweep the paper stops short of: Table VIII
is inference-only).  ResNet-18/50 *training* graphs — forward + backward +
updates, batch 32 — swept at the Table VIII budgets on the matching
training presets, via one ``search_many(training=True)`` per budget so
the per-size cost tables are built once and shared across the networks
(and, through the process-lifetime table cache, across budgets).

Per network the sweep reports the best allocation, the worst/best
improvement, and the phase-resolved shares at the optimum (conv fwd/dX/dW
vs SIMD fwd/bwd); a companion inference sweep quantifies the frontier
shift (how the optimal allocation moves toward VMem size and bandwidth
when the workload switches to training).

The paper's headline 59.5% non-conv share for ResNet-50 training on a
64x64 array is emitted on the ``claim`` row: this model brackets it —
68.6% on the static HT3 allocation vs 56.1% at the DSE optimum (the
16x16/32x32 static shares match the paper within ~2pp, Table VI) — and
``tests/test_training_claim.py`` pins both endpoints at +/-1pp.
"""
from __future__ import annotations

from typing import List

from repro.core import TRAIN_PRESETS
from repro.core.dse import (frontier_shift, phase_profile, search_many,
                            table_cache_stats)
from repro.core.networks import resnet18, resnet50

from .common import row, timed

BUDGETS = {16: 512, 32: 1024, 64: 2048}       # Table VIII (kB, bits/cycle)
PAPER_STATIC_SHARE = {16: 41.9, 32: 56.6, 64: 59.5}   # Table VI training %
BATCH = 32                                    # paper Sec. VII-A


def run() -> List[str]:
    rows: List[str] = []
    nets_train = {"resnet18": resnet18(BATCH), "resnet50": resnet50(BATCH)}
    nets_infer = {"resnet18": resnet18(1, bn=False),
                  "resnet50": resnet50(1, bn=False)}
    for jk, budget in BUDGETS.items():
        hw = TRAIN_PRESETS[jk]
        before = table_cache_stats()
        us, results = timed(search_many, hw, nets_train, budget, budget,
                            training=True)
        inf_results = search_many(hw, nets_infer, budget, budget)
        after = table_cache_stats()
        rows.append(row(
            f"table11.all.{jk}x{jk}", us,
            f"networks={len(results)};budget={budget}kB/{budget}bpc;"
            f"conv_tables_built={after['conv_misses'] - before['conv_misses']};"
            f"conv_tables_reused={after['conv_hits'] - before['conv_hits']}"))
        for name, res in results.items():
            pb = res.phase_breakdown()
            shift = frontier_shift(inf_results[name], res)
            rows.append(row(
                f"table11.{name}.train.{jk}x{jk}", 0.0,
                f"improvement={res.improvement:.2f}x;"
                f"opt_sizes={'/'.join(map(str, res.best.sizes_kb))}kB;"
                f"opt_bw={'/'.join(map(str, res.best.bws))};"
                f"nonconv={pb.nonconv_share * 100:.1f}%;"
                f"bwd={pb.bwd_share * 100:.1f}%;"
                f"vmem_share={shift['vmem_share_inf'] * 100:.0f}->"
                f"{shift['vmem_share_trn'] * 100:.0f}%;"
                f"bw_v_share={shift['bw_v_share_inf'] * 100:.0f}->"
                f"{shift['bw_v_share_trn'] * 100:.0f}%;"
                f"frontier_overlap={shift['frontier_overlap'] * 100:.0f}%"))
        # the paper's static-allocation share (Table VI) vs this model's,
        # on the preset (HT1/2/3) configuration and at the DSE optimum
        us_p, prof = timed(phase_profile, hw, resnet50(BATCH), training=True)
        pb_opt = results["resnet50"].phase_breakdown()
        rows.append(row(
            f"table11.resnet50.claim.{jk}x{jk}", us_p,
            f"nonconv_static={prof.nonconv_share * 100:.1f}%;"
            f"nonconv_opt={pb_opt.nonconv_share * 100:.1f}%;"
            f"paper={PAPER_STATIC_SHARE[jk]}%"))
    return rows
