"""Paper Fig. 5: SimDIT's tile-granular stall model vs the No-Stall and
Simplified baselines, on representative ResNet-50 Conv layers (two from
inference, two from the training backward pass).

The paper reports underestimation up to 80.7% (No-Stall) and 46.7%
(Simplified); derived column reports each baseline's cycle count normalized
to SimDIT (1.0 = no underestimation)."""
from __future__ import annotations

from typing import List

from repro.core import HI3, HT3
from repro.core.backward import dw_conv, dx_conv
from repro.core.conv_model import simulate_conv
from repro.core.networks import resnet50

from .common import row, timed


def _gap(hw, layer, baseline: str) -> float:
    full = simulate_conv(hw, layer).total_cycles
    alt = simulate_conv(hw, layer, stall_model=baseline).total_cycles
    return 1 - alt / full


def _pick_layers():
    """Representative layers, chosen like the paper's: per phase, the conv
    with the largest No-Stall gap and the conv with the largest Simplified
    gap (the Simplified gap only opens when tile segments are heterogeneous
    across the Table IV cases, so picking argmax exhibits the effect)."""
    inf = [l for l in resnet50(1, bn=False) if hasattr(l, "kh")]
    trn = [l for l in resnet50(32) if hasattr(l, "kh")]
    bwd = [dx_conv(l) for l in trn] + [dw_conv(l) for l in trn]
    layer1 = max(inf, key=lambda l: _gap(HI3, l, "no_stall"))
    layer2 = max(inf, key=lambda l: _gap(HI3, l, "simplified"))
    layer3 = max(bwd, key=lambda l: _gap(HT3, l, "no_stall"))
    layer4 = max(bwd, key=lambda l: _gap(HT3, l, "simplified"))
    return [("Layer1", HI3, layer1), ("Layer2", HI3, layer2),
            ("Layer3", HT3, layer3), ("Layer4", HT3, layer4)]


def run() -> List[str]:
    rows: List[str] = []
    for name, hw, layer in _pick_layers():
        us, full = timed(simulate_conv, hw, layer)
        nostall = simulate_conv(hw, layer, stall_model="no_stall")
        simpl = simulate_conv(hw, layer, stall_model="simplified")
        base = full.total_cycles
        rows.append(row(
            f"fig5.{name}", us,
            f"simdit=1.0;no_stall={nostall.total_cycles / base:.3f};"
            f"simplified={simpl.total_cycles / base:.3f};"
            f"underest_nostall={(1 - nostall.total_cycles / base) * 100:.1f}%;"
            f"underest_simplified={(1 - simpl.total_cycles / base) * 100:.1f}%"))
    return rows
