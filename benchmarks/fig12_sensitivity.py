"""Paper Fig. 12: sensitivity of ResNet-50 inference cycles (64x64 array)
to each SRAM size / bandwidth parameter around the optimal point.

Paper's finding: weak sensitivity to SRAM sizes (worst ~1.23x for the
smallest IBuf), strong sensitivity to bandwidths (up to ~11.4x for the
smallest BW_i)."""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS
from repro.core.dse import search, sensitivity
from repro.core.hardware import KB
from repro.core.networks import resnet50

from .common import row, timed


def run() -> List[str]:
    hw = INFER_PRESETS[64]
    net = resnet50(1, bn=False)
    res = search(hw, net, 2048, 2048)
    b = res.best
    hw_opt = hw.replace(wbuf=b.sizes_kb[0] * KB, ibuf=b.sizes_kb[1] * KB,
                        obuf=b.sizes_kb[2] * KB, vmem=b.sizes_kb[3] * KB,
                        bw_w=b.bws[0], bw_i=b.bws[1], bw_o=b.bws[2],
                        bw_v=b.bws[3])
    us, sens = timed(sensitivity, hw_opt, net)
    rows: List[str] = []
    for param, curve in sens.items():
        worst = max(curve.values())
        sat = min(v for v in curve if curve[v] <= 1.05)
        rows.append(row(f"fig12.{param}", us / len(sens),
                        f"worst={worst:.2f}x;saturates_at={sat}"))
    return rows
