"""LLM workloads through the systolic DSE engine (GEMM front-end).

Transformer configs lower to (GEMM + SIMD) graphs and sweep the Table
VIII 16x16 budget: per workload the GEMM-vs-non-GEMM cycle split at the
optimum (the paper's conv-vs-non-conv question asked of attention/MLP
workloads), and the buffer-allocation shift against ResNet-50 at the
same budget — how much of the SRAM/bandwidth budget moves from the
array-side buffers to VMem when the workload's non-GEMM fraction grows.
"""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS, TRAIN_PRESETS
from repro.core.study import Study, Workload

from .common import row, timed

JK, BUDGET = 16, 512          # Table VIII smallest array / budget
SEQ = 512


def _shares(res) -> str:
    pb = res.phase_breakdown()
    t = pb.total
    out = (f"improvement={res.improvement:.2f}x;"
           f"opt_sizes={'/'.join(map(str, res.best.sizes_kb))}kB;"
           f"opt_bw={'/'.join(map(str, res.best.bws))};"
           f"gemm={pb.gemm_cycles / t * 100:.1f}%;"
           f"nongemm={pb.nonconv_cycles / t * 100:.1f}%")
    if pb.bwd_cycles:
        out += f";bwd={pb.bwd_share * 100:.1f}%"
    return out


def _vmem_alloc(res) -> tuple:
    sz, bw = res.best.sizes_kb, res.best.bws
    return sz[3] / sum(sz), bw[3] / sum(bw)


def run() -> List[str]:
    rows: List[str] = []

    hw_i = INFER_PRESETS[JK]
    study_i = Study(hw_i)
    us, llm_i = timed(study_i.search_many,
                      {"qwen3_0_6b": Workload("qwen3_0_6b", seq=SEQ),
                       "gemma3_27b": Workload("gemma3_27b", seq=SEQ)},
                      BUDGET, BUDGET)
    for name, res in llm_i.items():
        rows.append(row(f"llm_dse.{name}.infer.{JK}x{JK}",
                        us / len(llm_i), _shares(res)))

    hw_t = TRAIN_PRESETS[JK]
    us_t, llm_t = timed(Study(hw_t).search,
                        Workload("qwen3_0_6b", training=True, seq=SEQ),
                        BUDGET, BUDGET)
    rows.append(row(f"llm_dse.qwen3_0_6b.train.{JK}x{JK}", us_t,
                    _shares(llm_t)))

    # allocation shift vs the CNN baseline at the same budget: the LLM
    # optimum re-weights VMem capacity/bandwidth by its non-GEMM share
    us_r, cnn = timed(study_i.search, Workload("resnet50"), BUDGET, BUDGET)
    cv, cb = _vmem_alloc(cnn)
    qv, qb = _vmem_alloc(llm_i["qwen3_0_6b"])
    tv, tb = _vmem_alloc(llm_t)
    rows.append(row(
        f"llm_dse.alloc_shift.{JK}x{JK}", us_r,
        f"vmem_share=resnet50:{cv * 100:.0f}%/qwen3:{qv * 100:.0f}%/"
        f"qwen3_train:{tv * 100:.0f}%;"
        f"bw_v_share=resnet50:{cb * 100:.0f}%/qwen3:{qb * 100:.0f}%/"
        f"qwen3_train:{tb * 100:.0f}%"))
    return rows
