"""Paper Table VIII: optimal vs worst-case resource allocation for ResNet-50
inference across array sizes, budgets (SRAM kB, bits/cycle) =
(512,512) / (1024,1024) / (2048,2048) / (4096,4096)."""
from __future__ import annotations

from typing import List

from repro.core import HardwareSpec, INFER_PRESETS
from repro.core.dse import search
from repro.core.networks import resnet50

from .common import row, timed

BUDGETS = {16: 512, 32: 1024, 64: 2048, 128: 4096}
PAPER = {16: 9.64, 32: 14.45, 64: 18.43, 128: 25.55}


def _hw(jk: int) -> HardwareSpec:
    base = INFER_PRESETS.get(jk, INFER_PRESETS[64])
    return base.replace(name=f"dse{jk}", J=jk, K=jk)


def run(network=resnet50, tag: str = "table8.resnet50") -> List[str]:
    net = network(1, bn=False)
    rows: List[str] = []
    for jk, budget in BUDGETS.items():
        us, res = timed(search, _hw(jk), net, budget, budget)
        rows.append(row(
            f"{tag}.{jk}x{jk}", us,
            f"improvement={res.improvement:.2f}x;paper={PAPER[jk]}x;"
            f"cands={res.n_candidates};"
            f"opt_sizes={'/'.join(map(str, res.best.sizes_kb))}kB;"
            f"opt_bw={'/'.join(map(str, res.best.bws))}"))
    return rows
