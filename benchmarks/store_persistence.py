"""Persistent table store smoke: cold-store vs warm-store wall time for a
Table VIII style sweep (ResNet-50 inference, one budget per array size).

The cold pass builds every ``ConvTable``/``SimdTable`` and persists it;
the warm pass drops the in-memory L1 and re-runs the same sweep against
the store alone.  Asserted, not just reported: the warm sweep rebuilds
*zero* tables (``table_cache_stats()``: store hits only, no misses, no
builds) and its results are bit-identical to the cold pass.  The derived
column reports the cold/warm speedup plus the raw hit counters — the
headline number for the ROADMAP's "DSE-as-a-service" persistence story.
"""
from __future__ import annotations

import tempfile
from typing import List

from repro.core import HardwareSpec, INFER_PRESETS
from repro.core.dse import clear_table_caches, table_cache_stats
from repro.core.networks import resnet50
from repro.core.study import Study, Workload

from .common import row, timed

BUDGETS = {16: 512, 64: 2048}         # smoke subset of the Table VIII axis


def _hw(jk: int) -> HardwareSpec:
    base = INFER_PRESETS.get(jk, INFER_PRESETS[64])
    return base.replace(name=f"dse{jk}", J=jk, K=jk)


def run(tag: str = "store_persistence") -> List[str]:
    wl = Workload(net="resnet50")
    rows: List[str] = []
    with tempfile.TemporaryDirectory(prefix="repro-store-") as root:
        for jk, budget in BUDGETS.items():
            study = Study(_hw(jk), store=root)

            clear_table_caches()
            cold_us, cold = timed(study.search, wl, budget, budget)
            cold_st = table_cache_stats()
            assert cold_st["conv_builds"] + cold_st["simd_builds"] > 0

            clear_table_caches()      # kill the L1; the store survives
            warm_us, warm = timed(study.search, wl, budget, budget)
            warm_st = table_cache_stats()
            assert warm_st["conv_builds"] == 0, warm_st
            assert warm_st["simd_builds"] == 0, warm_st
            assert warm_st["store_misses"] == 0, warm_st
            assert warm_st["store_hits"] > 0, warm_st
            assert (warm.grid.costs == cold.grid.costs).all()
            assert warm.best == cold.best

            rows.append(row(
                f"{tag}.{jk}x{jk}", warm_us,
                f"cold_us={cold_us:.0f};speedup={cold_us / warm_us:.2f}x;"
                f"store_hits={warm_st['store_hits']};"
                f"rebuilds={warm_st['conv_builds'] + warm_st['simd_builds']};"
                f"best={warm.best.cycles}"))
    clear_table_caches()
    return rows
