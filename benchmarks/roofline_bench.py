"""Roofline benchmark: reads the dry-run artifacts produced by
``repro.launch.dryrun`` (artifacts/dryrun/*.json) and reports the three
roofline terms per (arch x shape) cell. Falls back to a note if the
dry-run has not been executed yet."""
from __future__ import annotations

import json
import pathlib
from typing import List

from .common import row

ARTIFACTS = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run() -> List[str]:
    rows: List[str] = []
    if not ARTIFACTS.exists():
        return [row("roofline.missing", 0.0,
                    "run `PYTHONPATH=src python -m repro.launch.dryrun --all` first")]
    for p in sorted(ARTIFACTS.glob("*.json")):
        d = json.loads(p.read_text())
        r = d.get("roofline", {})
        if not r:
            continue
        rows.append(row(
            f"roofline.{d['arch']}.{d['shape']}.{d['mesh']}",
            d.get("compile_us", 0.0),
            f"bound={r['bound']};t_compute={r['t_compute_s']:.3e}s;"
            f"t_memory={r['t_memory_s']:.3e}s;"
            f"t_collective={r['t_collective_s']:.3e}s;"
            f"frac={r['roofline_fraction']:.3f};"
            f"model_vs_hlo={r.get('model_flops_ratio', 0):.3f}"))
    return rows or [row("roofline.empty", 0.0, "no artifacts found")]
