"""DSE-as-a-service smoke: N coalesced clients vs N sequential searches.

The serving claim is economic: a burst of concurrent queries answered
through one ``DSEService`` shares grouped ``search_many`` dispatches and
union-of-shape table builds, so it must build strictly fewer cost tables
(and take less wall time) than the same N queries issued as isolated
cold searches.  Three passes over one mixed burst (2 networks x 2
budgets x 2 objectives, inference + training):

  * ``seq_cold``  — every query a fresh cold ``Study.search`` (L1
    cleared between queries, no store): the "N independent scripts"
    baseline.
  * ``svc_cold``  — the same burst submitted before the dispatcher
    starts, served coalesced against an empty persistent store.
  * ``svc_warm``  — the burst again, L1 dropped, store warm: serving
    steady-state (store hits only, zero rebuilds).

Asserted, not just reported: every service response bit-identical to its
sequential reference, cold-service builds < sequential builds,
coalescing ratio > 1, and the warm pass rebuilds nothing.  The derived
columns carry the headline numbers (speedup, coalescing ratio, build
counts, p95 latency) for the bench-trajectory artifact.
"""
from __future__ import annotations

import tempfile
from typing import List

from repro.core import INFER_PRESETS
from repro.core.dse import clear_table_caches, table_cache_stats
from repro.core.layers import ConvLayer, batch_norm, fc, relu
from repro.core.study import Study, Workload
from repro.serve import DSEClient, DSERequest, DSEService

from .common import row, timed

HW16 = INFER_PRESETS[16]
GRID = (32, 64, 128, 256)


def _train_net():
    def conv(name, **kw):
        base = dict(name=name, n=1, ic=16, ih=16, iw=16, oc=32, oh=16,
                    ow=16, kh=3, kw=3, s=1, has_bias=False)
        base.update(kw)
        return ConvLayer(**base)
    return (conv("c1"), batch_norm("c1.bn", 16, 16, 1, 32),
            relu("c1.relu", 16, 16, 1, 32), conv("c2", ic=32, oc=32),
            fc("fc", 1, 2048, 10))


def _requests() -> List[DSERequest]:
    train = Workload(net=_train_net(), training=True, name="tiny-train")
    return [
        DSERequest("resnet18", 512, 256, objective="cycles"),
        DSERequest("resnet18", 256, 256, objective="edp"),
        DSERequest("alexnet", 512, 256, objective="edp"),
        DSERequest("alexnet", 256, 256, objective="cycles"),
        DSERequest(train, 512, 256, objective="cycles"),
        DSERequest(train, 256, 256, objective="edp"),
    ]


def _study(store=None) -> Study:
    return Study(HW16, sizes=GRID, bws=GRID, tol=0.5, store=store)


def _builds() -> int:
    s = table_cache_stats()
    return sum(int(s[f"{k}_builds"]) for k in ("conv", "simd", "gemm"))


def _serve_burst(store: str):
    """Submit the whole burst before the dispatcher starts (maximal
    coalescing, deterministic), then gather; returns (us, results, stats,
    builds_delta)."""
    reqs = _requests()
    svc = DSEService(_study(store), autostart=False, max_batch=len(reqs))
    tickets = DSEClient(svc).submit_burst(reqs)
    b0 = _builds()

    def serve():
        svc.start()
        return [t.result(timeout=600) for t in tickets]

    us, results = timed(serve)
    svc.close()
    return us, results, svc.stats(), _builds() - b0


def run(tag: str = "dse_service") -> List[str]:
    rows: List[str] = []
    reqs = _requests()

    # -- seq_cold: N isolated cold searches (the no-service baseline) --
    seq_results, seq_us, seq_builds = [], 0.0, 0
    for r in reqs:
        clear_table_caches()
        us, res = timed(_study().search, r.workload, r.size_budget_kb,
                        r.bw_budget, objective=r.objective)
        seq_us += us
        seq_builds += _builds()
        seq_results.append(res)
    rows.append(row(f"{tag}.seq_cold", seq_us,
                    f"queries={len(reqs)};builds={seq_builds};"
                    f"per_query_us={seq_us / len(reqs):.0f}"))

    with tempfile.TemporaryDirectory(prefix="repro-serve-") as store:
        # -- svc_cold: the same burst, coalesced, empty store ----------
        clear_table_caches()
        svc_us, svc_results, st, svc_builds = _serve_burst(store)
        assert st.completed == len(reqs) and st.failed == 0, st.summary()
        assert st.coalescing_ratio > 1.0, st.summary()
        assert svc_builds < seq_builds, (svc_builds, seq_builds)
        for mine, ref in zip(svc_results, seq_results):
            assert mine.best == ref.best
            assert (mine.grid.costs == ref.grid.costs).all()
        rows.append(row(
            f"{tag}.svc_cold", svc_us,
            f"speedup={seq_us / svc_us:.2f}x;"
            f"coalescing={st.coalescing_ratio:.2f}x;"
            f"builds={svc_builds}_vs_seq{seq_builds};"
            f"p95_ms={st.latency_p95_s * 1e3:.1f}"))

        # -- svc_warm: L1 dropped, store warm: lookups only ------------
        clear_table_caches()
        warm_us, warm_results, wst, warm_builds = _serve_burst(store)
        assert warm_builds == 0, table_cache_stats()
        assert wst.store_hit_rate > 0.0, wst.summary()
        for mine, ref in zip(warm_results, seq_results):
            assert mine.best == ref.best
            assert (mine.grid.costs == ref.grid.costs).all()
        rows.append(row(
            f"{tag}.svc_warm", warm_us,
            f"speedup_vs_seq={seq_us / warm_us:.2f}x;"
            f"speedup_vs_cold={svc_us / warm_us:.2f}x;"
            f"rebuilds={warm_builds};"
            f"store_hit_rate={wst.store_hit_rate:.2f}"))
    clear_table_caches()
    return rows
