"""Paper Table X + Fig. 11: economic design points for ResNet-50 inference
on a 64x64 array — the design landscape within 15% of the optimum, and the
minimum-SRAM / minimum-bandwidth points in it."""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS
from repro.core.dse import search
from repro.core.networks import resnet50

from .common import row, timed


def run() -> List[str]:
    hw = INFER_PRESETS[64]
    net = resnet50(1, bn=False)
    us, res = timed(search, hw, net, 2048, 2048, lower_bound=False)
    eco_s = res.economic_min_sram()
    eco_b = res.economic_min_bw()
    best = res.best
    rows = [
        row("table10.optimal", us,
            f"sram={best.total_size_kb}kB;bw={best.total_bw};penalty=0%"),
        row("table10.min_sram", 0.0,
            f"sram={eco_s.total_size_kb}kB;bw={eco_s.total_bw};"
            f"penalty={(eco_s.cycles / best.cycles - 1) * 100:.1f}%;"
            f"sram_saving={(1 - eco_s.total_size_kb / best.total_size_kb) * 100:.1f}%;"
            f"paper=448kB/13.1%"),
        row("table10.min_bw", 0.0,
            f"sram={eco_b.total_size_kb}kB;bw={eco_b.total_bw};"
            f"penalty={(eco_b.cycles / best.cycles - 1) * 100:.1f}%;"
            f"paper=1792bits/14.6%"),
        row("fig11.landscape", 0.0,
            f"points_within_15pct={len(res.points)};"
            f"cands={res.n_candidates}"),
    ]
    return rows
