"""Paper Table VII: same analysis as Table VI for ResNet-18."""
from __future__ import annotations

from typing import List

from . import table6_resnet50


def run() -> List[str]:
    rows = table6_resnet50.run(network="resnet18")
    return [r.replace("table6.", "table7.") for r in rows]
