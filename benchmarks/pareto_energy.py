"""Cycles-vs-energy Pareto landscape of the Table VIII DSE (Sec. VI + VII-B).

For ResNet-50 inference and training at every Table VIII budget, one
exhaustive grid search prices every candidate in both metrics (the energy
tensors ride along in the cost tables) and emits:

  * the 2-D (cycles, energy) Pareto-frontier size vs the legacy
    within-15%-of-min-cycles band size,
  * the energy delta between the min-cycles and the min-energy
    configurations — what a latency-only DSE leaves on the table — and
    the cycle premium the min-energy configuration pays,
  * the min-EDP point's position between the two.

Uses the objective-first ``Study`` API; the per-budget searches share the
process-lifetime table cache, so the energy/EDP reductions after the
cycles sweep rebuild nothing.
"""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS, TRAIN_PRESETS, Study, Workload
from repro.core.dse import clear_table_caches
from repro.core.tiling import clear_tiling_caches

from .common import row, timed

BUDGETS = {16: 512, 32: 1024, 64: 2048, 128: 4096}


def _hw(presets, jk: int):
    base = presets.get(jk, presets[64])
    return base.replace(name=f"pareto{jk}", J=jk, K=jk)


def run(tag: str = "pareto_energy.resnet50") -> List[str]:
    rows: List[str] = []
    for mode, presets, training in (("inference", INFER_PRESETS, False),
                                    ("training", TRAIN_PRESETS, True)):
        wl = Workload("resnet50", training=training)
        for jk, budget in BUDGETS.items():
            clear_tiling_caches()
            clear_table_caches()
            study = Study(_hw(presets, jk))
            us, cyc = timed(study.search, wl, budget, budget)
            us_e, eng = timed(study.search, wl, budget, budget,
                              objective="energy")
            edp = study.search(wl, budget, budget, objective="edp")
            front = cyc.pareto()
            # both single-metric optima are represented (on an exact tie
            # the frontier keeps the tied point with the better other
            # metric, so compare achieved values, not point identity)
            assert min(p.cycles for p in front) == cyc.best.cycles
            assert min(cyc.energy_of(p) for p in front) == eng.best_score
            e_at_min_cycles = cyc.energy_of(cyc.best)
            e_min = eng.best_score
            energy_saving = e_at_min_cycles / e_min
            cycle_premium = eng.best.cycles / cyc.best.cycles
            rows.append(row(
                f"{tag}.{mode}.{jk}x{jk}", us + us_e,
                f"pareto={len(front)};band15={len(cyc.points)};"
                f"minE_vs_minC_energy={energy_saving:.4f}x;"
                f"minE_cycle_premium={cycle_premium:.4f}x;"
                f"edp_opt_cycles={edp.best.cycles};"
                f"minC={'/'.join(map(str, cyc.best.sizes_kb))}kB;"
                f"minE={'/'.join(map(str, eng.best.sizes_kb))}kB"))
    return rows
