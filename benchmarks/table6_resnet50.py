"""Paper Table VI: non-Conv share of runtime/energy/accesses for ResNet-50,
training (HT1-3, batch 32) and inference (HI1-3, batch 1)."""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS, TRAIN_PRESETS, simulate

from .common import row, timed

PAPER = {  # (mode, JxK) -> (nonconv_runtime_%, nonconv_energy_%)
    ("training", 16): (41.9, 50.3), ("training", 32): (56.6, 52.3),
    ("training", 64): (59.5, 49.4),
    ("inference", 16): (30.1, 33.2), ("inference", 32): (41.6, 40.3),
    ("inference", 64): (49.3, 38.2),
}


def run(network: str = "resnet50") -> List[str]:
    rows: List[str] = []
    for mode, presets in (("training", TRAIN_PRESETS),
                          ("inference", INFER_PRESETS)):
        for jk, hw in presets.items():
            us, rep = timed(simulate, hw, network, mode)
            e = rep.energy(hw)
            nc_rt = rep.nonconv_fraction("cycles") * 100
            nc_on = rep.nonconv_fraction("sram") * 100
            nc_off = rep.nonconv_fraction("dram") * 100
            nc_e = rep.nonconv_energy_fraction(hw) * 100
            ref = PAPER.get((mode, jk))
            derived = (f"nonconv_runtime={nc_rt:.1f}%;onchip={nc_on:.1f}%;"
                       f"offchip={nc_off:.1f}%;energy={nc_e:.1f}%;"
                       f"P={e['P_avg']:.2f}W;t={e['runtime_s']:.4f}s"
                       + (f";paper_runtime={ref[0]}%;paper_energy={ref[1]}%"
                          if ref and network == "resnet50" else ""))
            rows.append(row(f"table6.{network}.{mode}.{jk}x{jk}", us, derived))
    return rows
