"""DSE throughput micro-benchmark: candidates evaluated per second for the
legacy scalar double loop (``search_reference``) vs the tensorized grid
engine (``search``), on the Table VIII ResNet-50 setup.

The legacy loop is timed on the smaller budgets only (it is the slow path
this benchmark exists to track); the tensorized engine is additionally
timed on the full Table VIII budgets.  Tiling caches are cleared before
every timed run so neither path inherits the other's warm state.
"""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS
from repro.core.dse import clear_table_caches, search, search_reference
from repro.core.networks import resnet50
from repro.core.tiling import clear_tiling_caches

from .common import row, timed


def _clear_caches() -> None:
    """Cold-start both the tiling and the process-lifetime table caches so
    neither timed path inherits warm state."""
    clear_tiling_caches()
    clear_table_caches()

COMPARE_BUDGETS = (512, 1024, 2048)  # legacy + tensorized, equivalence-checked
SCALE_BUDGETS = (4096,)              # tensorized only


def run() -> List[str]:
    hw = INFER_PRESETS[64]
    net = resnet50(1, bn=False)
    rows: List[str] = []
    for budget in COMPARE_BUDGETS:
        _clear_caches()
        us_ref, ref = timed(search_reference, hw, net, budget, budget)
        _clear_caches()
        us_new, res = timed(search, hw, net, budget, budget)
        n = res.n_candidates
        assert ref.best == res.best and ref.worst == res.worst, budget
        rows.append(row(
            f"dse_scaling.loop.{budget}", us_ref,
            f"cands={n};cands_per_s={n / (us_ref / 1e6):.0f}"))
        rows.append(row(
            f"dse_scaling.tensor.{budget}", us_new,
            f"cands={n};cands_per_s={n / (us_new / 1e6):.0f};"
            f"speedup={us_ref / us_new:.1f}x"))
    for budget in SCALE_BUDGETS:
        _clear_caches()
        us_new, res = timed(search, hw, net, budget, budget)
        n = res.n_candidates
        rows.append(row(
            f"dse_scaling.tensor.{budget}", us_new,
            f"cands={n};cands_per_s={n / (us_new / 1e6):.0f}"))
    return rows
