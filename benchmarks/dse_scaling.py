"""DSE throughput micro-benchmark: candidates evaluated per second for the
legacy scalar double loop (``search_reference``) vs the tensorized grid
engine (``search``), on the Table VIII ResNet-50 setup — plus the two
build phases upstream of the grid reduction:

  * ``tiling_build``   — the greedy tiling derivation for every (size
    triple x conv shape) and (vmem x SIMD shape) of the table8 grid:
    scalar reference walk vs the vectorized batch kernels (the dominant
    serial cost of a cold sweep since PR 1 tensorized everything
    downstream of it).  The batch results are asserted elementwise
    bit-identical to the scalar.
  * ``table_build``    — the full serial (workers=0) cost-table build for
    the same grid: the legacy per-triple ``ConvTable`` loop over
    scalar-derived tilings vs ``batch_build_conv_tables``'s one
    vectorized pass per layer.  Tables are asserted field-identical, and
    the speedup is asserted >= 3x (the PR 5 acceptance bar).
  * ``grid_eval``      — the grid *reductions* on warm tables: the host
    numpy tensor path vs the on-device backends (``repro.core.gridax``
    jit/vmap, and the fused Pallas outer-add+argmin kernel), plus the
    sequential host Pareto walk vs the vectorized device mask.  Every
    backend's best/worst/frontier/Pareto is asserted bit-identical; the
    >= 5x backend speedup bar is asserted on real accelerators only (on
    CPU the int64 reductions are memory-bound and XLA's multi-key sort
    trails numpy's, so CI asserts correctness in interpret mode and an
    absolute >10M cands/s floor instead).

Tiling and table caches are cleared before every timed run so no path
inherits another's warm state (``grid_eval`` deliberately runs warm:
it times reductions, not builds).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import INFER_PRESETS
from repro.core.dse import (_GridEngine, _project, _tuples, ConvTable,
                            _conv_table_key, _CONV_TABLE_CACHE,
                            batch_build_conv_tables, clear_table_caches,
                            search, search_reference)
from repro.core.hardware import KB
from repro.core.networks import resnet50
from repro.core.tiling import (_conv_hw_key, _conv_layer_key, _simd_hw_key,
                               _simd_layer_key, _CONV_TILING_CACHE,
                               clear_tiling_caches,
                               derive_conv_tiling_reference,
                               derive_conv_tilings_batch,
                               derive_simd_tiling_reference,
                               derive_simd_tilings_batch)

from .common import row, timed


def _clear_caches() -> None:
    """Cold-start both the tiling and the process-lifetime table caches so
    neither timed path inherits warm state."""
    clear_tiling_caches()
    clear_table_caches()

COMPARE_BUDGETS = (512, 1024, 2048)  # legacy + tensorized, equivalence-checked
SCALE_BUDGETS = (4096,)              # tensorized only
TABLE8_BUDGET = 2048                 # grid for the build-phase timings


def _table8_grid(hw, net):
    """Unique conv size triples (kB) and vmem sizes (kB) of the table8
    grid, plus the deduped layer-shape unions."""
    size_tuples = _tuples((32, 64, 128, 256, 512, 1024, 2048), 4,
                          TABLE8_BUDGET * 0.85, TABLE8_BUDGET * 1.15)
    s3s, _ = _project(size_tuples, lambda t: t[:3])
    vmems, _ = _project(size_tuples, lambda t: t[3])
    eng = _GridEngine(hw, {"net": net})
    return s3s, vmems, eng._conv_union, eng._simd_union


def _derive_scalar(hw, s3s, vmems, convs, simds):
    """Legacy-world tiling derivation: one scalar greedy walk per
    (candidate, layer shape) pair; returns {key: tiling} for seeding."""
    out = {}
    for wb, ib, ob in s3s:
        hw_t = hw.replace(wbuf=wb * KB, ibuf=ib * KB, obuf=ob * KB)
        for layer in convs:
            out[(_conv_hw_key(hw_t), _conv_layer_key(layer))] = \
                derive_conv_tiling_reference(hw_t, layer)
    for vm in vmems:
        hw_v = hw.replace(vmem=vm * KB)
        for layer in simds:
            out[(_simd_hw_key(hw_v), _simd_layer_key(layer))] = \
                derive_simd_tiling_reference(hw_v, layer)
    return out


def _derive_batched(hw, s3s, vmems, convs, simds):
    """Vectorized derivation: one numpy pass per layer shape covers the
    whole candidate axis."""
    tri = [(wb * KB, ib * KB, ob * KB) for wb, ib, ob in s3s]
    vms = [vm * KB for vm in vmems]
    conv = {id(l): derive_conv_tilings_batch(hw, tri, l) for l in convs}
    simd = {id(l): derive_simd_tilings_batch(hw, vms, l) for l in simds}
    return conv, simd


def run() -> List[str]:
    hw = INFER_PRESETS[64]
    net = resnet50(1, bn=False)
    rows: List[str] = []

    # ---- tiling_build: scalar greedy walk vs vectorized batch -------------
    # every build-phase timing is best-of-two (cold caches both times):
    # the compared quantities are deterministic, so min() strips scheduler
    # noise on small CI containers without changing what is measured
    s3s, vmems, convs, simds = _table8_grid(hw, net)
    n_tilings = len(s3s) * len(convs) + len(vmems) * len(simds)

    def best_of_two(fn, *args):
        _clear_caches()
        us1, out = timed(fn, *args)
        _clear_caches()
        us2, out = timed(fn, *args)
        return min(us1, us2), out

    us_scalar, scalar_tls = best_of_two(_derive_scalar, hw, s3s, vmems,
                                        convs, simds)
    us_batch, (conv_tls, simd_tls) = best_of_two(_derive_batched, hw, s3s,
                                                 vmems, convs, simds)
    # elementwise bit-equivalence of every derived tiling
    for layer in convs:
        for (wb, ib, ob), t in zip(s3s, conv_tls[id(layer)]):
            hw_t = hw.replace(wbuf=wb * KB, ibuf=ib * KB, obuf=ob * KB)
            assert t == scalar_tls[(_conv_hw_key(hw_t),
                                    _conv_layer_key(layer))]
    for layer in simds:
        for vm, t in zip(vmems, simd_tls[id(layer)]):
            hw_v = hw.replace(vmem=vm * KB)
            assert t == scalar_tls[(_simd_hw_key(hw_v),
                                    _simd_layer_key(layer))]
    rows.append(row(
        "dse_scaling.tiling_build.scalar", us_scalar,
        f"tilings={n_tilings};per_s={n_tilings / (us_scalar / 1e6):.0f}"))
    rows.append(row(
        "dse_scaling.tiling_build.batched", us_batch,
        f"tilings={n_tilings};per_s={n_tilings / (us_batch / 1e6):.0f};"
        f"speedup={us_scalar / us_batch:.1f}x"))

    # ---- table_build: legacy serial ConvTable loop vs batch build ---------
    hws = [hw.replace(wbuf=wb * KB, ibuf=ib * KB, obuf=ob * KB)
           for wb, ib, ob in s3s]

    def build_scalar():
        # legacy world: a scalar greedy walk per (triple, layer) feeding
        # the tiling cache, then one per-layer Python loop per ConvTable
        for key, t in _derive_scalar(hw, s3s, (), convs, ()).items():
            _CONV_TILING_CACHE[key] = t
        return [ConvTable(h, convs) for h in hws]

    def build_batched():
        batch_build_conv_tables(hws, convs)
        return [_CONV_TABLE_CACHE[_conv_table_key(h, convs)] for h in hws]

    us_tscalar, scalar_tables = best_of_two(build_scalar)
    us_tbatch, batch_tables = best_of_two(build_batched)
    for st, bt in zip(scalar_tables, batch_tables):
        for f in ("c_tile", "o1", "o2", "o4", "o5", "w_bits", "wb_bits",
                  "i_bits", "ps_bits", "pls_bits", "busy", "dram"):
            assert np.array_equal(getattr(st, f), getattr(bt, f)), f
        for buf in st.sram:
            assert np.array_equal(st.sram[buf], bt.sram[buf]), buf
    speedup = us_tscalar / us_tbatch
    assert speedup >= 3.0, f"table_build speedup {speedup:.2f}x < 3x"
    rows.append(row(
        "dse_scaling.table_build.scalar", us_tscalar,
        f"tables={len(hws)};tables_per_s={len(hws) / (us_tscalar / 1e6):.0f}"))
    rows.append(row(
        "dse_scaling.table_build.batched", us_tbatch,
        f"tables={len(hws)};tables_per_s={len(hws) / (us_tbatch / 1e6):.0f};"
        f"speedup={speedup:.1f}x"))

    # ---- end-to-end: legacy scalar loop vs tensorized engine --------------
    for budget in COMPARE_BUDGETS:
        _clear_caches()
        us_ref, ref = timed(search_reference, hw, net, budget, budget)
        _clear_caches()
        us_new, res = timed(search, hw, net, budget, budget)
        n = res.n_candidates
        assert ref.best == res.best and ref.worst == res.worst, budget
        assert ref.within(0.15) == res.points, budget
        rows.append(row(
            f"dse_scaling.loop.{budget}", us_ref,
            f"cands={n};cands_per_s={n / (us_ref / 1e6):.0f}"))
        rows.append(row(
            f"dse_scaling.tensor.{budget}", us_new,
            f"cands={n};cands_per_s={n / (us_new / 1e6):.0f};"
            f"speedup={us_ref / us_new:.1f}x"))
    for budget in SCALE_BUDGETS:
        _clear_caches()
        us_new, res = timed(search, hw, net, budget, budget)
        n = res.n_candidates
        rows.append(row(
            f"dse_scaling.tensor.{budget}", us_new,
            f"cands={n};cands_per_s={n / (us_new / 1e6):.0f}"))

    # ---- grid_eval: host reduction vs on-device jit/vmap vs fused ---------
    rows.extend(_grid_eval_rows(hw, net))
    _clear_caches()
    return rows


def _grid_eval_rows(hw, net) -> List[str]:
    """Time the table8 grid reductions themselves (tables warm) on every
    backend and assert them bit-identical; see module docstring for the
    speedup-bar policy."""
    import jax

    from repro.core import gridax
    from repro.core.dse import (_EnergyFields, _pareto_mask, FRONTIER_FRAC)
    from repro.core.energy import DEFAULT_ENERGY

    rows: List[str] = []
    lattice = (32, 64, 128, 256, 512, 1024, 2048)
    size_tuples = _tuples(lattice, 4, TABLE8_BUDGET * 0.85,
                          TABLE8_BUDGET * 1.15)
    bw_tuples = _tuples(lattice, 4, TABLE8_BUDGET * 0.85,
                        TABLE8_BUDGET * 1.15)
    s3s, s3_of = _project(size_tuples, lambda t: t[:3])
    vs, v_of = _project(size_tuples, lambda t: t[3])
    b3s, b3_of = _project(bw_tuples, lambda t: t[:3])
    ws, w_of = _project(bw_tuples, lambda t: t[3])
    eng = _GridEngine(hw, {"net": net})
    conv_mats, _, conv_e = eng.conv_matrices(s3s, b3s)
    simd_mats, _, simd_e = eng.simd_matrices(vs, ws)
    conv, simd = conv_mats["net"], simd_mats["net"]
    mult = 1.0 + FRONTIER_FRAC
    n = len(size_tuples) * len(bw_tuples)
    on_accelerator = jax.default_backend() in ("tpu", "gpu")

    def best_of(fn, reps=3):
        us = min(timed(fn)[0] for _ in range(reps))
        return us, fn()

    def numpy_reduce():
        costs = conv[np.ix_(s3_of, b3_of)] + simd[np.ix_(v_of, w_of)]
        flat = costs.ravel()
        bi = int(flat.argmin())
        return costs, bi, int(flat.argmax()), flat <= flat[bi] * mult

    def jit_reduce():
        return gridax.reduce_cycles_many([conv], [simd], s3_of, b3_of,
                                         v_of, w_of, frontier_mult=mult)[0]

    def fused_reduce():
        return gridax.reduce_cycles_many([conv], [simd], s3_of, b3_of,
                                         v_of, w_of, frontier_mult=mult,
                                         fused=True)[0]

    us_np, (costs, bi, wi, fm) = best_of(numpy_reduce)
    us_jit, (cj, bj, wj, fj) = best_of(jit_reduce)
    us_fused, (cf, bf, wf, ff) = best_of(fused_reduce)
    for label, (c2, b2, w2, f2) in (("jit", (cj, bj, wj, fj)),
                                    ("fused", (cf, bf, wf, ff))):
        assert (b2, w2) == (bi, wi), label
        assert np.array_equal(c2, costs) and np.array_equal(f2, fm), label

    speedup = us_np / us_jit
    rows.append(row("dse_scaling.grid_eval.numpy", us_np,
                    f"cands={n};cands_per_s={n / (us_np / 1e6):.0f}"))
    rows.append(row(
        "dse_scaling.grid_eval.jit", us_jit,
        f"cands={n};cands_per_s={n / (us_jit / 1e6):.0f};"
        f"speedup={speedup:.2f}x;backend={jax.default_backend()}"))
    rows.append(row(
        "dse_scaling.grid_eval.fused", us_fused,
        f"cands={n};cands_per_s={n / (us_fused / 1e6):.0f};"
        f"interpret={not on_accelerator}"))
    if on_accelerator:
        assert speedup >= 5.0, \
            f"grid_eval jit speedup {speedup:.2f}x < 5x on accelerator"
    else:
        # CPU floor: both paths must clear the >10M cands/s target
        assert n / (us_np / 1e6) > 10e6 and n / (us_jit / 1e6) > 10e6

    # ---- Pareto: sequential host walk vs vectorized device mask ----------
    energy = _EnergyFields(hw=hw, em=DEFAULT_ENERGY, conv=conv_e["net"],
                           simd=simd_e["net"], s3_of=s3_of, v_of=v_of,
                           sizes_kb=np.array(size_tuples, dtype=np.int64))
    e_total = energy.grids(costs)["E_total"].ravel()
    flat = costs.ravel()
    us_ploop, pm_np = best_of(lambda: _pareto_mask(flat, e_total))
    us_pjit, pm_dev = best_of(lambda: gridax.pareto_mask(flat, e_total))
    assert np.array_equal(pm_np, pm_dev)
    rows.append(row("dse_scaling.grid_eval.pareto_loop", us_ploop,
                    f"cands={n};front={int(pm_np.sum())}"))
    rows.append(row(
        "dse_scaling.grid_eval.pareto_dev", us_pjit,
        f"cands={n};front={int(pm_dev.sum())};"
        f"speedup={us_ploop / us_pjit:.2f}x"))
    if on_accelerator:
        assert us_ploop / us_pjit >= 5.0
    return rows
