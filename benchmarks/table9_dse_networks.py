"""Paper Table IX: DSE on a 64x64 array, budget (2048kB, 2048 bits/cycle),
across ResNet-18 / VGG16 / AlexNet — one ``search_many`` call, so every
per-size cost table is built once and shared across the networks."""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS
from repro.core.dse import search_many
from repro.core.networks import alexnet, resnet18, vgg16

from .common import row, timed

PAPER = {"resnet18": 13.85, "vgg16": 19.94, "alexnet": 33.72}


def run() -> List[str]:
    hw = INFER_PRESETS[64]
    nets = {name: builder(1, bn=False)
            for name, builder in (("resnet18", resnet18), ("vgg16", vgg16),
                                  ("alexnet", alexnet))}
    us, results = timed(search_many, hw, nets, 2048, 2048)
    # The search is one shared call; its wall time is reported once on the
    # .all row rather than attributed (evenly and wrongly) per network.
    rows: List[str] = [row("table9.all.64x64", us,
                           f"networks={len(results)};shared_tables=1")]
    for name, res in results.items():
        rows.append(row(
            f"table9.{name}.64x64", 0.0,
            f"improvement={res.improvement:.2f}x;paper={PAPER[name]}x;"
            f"opt_sizes={'/'.join(map(str, res.best.sizes_kb))}kB;"
            f"opt_bw={'/'.join(map(str, res.best.bws))}"))
    return rows
