"""Paper Table IX: DSE on a 64x64 array, budget (2048kB, 2048 bits/cycle),
across ResNet-18 / VGG16 / AlexNet."""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS
from repro.core.dse import search
from repro.core.networks import alexnet, resnet18, vgg16

from .common import row, timed

PAPER = {"resnet18": 13.85, "vgg16": 19.94, "alexnet": 33.72}


def run() -> List[str]:
    rows: List[str] = []
    hw = INFER_PRESETS[64]
    for name, builder in (("resnet18", resnet18), ("vgg16", vgg16),
                          ("alexnet", alexnet)):
        net = builder(1, bn=False)
        us, res = timed(search, hw, net, 2048, 2048)
        rows.append(row(
            f"table9.{name}.64x64", us,
            f"improvement={res.improvement:.2f}x;paper={PAPER[name]}x;"
            f"opt_sizes={'/'.join(map(str, res.best.sizes_kb))}kB;"
            f"opt_bw={'/'.join(map(str, res.best.bws))}"))
    return rows
