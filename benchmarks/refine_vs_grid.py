"""Local-search (``method="refine"``) vs exhaustive grid DSE on the
Table VIII ResNet-50 sweep: wall time, optimum quality (refined cycles /
exhaustive power-of-two optimum — <= 1.0 by the never-worse invariant,
< 1.0 whenever the off-lattice granularity pays), candidate-evaluation
saving (>= 10x by construction), and the table-cache hit story (the
refine run after the grid sweep rebuilds nothing at the lattice level).

Caches are cleared before each budget's grid run so the timings are
cold-start per budget; the refine run then *keeps* the grid's tables,
which is the intended deployment (the cache-hit column shows how much of
the refine run's table work the grid sweep had already paid for).
"""
from __future__ import annotations

from typing import List

from repro.core import INFER_PRESETS
from repro.core.dse import clear_table_caches, search, table_cache_stats
from repro.core.networks import resnet50
from repro.core.tiling import clear_tiling_caches

from .common import row, timed

BUDGETS = {16: 512, 32: 1024, 64: 2048, 128: 4096}


def _hw(jk: int):
    base = INFER_PRESETS.get(jk, INFER_PRESETS[64])
    return base.replace(name=f"refine{jk}", J=jk, K=jk)


def run(network=resnet50, tag: str = "refine_vs_grid.resnet50") -> List[str]:
    net = network(1, bn=False)
    rows: List[str] = []
    for jk, budget in BUDGETS.items():
        clear_tiling_caches()
        clear_table_caches()
        hw = _hw(jk)
        us_grid, g = timed(search, hw, net, budget, budget)
        before = table_cache_stats()
        us_ref, r = timed(search, hw, net, budget, budget, method="refine")
        after = table_cache_stats()
        hits = after["conv_hits"] - before["conv_hits"]
        misses = after["conv_misses"] - before["conv_misses"]
        hit_rate = hits / max(1, hits + misses)
        assert r.best.cycles <= g.best.cycles, (jk, budget)
        rows.append(row(
            f"{tag}.{jk}x{jk}.grid", us_grid,
            f"best={g.best.cycles};cands={g.n_candidates}"))
        rows.append(row(
            f"{tag}.{jk}x{jk}.refine", us_ref,
            f"best={r.best.cycles};quality={r.best.cycles / g.best.cycles:.4f};"
            f"evals={r.n_candidates};saving={r.refine.eval_saving:.1f}x;"
            f"table_hit_rate={hit_rate:.2f};"
            f"opt_sizes={'/'.join(map(str, r.best.sizes_kb))}kB;"
            f"opt_bw={'/'.join(map(str, r.best.bws))}"))
    return rows
