"""Shared benchmark harness: every table/figure module exposes ``run()``
returning a list of CSV rows ``name,us_per_call,derived`` where ``derived``
is the headline metric the paper's table reports."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timed(fn: Callable, *args, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return (time.perf_counter() - t0) * 1e6, out


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
