"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows by default, or a JSON array
with ``--json`` (for harnesses that need robust parsing).  Run as
``PYTHONPATH=src python -m benchmarks.run [--only PREFIX] [--json]``.

Failures never abort the sweep: the offending module's traceback goes to
stderr, an ERROR row is emitted, and the exit code is non-zero.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benchmarks whose module name contains this")
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON array instead of CSV rows")
    args = ap.parse_args()

    from . import (dse_scaling, dse_service, fig5_stall_models,
                   fig12_sensitivity, llm_dse, pareto_energy,
                   refine_vs_grid, store_persistence, table6_resnet50,
                   table7_resnet18, table8_dse, table9_dse_networks,
                   table10_economic, table11_training_dse)
    from . import roofline_bench

    modules = [table6_resnet50, table7_resnet18, fig5_stall_models,
               table8_dse, table9_dse_networks, table10_economic,
               table11_training_dse, llm_dse, refine_vs_grid,
               pareto_energy, fig12_sensitivity, roofline_bench,
               dse_scaling, store_persistence, dse_service]

    records = []
    failures = 0
    for mod in modules:
        name = mod.__name__.rsplit(".", 1)[-1]
        if args.only and args.only not in name:
            continue
        try:
            for line in mod.run():
                rname, us, derived = line.split(",", 2)
                records.append((rname, float(us), derived))
        except Exception as exc:
            failures += 1
            traceback.print_exc(file=sys.stderr)
            records.append((f"{name}.ERROR", 0.0,
                            f"{type(exc).__name__}:{exc}"))

    if args.json:
        print(json.dumps([{"name": n, "us_per_call": us, "derived": d}
                          for n, us, d in records], indent=2))
    else:
        print("name,us_per_call,derived")
        for n, us, d in records:
            print(f"{n},{us:.1f},{d}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
