"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Run as
``PYTHONPATH=src python -m benchmarks.run [--only PREFIX]``.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benchmarks whose module name contains this")
    args = ap.parse_args()

    from . import (fig5_stall_models, fig12_sensitivity, table6_resnet50,
                   table7_resnet18, table8_dse, table9_dse_networks,
                   table10_economic)
    from . import roofline_bench

    modules = [table6_resnet50, table7_resnet18, fig5_stall_models,
               table8_dse, table9_dse_networks, table10_economic,
               fig12_sensitivity, roofline_bench]

    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        name = mod.__name__.rsplit(".", 1)[-1]
        if args.only and args.only not in name:
            continue
        try:
            for line in mod.run():
                print(line)
        except Exception as exc:  # pragma: no cover
            failures += 1
            print(f"{name}.ERROR,0.0,{type(exc).__name__}:{exc}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
